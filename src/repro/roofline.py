"""Roofline analysis from compiled (SPMD-partitioned) HLO.

``compiled.cost_analysis()`` visits every instruction ONCE — it does not
multiply ``while``-loop bodies by their trip count, so a scan-over-layers
model under-reports FLOPs by ~num_layers x.  This module re-derives the
three roofline terms by walking the post-optimization HLO text with
explicit trip-count multipliers:

  * FLOPs        — every ``dot`` (2 * prod(result) * contracted), recursing
                   into fusions / calls / while bodies (x trip count).
  * HBM bytes    — operand + result bytes of instructions at fusion
                   granularity (fusion internals excluded: on TPU a fusion
                   reads inputs and writes outputs through HBM once).
                   Bookkeeping opcodes (parameter/tuple/gte/constant/bitcast)
                   are skipped.  This is an HBM-traffic estimate, not an
                   exact count — documented in EXPERIMENTS.md.
  * collectives  — operand bytes of all-reduce / all-gather / reduce-scatter
                   / all-to-all / collective-permute (+ async -start forms),
                   x trip count, bucketed by type.

All numbers are PER DEVICE (the partitioned module is the per-device
program).  Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

PEAK_FLOPS = 197e12      # bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")
_SKIP_BYTES_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast",
                   "constant", "after-all", "partition-id", "replica-id"}

# opcodes we must detect (longest-match first so e.g. all-gather-start wins)
_KNOWN_OPS = sorted(
    ["dot", "fusion", "call", "conditional", "while", "convolution",
     "custom-call", "parameter", "tuple", "get-tuple-element", "bitcast",
     "constant", "iota", "broadcast", "scatter", "gather", "copy",
     "all-reduce-start", "all-reduce-done", "all-reduce",
     "all-gather-start", "all-gather-done", "all-gather",
     "reduce-scatter", "all-to-all", "ragged-all-to-all",
     "collective-permute-start", "collective-permute-done",
     "collective-permute"],
    key=len, reverse=True)


def _shape_bytes(dtype: str, dims: str) -> int:
    if not dims:
        return _DTYPE_BYTES[dtype]
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _opcode_of(rhs: str) -> Optional[str]:
    for op in _KNOWN_OPS:
        if rhs.startswith(f"{op}("):
            return op
        i = rhs.find(f" {op}(")
        if i >= 0:
            return op
    m = re.search(r"(?:^|\s)([a-z0-9\-]+)\(", rhs)
    return m.group(1) if m else None


_NAME_RE = re.compile(r"%([\w\.\-]+)")


@dataclass
class Instr:
    name: str
    opcode: str
    result_shapes: list          # [(dtype, dims_str), ...]
    operands: list               # operand instruction names
    line: str

    @property
    def result_bytes(self) -> int:
        return sum(_shape_bytes(d, s) for d, s in self.result_shapes)

    def result_dims(self) -> Optional[list]:
        if len(self.result_shapes) == 1:
            dims = self.result_shapes[0][1]
            return [int(x) for x in dims.split(",")] if dims else []
        return None


def _parse_instr(line: str) -> Optional[Instr]:
    if " = " not in line:
        return None
    lhs, rhs = line.split(" = ", 1)
    mname = _NAME_RE.search(lhs)
    if not mname:
        return None
    opcode = _opcode_of(rhs)
    if opcode is None:
        return None
    op_idx = rhs.find(f"{opcode}(")
    if op_idx < 0:
        return None
    # result shapes: all shape tokens before the opcode
    result_shapes = [(m.group(1), m.group(2))
                     for m in _SHAPE_RE.finditer(rhs[:op_idx])]
    # operand list: balanced-paren scan from the opcode's '('
    start = op_idx + len(opcode) + 1
    depth, end = 1, start
    while end < len(rhs) and depth:
        c = rhs[end]
        depth += (c == "(") - (c == ")")
        end += 1
    operands = _NAME_RE.findall(rhs[start:end - 1])
    return Instr(mname.group(1), opcode, result_shapes, operands, line)


@dataclass
class HloCosts:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    by_type: dict = field(default_factory=dict)

    def add(self, other: "HloCosts", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.by_type.items():
            self.by_type[k] = self.by_type.get(k, 0.0) + v * mult


class HloModule:
    """Minimal post-optimization HLO text parser with a per-computation
    symbol table (operand shapes are not printed inline)."""

    def __init__(self, text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.tables: dict[str, dict[str, Instr]] = {}
        self.entry: Optional[str] = None
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            if cur is None:
                m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(\([^{]*)?\{\s*$", line)
                if m and ("->" in line or m.group(1) or "(" in line):
                    cur = m.group(2)
                    self.computations[cur] = []
                    self.tables[cur] = {}
                    if m.group(1):
                        self.entry = cur
                continue
            if stripped.startswith("}"):
                cur = None
                continue
            if stripped:
                ins = _parse_instr(stripped)
                if ins is not None:
                    self.computations[cur].append(ins)
                    self.tables[cur][ins.name] = ins
        if self.entry is None and self.computations:
            self.entry = list(self.computations)[-1]

    def _operand_bytes(self, comp: str, ins: Instr) -> int:
        table = self.tables.get(comp, {})
        total = 0
        for op_name in ins.operands:
            ref = table.get(op_name)
            if ref is not None:
                total += ref.result_bytes
        return total

    def _dot_flops(self, comp: str, ins: Instr) -> float:
        res = ins.result_dims()
        if res is None:
            return 0.0
        contracted = 1
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
        lhs = self.tables.get(comp, {}).get(ins.operands[0]) if ins.operands else None
        if m and m.group(1) and lhs is not None:
            lhs_dims = lhs.result_dims() or []
            for ci in m.group(1).split(","):
                ci = int(ci)
                if ci < len(lhs_dims):
                    contracted *= lhs_dims[ci]
        return 2.0 * float(np.prod(res or [1])) * contracted

    _PASSTHROUGH = ("convert", "bitcast", "reduce-precision", "copy", "reshape")

    def _fusion_io_bytes(self, fusion_comp: str, call_ins: Instr,
                         caller_comp: str) -> int:
        """HBM traffic of one fusion call.

        A fusion reads its inputs and writes its outputs through HBM once —
        EXCEPT parameters that (possibly through elementwise convert chains)
        are only consumed by (dynamic-)slice/gather (scan xs indexing: only
        the slice is read) or feed the buffer side of a dynamic-update-slice
        (in-place on TPU: only the update window moves).  Elementwise
        convert/bitcast chains are register traffic on TPU, not HBM.
        """
        instrs = self.computations.get(fusion_comp)
        if instrs is None:
            return call_ins.result_bytes + self._operand_bytes(caller_comp, call_ins)
        table = self.tables[fusion_comp]
        uses: dict[str, list[Instr]] = {}
        for ins in instrs:
            for op_name in ins.operands:
                uses.setdefault(op_name, []).append(ins)

        def terminal_uses(name: str, depth: int = 0) -> Optional[list]:
            """Follow pass-through chains; None => give up (count full)."""
            if depth > 8:
                return None
            out = []
            for u in uses.get(name, ()):
                if u.opcode in self._PASSTHROUGH:
                    t = terminal_uses(u.name, depth + 1)
                    if t is None:
                        return None
                    out.extend(t)
                else:
                    out.append((name, u))
            return out

        read = 0
        for p in (i for i in instrs if i.opcode == "parameter"):
            terms = terminal_uses(p.name)
            if terms is None:
                read += p.result_bytes
                continue
            if not terms:       # unused (or pure passthrough to root)
                read += p.result_bytes
                continue
            partial = 0
            ok = True
            for via, u in terms:
                if u.opcode in ("dynamic-slice", "slice", "gather"):
                    partial += u.result_bytes
                elif u.opcode == "dynamic-update-slice" and u.operands and \
                        u.operands[0] == via:
                    upd = table.get(u.operands[1]) if len(u.operands) > 1 else None
                    partial += upd.result_bytes if upd else 0
                else:
                    ok = False
                    break
            read += partial if ok else p.result_bytes
        # output side: walk back through pass-through ops to a DUS root
        root = next((i for i in instrs if "ROOT" in i.line), instrs[-1])
        for _ in range(8):
            if root.opcode in self._PASSTHROUGH and root.operands and \
                    root.operands[0] in table:
                root = table[root.operands[0]]
            else:
                break
        if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
            upd = table.get(root.operands[1])
            written = upd.result_bytes if upd else call_ins.result_bytes
        else:
            written = call_ins.result_bytes
        return read + written

    def trip_count(self, cond_name: str) -> int:
        best = 1
        for ins in self.computations.get(cond_name, ()):
            for m in re.finditer(r"constant\((\d+)\)", ins.line):
                best = max(best, int(m.group(1)))
        return best

    def cost(self, name: Optional[str] = None, as_fusion: bool = False,
             _memo: Optional[dict] = None) -> HloCosts:
        if _memo is None:
            _memo = {}
        name = name or self.entry
        key = (name, as_fusion)
        if key in _memo:
            return _memo[key]
        total = HloCosts()
        for ins in self.computations.get(name, ()):
            op = ins.opcode
            if op == "dot":
                total.flops += self._dot_flops(name, ins)
            elif op == "fusion":
                m = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                if m:
                    inner = self.cost(m.group(1), as_fusion=True, _memo=_memo)
                    total.add(HloCosts(flops=inner.flops,
                                       collective_bytes=inner.collective_bytes,
                                       by_type=inner.by_type))
                if not as_fusion:
                    total.bytes += self._fusion_io_bytes(
                        m.group(1) if m else "", ins, name)
                continue
            elif op in ("call", "conditional", "custom-call"):
                m = re.search(r"to_apply=%?([\w\.\-]+)", ins.line)
                if m:
                    total.add(self.cost(m.group(1), as_fusion=as_fusion, _memo=_memo))
            elif op == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                trips = self.trip_count(mc.group(1)) if mc else 1
                if mb:
                    total.add(self.cost(mb.group(1), as_fusion=False, _memo=_memo),
                              mult=trips)
                continue
            elif op.startswith(_COLLECTIVES):
                if op.endswith("-done"):
                    continue
                operand_bytes = self._operand_bytes(name, ins)
                base = op.replace("-start", "")
                total.collective_bytes += operand_bytes
                total.by_type[base] = total.by_type.get(base, 0.0) + operand_bytes
                if not as_fusion:
                    total.bytes += ins.result_bytes + operand_bytes
                continue
            if not as_fusion and op not in _SKIP_BYTES_OPS:
                total.bytes += ins.result_bytes + self._operand_bytes(name, ins)
        _memo[key] = total
        return total


def analyze_hlo_text(text: str) -> HloCosts:
    return HloModule(text).cost()


def roofline_terms(costs: HloCosts) -> dict:
    """Per-device seconds for the three roofline terms + dominant."""
    t_compute = costs.flops / PEAK_FLOPS
    t_memory = costs.bytes / HBM_BW
    t_collective = costs.collective_bytes / LINK_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_collective)),
        key=lambda kv: kv[1])[0]
    total = max(t_compute, t_memory, t_collective)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "bound_step_s": total,
        "roofline_fraction": (t_compute / total) if total > 0 else 0.0,
        "collective_by_type": dict(costs.by_type),
        "hlo_flops_per_dev": costs.flops,
        "hlo_bytes_per_dev": costs.bytes,
        "collective_bytes_per_dev": costs.collective_bytes,
    }


def model_flops(cfg, shape, accum_unused: int = 1) -> float:
    """Analytic MODEL_FLOPS (global): 6·N·D train (N_active for MoE),
    2·N·D for inference shapes."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        if cfg.family == "audio":
            tokens = shape.global_batch * (shape.seq_len + shape.seq_len // cfg.dec_ratio)
        else:
            tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "audio":
            tokens = shape.global_batch * (shape.seq_len + shape.seq_len // cfg.dec_ratio)
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch
