"""Yi-6B — llama-arch GQA [arXiv:2403.04652; hf]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=4,
    d_ff=11008, vocab_size=64000,
    activation="swiglu", norm_type="rmsnorm", rope_theta=5_000_000.0,
)

SMOKE = ModelConfig(
    name="yi-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
    d_ff=128, vocab_size=512,
    activation="swiglu", norm_type="rmsnorm",
)
