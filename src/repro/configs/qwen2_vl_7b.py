"""Qwen2-VL-7B — LM backbone with M-RoPE; vision frontend is a stub that
feeds precomputed patch embeddings [arXiv:2409.12191; hf]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, activation="swiglu", norm_type="rmsnorm",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    frontend="vision_patch",
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512,
    qkv_bias=True, activation="swiglu", norm_type="rmsnorm",
    mrope_sections=(2, 3, 3),
    frontend="vision_patch",
)
