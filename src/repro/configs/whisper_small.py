"""Whisper-small — enc-dec; conv frontend is a stub that feeds precomputed
frame embeddings [arXiv:2212.04356; unverified].

Deviations (DESIGN.md §4): RoPE replaces learned/sinusoidal absolute
positions so the assigned 32k decode shape is well-defined; decoder length
is seq_len // dec_ratio for sequence shapes.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865,
    activation="gelu", norm_type="layernorm",
    is_encoder_decoder=True, num_decoder_layers=12, dec_ratio=4,
    frontend="audio_frames",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512,
    activation="gelu", norm_type="layernorm",
    is_encoder_decoder=True, num_decoder_layers=2, dec_ratio=4,
    frontend="audio_frames",
)
