"""InternLM2-20B — dense GQA [arXiv:2403.17297; hf]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b", family="dense",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92544,
    activation="swiglu", norm_type="rmsnorm", rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="internlm2-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512,
    activation="swiglu", norm_type="rmsnorm",
)
