"""CodeQwen1.5-7B — qwen1.5-arch, kv=32 (MHA-like), QKV bias [hf:Qwen/CodeQwen1.5-7B]."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13440, vocab_size=92416,
    qkv_bias=True, activation="swiglu", norm_type="rmsnorm",
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="codeqwen-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512,
    qkv_bias=True, activation="swiglu", norm_type="rmsnorm",
)
