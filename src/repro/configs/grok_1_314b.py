"""Grok-1 (314B) — 8 experts top-2, attention logit softcap 30
[hf:xai-org/grok-1; unverified]."""
from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=32768, vocab_size=131072,
    activation="geglu", norm_type="rmsnorm",
    attn_logit_softcap=30.0,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32768),
)

SMOKE = ModelConfig(
    name="grok-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512,
    activation="geglu", norm_type="rmsnorm",
    attn_logit_softcap=30.0,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
)
