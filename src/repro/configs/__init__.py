"""Architecture config registry.

Each assigned architecture lives in ``repro/configs/<id>.py`` (dashes ->
underscores) and exposes ``CONFIG`` (the exact published config) and
``SMOKE`` (a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "internlm2-20b",
    "yi-6b",
    "codeqwen1.5-7b",
    "qwen2.5-14b",
    "recurrentgemma-2b",
    "olmoe-1b-7b",
    "grok-1-314b",
    "rwkv6-3b",
    "qwen2-vl-7b",
    "whisper-small",
)


def _module(arch: str):
    mod = arch.replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(arch: str):
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    return _module(arch).CONFIG


def get_smoke_config(arch: str):
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    return _module(arch).SMOKE


def list_archs() -> tuple[str, ...]:
    return ARCH_IDS
