"""RWKV-6 (Finch) 3B — attention-free, data-dependent decay
[arXiv:2404.05892; hf]."""
from repro.config import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40,
    d_ff=8960, vocab_size=65536,
    activation="relu_sq", norm_type="layernorm",
    rwkv=RWKVConfig(head_size=64, decay_lora=64, gate_lora=160),
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512,
    activation="relu_sq", norm_type="layernorm",
    rwkv=RWKVConfig(head_size=16, decay_lora=8, gate_lora=16),
)
