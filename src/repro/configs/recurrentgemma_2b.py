"""RecurrentGemma-2B — Griffin: RG-LRU + local attention, 1 attn per 2
recurrent blocks [arXiv:2402.19427; hf]."""
from repro.config import ModelConfig, RecurrentConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256_000,
    activation="geglu", norm_type="rmsnorm", tie_embeddings=True,
    recurrent=RecurrentConfig(
        lru_width=2560, conv1d_width=4,
        block_pattern=("recurrent", "recurrent", "attention"),
        window_size=2048,
    ),
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid",
    num_layers=5, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512,
    activation="geglu", norm_type="rmsnorm", tie_embeddings=True,
    recurrent=RecurrentConfig(
        lru_width=64, conv1d_width=4,
        block_pattern=("recurrent", "recurrent", "attention"),
        window_size=8,
    ),
)
