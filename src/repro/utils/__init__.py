from repro.utils.trees import (
    tree_bytes,
    tree_count,
    tree_flatten_with_names,
    tree_allclose,
    tree_zeros_like,
)
from repro.utils.timing import Timer, now

__all__ = [
    "tree_bytes",
    "tree_count",
    "tree_flatten_with_names",
    "tree_allclose",
    "tree_zeros_like",
    "Timer",
    "now",
]
