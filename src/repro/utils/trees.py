"""Pytree utilities used across the framework."""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree: Any) -> int:
    """Total number of array elements in a pytree."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(np.prod(l.shape) if hasattr(l, "shape") else 1 for l in leaves))


def tree_bytes(tree: Any) -> int:
    """Total bytes of a pytree (works on ShapeDtypeStruct too)."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            total += int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        else:
            total += 8
    return total


def _name_of_path(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def tree_flatten_with_names(tree: Any) -> list[tuple[str, Any]]:
    """Flatten a pytree into (slash/path/name, leaf) pairs, stable order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(_name_of_path(path), leaf) for path, leaf in flat]


def tree_allclose(a: Any, b: Any, rtol: float = 1e-5, atol: float = 1e-6) -> bool:
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    if len(la) != len(lb):
        return False
    return all(
        np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
        for x, y in zip(la, lb)
    )


def tree_zeros_like(tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda l: jnp.zeros(l.shape, l.dtype), tree)


def tree_map_with_names(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """Map fn(name, leaf) over a pytree, preserving structure."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(_name_of_path(path), leaf), tree
    )
