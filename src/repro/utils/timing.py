"""Wall-clock helpers (real time for the live path; the simulator keeps its own clock)."""
from __future__ import annotations

import time


def now() -> float:
    return time.monotonic()


class Timer:
    """Context-manager timer: ``with Timer() as t: ...; t.elapsed``."""

    def __enter__(self) -> "Timer":
        self._t0 = time.monotonic()
        self.elapsed = 0.0
        return self

    def __exit__(self, *exc) -> None:
        self.elapsed = time.monotonic() - self._t0
