"""Error-feedback int8 gradient compression for the slow cross-pod
all-reduce (DESIGN.md §5 distributed-optimization tricks).

Standard EF-SGD scheme: compress(g + residual) -> int8 with a per-tensor
scale; the quantization error feeds back into the next step's residual so
the compression is unbiased over time.  Intended placement: gradients are
reduce-scattered at full precision *within* a pod (fast ICI), compressed
only for the pod-axis all-reduce (slow DCI) — an 8x byte reduction on the
slowest link.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def compress_tree(grads: Params, residual: Params):
    """Returns (q_tree, scale_tree, new_residual)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_r = gf - q.astype(jnp.float32) * scale
        return q, scale, new_r

    flat, treedef = jax.tree_util.tree_flatten(grads)
    rflat = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat, rflat)]
    q = treedef.unflatten([o[0] for o in out])
    s = treedef.unflatten([o[1] for o in out])
    new_r = treedef.unflatten([o[2] for o in out])
    return q, s, new_r


def decompress_tree(q: Params, scales: Params) -> Params:
    return jax.tree_util.tree_map(
        lambda qq, ss: qq.astype(jnp.float32) * ss, q, scales)


def init_residual(params: Params) -> Params:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads: Params, residual: Params, axis_name: str):
    """shard_map-compatible compressed all-reduce over ``axis_name``:
    quantize locally, psum the int8 payload (as int32 accumulators), and
    rescale by the mean scale.  Error feedback keeps it unbiased."""
    q, s, new_r = compress_tree(grads, residual)
    summed = jax.tree_util.tree_map(
        lambda qq: jax.lax.psum(qq.astype(jnp.int32), axis_name), q)
    mean_scale = jax.tree_util.tree_map(
        lambda ss: jax.lax.pmean(ss, axis_name), s)
    out = jax.tree_util.tree_map(
        lambda acc, ss: acc.astype(jnp.float32) * ss, summed, mean_scale)
    return out, new_r
