"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import OptimizerConfig


def make_schedule(cfg: OptimizerConfig):
    warmup = max(1, cfg.warmup_steps)
    total = max(cfg.total_steps, warmup + 1)

    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = cfg.lr * jnp.minimum(1.0, step / warmup)
        if cfg.schedule == "constant":
            return warm
        prog = jnp.clip((step - warmup) / (total - warmup), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cfg.lr * (0.1 + 0.9 * cos))

    return sched
