"""Optimizers in pure JAX (no optax): AdamW, SGD+momentum.

An Optimizer is a pair of pure functions ``init(params) -> opt_state`` and
``update(grads, opt_state, params, step) -> (new_params, new_opt_state)``.
Optimizer state lives in fp32 regardless of param dtype (master copies are
the params themselves, kept in ``param_dtype=float32`` by default).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig
from repro.optim.schedule import make_schedule

Params = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params, jax.Array], tuple[Params, Any]]


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree: Params, max_norm: float) -> tuple[Params, jax.Array]:
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), gn


def adamw(cfg: OptimizerConfig) -> Optimizer:
    sched = make_schedule(cfg)
    sdt = jnp.dtype(cfg.state_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, sdt)
        return {
            "m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
        }

    def update(grads, state, params, step):
        lr = sched(step)
        b1, b2 = cfg.b1, cfg.b2
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = m_new / bc1
            vhat = v_new / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            # decoupled weight decay on matrices only (ndim >= 2)
            wd = cfg.weight_decay if p.ndim >= 2 else 0.0
            p_new = p.astype(jnp.float32) - lr * (delta + wd * p.astype(jnp.float32))
            return p_new.astype(p.dtype), m_new.astype(sdt), v_new.astype(sdt)

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


def sgd(cfg: OptimizerConfig) -> Optimizer:
    sched = make_schedule(cfg)
    momentum = 0.9

    def init(params):
        return {"mom": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        lr = sched(step)

        def upd(g, m, p):
            m_new = momentum * m + g.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * m_new
            return p_new.astype(p.dtype), m_new

        pairs = jax.tree_util.tree_map(upd, grads, state["mom"], params)
        # tree_map over 3 trees returns tuples at leaves -> split
        new_p = jax.tree_util.tree_map(lambda t: t[0], pairs,
                                       is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree_util.tree_map(lambda t: t[1], pairs,
                                       is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"mom": new_m}

    return Optimizer(init, update)


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    if cfg.name == "adamw":
        return adamw(cfg)
    if cfg.name == "sgd":
        return sgd(cfg)
    raise ValueError(f"unknown optimizer {cfg.name}")
