from repro.runtime.trainer import ResilientTrainer, TrainerConfig
from repro.runtime.server import StreamServer

__all__ = ["ResilientTrainer", "TrainerConfig", "StreamServer"]
