from repro.runtime.trainer import (ResilientTrainer, TrainerConfig,
                                   TrainerJobHandle)
from repro.runtime.server import StreamServer

__all__ = ["ResilientTrainer", "TrainerConfig", "TrainerJobHandle",
           "StreamServer"]
