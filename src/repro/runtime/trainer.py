"""Live resilient trainer: the real-JAX data plane the Khaos control plane
supervises.

Wires together: streaming batcher (consumer-lag semantics) -> jit'd
train_step -> the unified checkpoint plane (one ``CheckpointManager``
executing a ``CheckpointPlan``: full or delta encoding, memory/local/remote
level routing, sync or async commit — atomically committed WITH the stream
cursor for exactly-once) -> failure injection + failure-kind-aware restore
(plus gray-failure *degradation* windows — straggler / net_delay /
backpressure — that slow or starve the job without killing it)
-> metrics -> the Khaos controller via ``TrainerJobHandle``.

``TrainerJobHandle`` implements the FULL ``core.controller.JobHandle``
protocol, including the ``reconfigure_plan`` actuation the ROADMAP called
for: ``ResilientTrainer.set_plan`` drains (checkpoint-now under the active
plan, async commits quiesced), rebuilds the ``CheckpointManager`` from the
new ``CheckpointPlan`` on the SAME policy clock and metrics store (cadence
and observation windows stay continuous across the switch), and resumes —
the live mirror of ``SimJobHandle.reconfigure_plan``'s savepoint+restart
semantics.

Time: the trainer runs on a *virtual clock* driven by measured step wall
times (scaled by ``time_scale``), so a 2-hour streaming experiment runs in
seconds on CPU while keeping real step/checkpoint costs in the loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import CheckpointPlan, ModelConfig, OptimizerConfig
from repro.config import replace as cfg_replace
from repro.data.pipeline import StreamingBatcher
from repro.data.stream import EventStream
from repro.ft.failures import Degradation, InjectedFailure, jitter_phase
from repro.metrics import MetricsStore
from repro.models import zoo
from repro.optim import make_optimizer


@dataclass
class TrainerConfig:
    batch: int = 8
    seq_len: int = 64
    ckpt_dir: str = "/tmp/repro_trainer"
    ckpt_interval_s: float = 30.0
    ckpt_async: bool = False
    num_shards: int = 2
    time_scale: float = 1.0        # virtual seconds per wall second of compute
    detect_s: float = 5.0          # simulated detection timeout after a crash
    restart_s: float = 2.0
    # Full mechanism description; when set it wins over the legacy
    # ckpt_interval_s/ckpt_async/num_shards trio above.
    plan: Optional[CheckpointPlan] = None

    def resolved_plan(self) -> CheckpointPlan:
        if self.plan is not None:
            return self.plan
        return CheckpointPlan(interval_s=self.ckpt_interval_s,
                              sync=not self.ckpt_async,
                              num_shards=self.num_shards)


class ResilientTrainer:
    def __init__(self, model_cfg: ModelConfig, tcfg: TrainerConfig,
                 stream: EventStream, opt_cfg: Optional[OptimizerConfig] = None,
                 seed: int = 0):
        self.cfg = model_cfg
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or OptimizerConfig(total_steps=100_000)
        self.optimizer = make_optimizer(self.opt_cfg)
        self.stream = stream
        self.batcher = StreamingBatcher(stream, tcfg.batch, tcfg.seq_len,
                                        model_cfg.vocab_size, seed=seed)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, tcfg.resolved_plan())
        self.policy = self.ckpt.policy   # the Khaos CI knob lives here
        self.metrics = MetricsStore()
        self.step_fn = jax.jit(zoo.make_train_step(model_cfg, self.optimizer,
                                                   self.opt_cfg))
        params = zoo.init_params(model_cfg, jax.random.PRNGKey(seed))
        self.state = {"params": params, "opt": self.optimizer.init(params),
                      "step": jnp.zeros((), jnp.int32)}
        # AOT-compile the step so jit compilation never counts as virtual
        # job time (the first step would otherwise eat the whole experiment)
        from repro.config import ShapeConfig
        specs = zoo.input_specs(model_cfg,
                                ShapeConfig("warm", "train", tcfg.seq_len,
                                            tcfg.batch))
        state_struct = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state)
        self.step_fn = self.step_fn.lower(state_struct, specs).compile()
        self.t = 0.0                       # virtual clock (seconds)
        self.failure_schedule: list[float] = []
        self.degradation_schedule: list[Degradation] = []
        self.events: list[dict] = []
        self.losses: list[float] = []
        self._measured_step_s: Optional[float] = None
        self._unhealthy_until = -1.0       # post-restore observation grace
        # active gray-failure windows (mirrors the simulator's dynamics on
        # the virtual clock: ft/failures.py "How degradations act")
        self._dg_step_factor = 1.0         # straggler: virtual step time x
        self._dg_step_until = -np.inf
        self._dg_ck_delay = 0.0            # net_delay to_ckpt_store: extra
        self._dg_ck_jitter = 0.0           # blocking seconds per trigger
        self._dg_ck_t0 = 0.0
        self._dg_ck_until = -np.inf
        self._dg_lat_delay = 0.0           # net_delay to_source: latency
        self._dg_lat_jitter = 0.0          # metric penalty
        self._dg_lat_t0 = 0.0
        self._dg_lat_until = -np.inf
        self._dg_bp_until = -np.inf        # backpressure: triggers held
        self._bp_last_slot = -np.inf
        self.bp_suppressed = 0

    # ------------------------------------------------------------------
    def inject_failure_at(self, t: float, kind: str = "node",
                          host: Optional[int] = None) -> None:
        """Schedule a failure.  ``host`` targets a specific simulated
        host: its node-local checkpoint files (primary shards + held
        replicas) die with it, so the restore that follows is the
        degraded-partial path; host=None keeps the legacy process-loss
        semantics (the node's disk survives)."""
        self.failure_schedule.append((t, kind, host))
        self.failure_schedule.sort(key=lambda f: f[0])

    def inject_degradation_at(self, t: float, kind: str, duration_s: float,
                              severity: float = 0.0, jitter_s: float = 0.0,
                              direction: str = "to_source",
                              host: Optional[int] = None) -> None:
        """Schedule a gray failure (``ft.failures.Degradation`` kinds):
        ``straggler`` inflates virtual step time by ``severity`` for the
        window, ``net_delay``/``to_ckpt_store`` adds blocking seconds to
        every checkpoint trigger, ``net_delay``/``to_source`` inflates the
        latency metric, ``backpressure`` holds triggers past their cadence
        slot (the manager's late-save accounting prices the slip).  The
        job never crashes — that is the point."""
        self.degradation_schedule.append(
            Degradation(t, kind, duration_s, severity, jitter_s, direction,
                        host))
        self.degradation_schedule.sort(key=lambda d: d.t)

    def _begin_degradation(self, d: Degradation) -> None:
        until = d.t + d.duration_s
        if d.kind == "straggler":
            self._dg_step_factor = max(d.severity, 1.0)
            self._dg_step_until = until
        elif d.kind == "net_delay" and d.direction == "to_ckpt_store":
            self._dg_ck_delay, self._dg_ck_jitter = d.severity, d.jitter_s
            self._dg_ck_t0, self._dg_ck_until = d.t, until
        elif d.kind == "net_delay":
            self._dg_lat_delay, self._dg_lat_jitter = d.severity, d.jitter_s
            self._dg_lat_t0, self._dg_lat_until = d.t, until
        else:                              # backpressure
            self._dg_bp_until = until
        self.events.append({"t": self.t, "event": "degradation",
                            "kind": d.kind, "direction": d.direction,
                            "host": d.host, "until": until})

    def healthy(self) -> bool:
        """False during the post-failure grace window, while latency/lag
        samples reflect the recovery rather than the (CI, TR) -> L mapping
        the controller's models were fitted on."""
        return self.t >= self._unhealthy_until

    def set_ci(self, interval_s: float) -> None:
        """Hot CI change (the Khaos actuation; no restart needed here).
        The manager's plan follows so ``current_plan().interval_s`` and
        ``current_ci()`` never disagree."""
        self.policy.set_interval(interval_s, self.t)
        self.ckpt.plan = cfg_replace(self.ckpt.plan, interval_s=interval_s)
        self.events.append({"t": self.t, "event": "reconfigure",
                            "ci": interval_s})

    def drain(self) -> float:
        """Checkpoint-now barrier: quiesce any in-flight async commit, then
        write a cadence-exempt FULL savepoint of state + cursor to every
        configured level (``CheckpointManager.savepoint`` — a regular
        cadence-gated trigger could land memory-only or skip disk levels
        entirely under every-Nth routing).  After drain() returns, nothing
        the job has processed can be lost by a mechanism switch.  Returns
        the blocking seconds (also charged to the virtual clock)."""
        extra = {"pipeline": self.batcher.state_dict(), "t": self.t}
        step = int(self.state["step"])
        report = self.ckpt.savepoint(step, self.state, self.t, extra)
        self.events.append({"t": self.t, "event": "checkpoint", "step": step,
                            "kind": "savepoint",
                            "levels": list(report.levels)})
        self.t += report.blocking_s * self.tcfg.time_scale
        return report.blocking_s

    def set_plan(self, plan: CheckpointPlan) -> None:
        """Controlled mechanism switch — the live ``reconfigure_plan``
        actuation (mirrors ``SimJobHandle.reconfigure_plan``'s savepoint +
        restart): drain under the old plan, rebuild the checkpoint plane
        from ``plan``, and resume on the SAME policy clock and metrics
        store.  Checkpoints already on disk remain restorable (the store
        format is plan-independent and the level subdirectories are
        shared), and the drained in-RAM snapshot + delta base carry over
        into the rebuilt manager, so a failure right after the switch
        still recovers the savepoint."""
        old = self.ckpt
        self.drain()
        self.policy.set_interval(plan.interval_s, self.t)
        # rebuild: fresh manager, same policy object -> cadence continuity
        # (the drain's policy.mark anchors the next trigger), same metrics
        # store -> the controller's observation windows span the switch.
        # the manager (not tcfg) is the plan's source of truth after init:
        # mutating the caller-owned TrainerConfig would leak one run's
        # actuations into other trainers built from the same config
        self.ckpt = CheckpointManager(self.tcfg.ckpt_dir, plan,
                                      policy=self.policy)
        self.ckpt.adopt_runtime_state(old)
        self.events.append({"t": self.t, "event": "set_plan",
                            "plan": plan.name, "ci": plan.interval_s})

    # ------------------------------------------------------------------
    def _checkpoint(self) -> float:
        """Run one checkpoint trigger; returns the blocking duration."""
        extra = {"pipeline": self.batcher.state_dict(), "t": self.t}
        step = int(self.state["step"])
        report = self.ckpt.save(step, self.state, self.t, extra)
        self.events.append({"t": self.t, "event": "checkpoint", "step": step,
                            "kind": report.kind,
                            "levels": list(report.levels)})
        return report.blocking_s

    def _restore(self, failure_kind: str = "node",
                 host: Optional[int] = None) -> None:
        self.ckpt.on_failure(failure_kind, host=host)
        # samples taken while catching up after the rollback reflect the
        # failure, not steady state — hold healthy() low for a grace window
        self._unhealthy_until = self.t + self.tcfg.detect_s + self.tcfg.restart_s
        try:
            report = self.ckpt.restore(self.state, failure_kind)
        except FileNotFoundError:
            self.events.append({"t": self.t, "event": "restore_fresh"})
            return
        self.state = jax.tree_util.tree_map(jnp.asarray, report.state)
        self.batcher.restore(report.extra["pipeline"])
        self.events.append({"t": self.t, "event": "restore",
                            "step": report.step, "level": report.level,
                            "kind": report.kind,
                            "degraded": report.degraded,
                            "restored_bytes": report.restored_bytes})

    # ------------------------------------------------------------------
    def run(self, duration_s: float,
            on_second: Optional[Callable[[dict], None]] = None) -> dict:
        """Run the resilient loop for ``duration_s`` virtual seconds."""
        t_end = self.t + duration_s
        next_metric_t = self.t
        while self.t < t_end:
            try:
                self._run_until_failure(t_end, on_second)
                break
            except InjectedFailure as failure:
                self.events.append({"t": self.t, "event": "failure",
                                    "kind": failure.kind,
                                    "host": failure.host})
                # downtime: detection + restart; lag accrues on the stream
                self.t += self.tcfg.detect_s + self.tcfg.restart_s
                self.stream.produce_until(self.t)
                self._restore(failure.kind, failure.host)
        return self.summary()

    def _run_until_failure(self, t_end: float, on_second) -> None:
        while self.t < t_end:
            if self.failure_schedule and self.t >= self.failure_schedule[0][0]:
                _, kind, host = self.failure_schedule.pop(0)
                raise InjectedFailure(kind=kind, host=host, t=self.t)
            while (self.degradation_schedule
                   and self.t >= self.degradation_schedule[0].t):
                self._begin_degradation(self.degradation_schedule.pop(0))
            if self.t >= self._dg_step_until:
                self._dg_step_factor = 1.0
            self.stream.produce_until(self.t)
            if self.policy.due(self.t):
                if self.t < self._dg_bp_until:
                    # backpressure: the barrier can't complete — hold the
                    # trigger, counting each missed cadence slot once
                    slot = self.policy.next_due(self.t)
                    if slot != self._bp_last_slot:
                        self._bp_last_slot = slot
                        self.bp_suppressed += 1
                        self.events.append({"t": self.t,
                                            "event": "backpressure_skip"})
                else:
                    # only the blocking part (sync write, or async snapshot)
                    # advances the virtual job clock
                    blocking = self._checkpoint()
                    if self.t < self._dg_ck_until:
                        blocking += self._dg_ck_delay + self._dg_ck_jitter \
                            * float(jitter_phase(self.t, self._dg_ck_t0))
                    self.t += blocking * self.tcfg.time_scale
            batch = self.batcher.next_batch()
            if batch is None:
                self.t += 0.05        # idle: stream underrun
                continue
            w0 = time.monotonic()
            bt = {"tokens": jnp.asarray(batch["tokens"]),
                  "labels": jnp.asarray(batch["labels"])}
            self.state, metrics = self.step_fn(self.state, bt)
            loss = float(metrics["loss"])
            wall = time.monotonic() - w0
            self._measured_step_s = wall
            # a straggler window inflates the virtual step time — the job
            # runs slower without any failure event firing (gray, not dead)
            step_s = wall * self._dg_step_factor
            self.t += step_s * self.tcfg.time_scale
            self.losses.append(loss)
            self.metrics.record("loss", self.t, loss)
            self.metrics.record("step_time", self.t, step_s)
            self.metrics.record("consumer_lag", self.t, self.stream.lag)
            self.metrics.record("arrival_rate", self.t,
                                self.stream.rate_at(self.t))
            lat = self.stream.lag / max(self.tcfg.batch / max(step_s * self.tcfg.time_scale, 1e-6), 1e-9)
            if self.t < self._dg_lat_until:
                lat += self._dg_lat_delay + self._dg_lat_jitter \
                    * float(jitter_phase(self.t, self._dg_lat_t0))
            self.metrics.record("latency", self.t, lat)
            if on_second is not None:
                on_second({"t": self.t, "loss": loss, "lag": self.stream.lag})

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        self.ckpt.wait()
        return {
            "final_step": int(self.state["step"]),
            "final_loss": self.losses[-1] if self.losses else float("nan"),
            "events": self.events,
            "checkpoints": sum(1 for e in self.events if e["event"] == "checkpoint"),
            "failures": sum(1 for e in self.events if e["event"] == "failure"),
            "restores": sum(1 for e in self.events if e["event"] == "restore"),
            "degradations": sum(1 for e in self.events
                                if e["event"] == "degradation"),
            "bp_suppressed": self.bp_suppressed,
            "plan_switches": sum(1 for e in self.events if e["event"] == "set_plan"),
            "measured_step_s": self._measured_step_s,
            "ckpt_stats": self.ckpt.stats(),
        }


# ---------------------------------------------------------------------------
# JobHandle adapter for the Khaos controller (Phase 3, live substrate)
# ---------------------------------------------------------------------------

class TrainerJobHandle:
    """``core.controller.JobHandle`` over the live ``ResilientTrainer`` —
    the full protocol, interchangeable with ``sim.SimJobHandle`` under
    ``KhaosController``/``KhaosRuntime``.  ``reconfigure_plan`` is the
    real actuation: drain (checkpoint-now), manager rebuild from the new
    plan, metrics-window continuity."""

    def __init__(self, trainer: ResilientTrainer):
        self.tr = trainer
        self.reconfigurations: list[tuple[float, float]] = []
        self.plan_changes: list[tuple[float, str]] = []

    def now(self) -> float:
        return self.tr.t

    def current_ci(self) -> float:
        return self.tr.policy.interval_s

    def current_plan(self) -> CheckpointPlan:
        return self.tr.ckpt.plan

    def avg_latency(self, window_s: float) -> float:
        return self.tr.metrics.series("latency").mean_over(
            self.tr.t - window_s, self.tr.t)

    def avg_throughput(self, window_s: float) -> float:
        """Trailing-window mean of the arrival rate (the TR the QoS models
        were fitted on), falling back to the instantaneous rate before the
        first step lands a sample."""
        tr_avg = self.tr.metrics.series("arrival_rate").mean_over(
            self.tr.t - window_s, self.tr.t)
        if np.isnan(tr_avg):
            return self.tr.stream.rate_at(self.tr.t)
        return tr_avg

    def healthy(self) -> bool:
        return self.tr.healthy()

    def drain(self) -> None:
        self.tr.drain()

    def reconfigure(self, new_ci: float) -> None:
        """Hot CI swap — no restart on this substrate (DESIGN.md §7.1)."""
        self.reconfigurations.append((self.tr.t, new_ci))
        self.tr.set_ci(new_ci)

    def reconfigure_plan(self, plan: CheckpointPlan) -> None:
        """Mechanism switch: drain + manager rebuild applies mode + CI."""
        self.reconfigurations.append((self.tr.t, plan.interval_s))
        self.plan_changes.append((self.tr.t, plan.name))
        self.tr.set_plan(plan)
