"""Live resilient trainer: the real-JAX data plane the Khaos control plane
supervises.

Wires together: streaming batcher (consumer-lag semantics) -> jit'd
train_step -> the unified checkpoint plane (one ``CheckpointManager``
executing a ``CheckpointPlan``: full or delta encoding, memory/local/remote
level routing, sync or async commit — atomically committed WITH the stream
cursor for exactly-once) -> failure injection + failure-kind-aware restore
-> metrics -> optional Khaos controller.

Time: the trainer runs on a *virtual clock* driven by measured step wall
times (scaled by ``time_scale``), so a 2-hour streaming experiment runs in
seconds on CPU while keeping real step/checkpoint costs in the loop.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import CheckpointPlan, ModelConfig, OptimizerConfig
from repro.data.pipeline import StreamingBatcher
from repro.data.stream import EventStream
from repro.ft.failures import InjectedFailure
from repro.metrics import MetricsStore
from repro.models import zoo
from repro.optim import make_optimizer


@dataclass
class TrainerConfig:
    batch: int = 8
    seq_len: int = 64
    ckpt_dir: str = "/tmp/repro_trainer"
    ckpt_interval_s: float = 30.0
    ckpt_async: bool = False
    num_shards: int = 2
    time_scale: float = 1.0        # virtual seconds per wall second of compute
    detect_s: float = 5.0          # simulated detection timeout after a crash
    restart_s: float = 2.0
    # Full mechanism description; when set it wins over the legacy
    # ckpt_interval_s/ckpt_async/num_shards trio above.
    plan: Optional[CheckpointPlan] = None

    def resolved_plan(self) -> CheckpointPlan:
        if self.plan is not None:
            return self.plan
        return CheckpointPlan(interval_s=self.ckpt_interval_s,
                              sync=not self.ckpt_async,
                              num_shards=self.num_shards)


class ResilientTrainer:
    def __init__(self, model_cfg: ModelConfig, tcfg: TrainerConfig,
                 stream: EventStream, opt_cfg: Optional[OptimizerConfig] = None,
                 seed: int = 0):
        self.cfg = model_cfg
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg or OptimizerConfig(total_steps=100_000)
        self.optimizer = make_optimizer(self.opt_cfg)
        self.stream = stream
        self.batcher = StreamingBatcher(stream, tcfg.batch, tcfg.seq_len,
                                        model_cfg.vocab_size, seed=seed)
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, tcfg.resolved_plan())
        self.policy = self.ckpt.policy   # the Khaos CI knob lives here
        self.metrics = MetricsStore()
        self.step_fn = jax.jit(zoo.make_train_step(model_cfg, self.optimizer,
                                                   self.opt_cfg))
        params = zoo.init_params(model_cfg, jax.random.PRNGKey(seed))
        self.state = {"params": params, "opt": self.optimizer.init(params),
                      "step": jnp.zeros((), jnp.int32)}
        # AOT-compile the step so jit compilation never counts as virtual
        # job time (the first step would otherwise eat the whole experiment)
        from repro.config import ShapeConfig
        specs = zoo.input_specs(model_cfg,
                                ShapeConfig("warm", "train", tcfg.seq_len,
                                            tcfg.batch))
        state_struct = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), self.state)
        self.step_fn = self.step_fn.lower(state_struct, specs).compile()
        self.t = 0.0                       # virtual clock (seconds)
        self.failure_schedule: list[float] = []
        self.events: list[dict] = []
        self.losses: list[float] = []
        self._measured_step_s: Optional[float] = None

    # ------------------------------------------------------------------
    def inject_failure_at(self, t: float, kind: str = "node") -> None:
        self.failure_schedule.append((t, kind))
        self.failure_schedule.sort()

    def set_ci(self, interval_s: float) -> None:
        """Hot CI change (the Khaos actuation; no restart needed here)."""
        self.policy.set_interval(interval_s, self.t)
        self.events.append({"t": self.t, "event": "reconfigure",
                            "ci": interval_s})

    # ------------------------------------------------------------------
    def _checkpoint(self) -> float:
        """Run one checkpoint trigger; returns the blocking duration."""
        extra = {"pipeline": self.batcher.state_dict(), "t": self.t}
        step = int(self.state["step"])
        report = self.ckpt.save(step, self.state, self.t, extra)
        self.events.append({"t": self.t, "event": "checkpoint", "step": step,
                            "kind": report.kind,
                            "levels": list(report.levels)})
        return report.blocking_s

    def _restore(self, failure_kind: str = "node") -> None:
        self.ckpt.on_failure(failure_kind)
        try:
            report = self.ckpt.restore(self.state, failure_kind)
        except FileNotFoundError:
            self.events.append({"t": self.t, "event": "restore_fresh"})
            return
        self.state = jax.tree_util.tree_map(jnp.asarray, report.state)
        self.batcher.restore(report.extra["pipeline"])
        self.events.append({"t": self.t, "event": "restore",
                            "step": report.step, "level": report.level,
                            "kind": report.kind})

    # ------------------------------------------------------------------
    def run(self, duration_s: float,
            on_second: Optional[Callable[[dict], None]] = None) -> dict:
        """Run the resilient loop for ``duration_s`` virtual seconds."""
        t_end = self.t + duration_s
        next_metric_t = self.t
        while self.t < t_end:
            try:
                self._run_until_failure(t_end, on_second)
                break
            except InjectedFailure as failure:
                self.events.append({"t": self.t, "event": "failure",
                                    "kind": failure.kind})
                # downtime: detection + restart; lag accrues on the stream
                self.t += self.tcfg.detect_s + self.tcfg.restart_s
                self.stream.produce_until(self.t)
                self._restore(failure.kind)
        return self.summary()

    def _run_until_failure(self, t_end: float, on_second) -> None:
        while self.t < t_end:
            if self.failure_schedule and self.t >= self.failure_schedule[0][0]:
                _, kind = self.failure_schedule.pop(0)
                raise InjectedFailure(kind=kind, t=self.t)
            self.stream.produce_until(self.t)
            if self.policy.due(self.t):
                # only the blocking part (sync write, or async snapshot)
                # advances the virtual job clock
                self.t += self._checkpoint() * self.tcfg.time_scale
            batch = self.batcher.next_batch()
            if batch is None:
                self.t += 0.05        # idle: stream underrun
                continue
            w0 = time.monotonic()
            bt = {"tokens": jnp.asarray(batch["tokens"]),
                  "labels": jnp.asarray(batch["labels"])}
            self.state, metrics = self.step_fn(self.state, bt)
            loss = float(metrics["loss"])
            wall = time.monotonic() - w0
            self._measured_step_s = wall
            self.t += wall * self.tcfg.time_scale
            self.losses.append(loss)
            self.metrics.record("loss", self.t, loss)
            self.metrics.record("step_time", self.t, wall)
            self.metrics.record("consumer_lag", self.t, self.stream.lag)
            lat = self.stream.lag / max(self.tcfg.batch / max(wall * self.tcfg.time_scale, 1e-6), 1e-9)
            self.metrics.record("latency", self.t, lat)
            if on_second is not None:
                on_second({"t": self.t, "loss": loss, "lag": self.stream.lag})

    # ------------------------------------------------------------------
    def summary(self) -> dict:
        self.ckpt.wait()
        return {
            "final_step": int(self.state["step"]),
            "final_loss": self.losses[-1] if self.losses else float("nan"),
            "events": self.events,
            "checkpoints": sum(1 for e in self.events if e["event"] == "checkpoint"),
            "failures": sum(1 for e in self.events if e["event"] == "failure"),
            "restores": sum(1 for e in self.events if e["event"] == "restore"),
            "measured_step_s": self._measured_step_s,
            "ckpt_stats": self.ckpt.stats(),
        }
