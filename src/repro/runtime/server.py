"""Batched streaming inference server: prefill + decode loop over request
batches pulled from an event stream, with per-request latency metrics.

The serving path shares the model zoo's prefill/decode step factories (the
same ones the dry-run lowers at production shapes)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.metrics import MetricsStore
from repro.models import zoo


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 8


class StreamServer:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_seq: int = 128, metrics_maxlen: int = 4096):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.prefill = jax.jit(zoo.make_prefill_step(cfg))
        self.decode = jax.jit(zoo.make_decode_step(cfg))
        # bounded: a long-lived server must not grow per-batch series forever
        self.metrics = MetricsStore(maxlen=metrics_maxlen)
        self._t = 0.0
        self.last_decode_positions: list[int] = []

    def _grow_caches(self, caches, extra: int):
        """Extend full-attention K/V caches (dense/moe/vlm: stacked
        (L, B, S, H, hd) with the seq axis at 2) by ``extra`` slots so
        decode steps have somewhere to write.  Ring (hybrid window) and
        state (ssm) caches are fixed-size by design and pass through."""
        if extra <= 0 or not (isinstance(caches, dict) and "k" in caches):
            return caches
        pad = [(0, 0), (0, 0), (0, extra), (0, 0), (0, 0)]
        return {"k": jnp.pad(caches["k"], pad),
                "v": jnp.pad(caches["v"], pad)}

    def serve_batch(self, requests: list[ServeRequest]) -> dict[int, np.ndarray]:
        """Prefill a batch of equal-length prompts, then decode greedily."""
        assert 0 < len(requests) <= self.max_batch
        S = len(requests[0].prompt)
        assert all(len(r.prompt) == S for r in requests), "bucket by length"
        max_new = max(r.max_new_tokens for r in requests)
        assert S + max_new <= self.max_seq, \
            f"prompt ({S}) + generation ({max_new}) exceeds max_seq " \
            f"({self.max_seq})"
        tokens = jnp.asarray(np.stack([r.prompt for r in requests]))
        next_tok, caches = self.prefill(self.params, {"tokens": tokens})
        # prefill caches hold exactly S positions; generated tokens land at
        # S, S+1, ... — grow the caches up front (an out-of-range scatter
        # would be silently DROPPED by JAX, so without room every step
        # would stomp one slot and decode against a stale window)
        caches = self._grow_caches(caches, max_new - 1)
        outs = [ [int(t)] for t in np.asarray(next_tok) ]
        cur = next_tok[:, None]
        self.last_decode_positions = []
        for i in range(max_new - 1):
            # step i writes the token generated at position S + i and
            # rotates its query to that absolute position
            p = S + i
            self.last_decode_positions.append(p)
            pos = jnp.full((len(requests),), p, jnp.int32)
            cur, caches = self.decode(self.params, caches,
                                      {"tokens": cur, "pos": pos})
            for b, t in enumerate(np.asarray(cur)[:, 0]):
                outs[b].append(int(t))
        self.metrics.record("served", self._t, len(requests))
        self._t += 1.0
        return {r.rid: np.array(o[: r.max_new_tokens])
                for r, o in zip(requests, outs)}
