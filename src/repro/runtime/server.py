"""Batched streaming inference server: prefill + decode loop over request
batches pulled from an event stream, with per-request latency metrics.

The serving path shares the model zoo's prefill/decode step factories (the
same ones the dry-run lowers at production shapes)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.metrics import MetricsStore
from repro.models import zoo


@dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 8


class StreamServer:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_seq: int = 128):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.prefill = jax.jit(zoo.make_prefill_step(cfg))
        self.decode = jax.jit(zoo.make_decode_step(cfg))
        self.metrics = MetricsStore()
        self._t = 0.0

    def serve_batch(self, requests: list[ServeRequest]) -> dict[int, np.ndarray]:
        """Prefill a batch of equal-length prompts, then decode greedily."""
        assert 0 < len(requests) <= self.max_batch
        S = len(requests[0].prompt)
        assert all(len(r.prompt) == S for r in requests), "bucket by length"
        tokens = jnp.asarray(np.stack([r.prompt for r in requests]))
        next_tok, caches = self.prefill(self.params, {"tokens": tokens})
        # decode caches sized S; continue writing into ring position
        outs = [ [int(t)] for t in np.asarray(next_tok) ]
        max_new = max(r.max_new_tokens for r in requests)
        cur = next_tok[:, None]
        for i in range(max_new - 1):
            pos = jnp.full((len(requests),), min(S - 1, S - 1), jnp.int32)
            cur, caches = self.decode(self.params, caches,
                                      {"tokens": cur, "pos": pos})
            for b, t in enumerate(np.asarray(cur)[:, 0]):
                outs[b].append(int(t))
        self.metrics.record("served", self._t, len(requests))
        self._t += 1.0
        return {r.rid: np.array(o[: r.max_new_tokens])
                for r, o in zip(requests, outs)}
