"""QoS-model transfer: profile fingerprints, the fleet model registry and
the divergence watchdog that guards transferred models.

Phase 2 (chaos profiling) is the expensive step of the Khaos loop — z x m
campaign lanes per job.  In a fleet, many jobs are near-copies of each
other (same state size, similar arrival envelope, same plan search space),
and their fitted M_L / M_R surfaces are interchangeable.  The registry
exploits that: every fitted job files its models under a coarse
``JobFingerprint``; a newly admitted job whose fingerprint matches a
neighbor adopts the neighbor's models (``KhaosRuntime.adopt_models``) and
skips the campaign entirely.

Transfer is a bet, so it ships with its own guard: a
``DivergenceWatchdog`` compares what the adopted M_L predicts against what
the job actually observes once it is optimizing; a sustained relative
error above threshold means the neighbor did NOT describe this job, and
the supervisor falls back to a real ``reprofile()`` (the PR-8 legal Phase-2
re-entry) — the fast path degrades to the cold path, never to a wrong
steady state.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.config import KhaosConfig
from repro.core.qos_models import QoSModel


@dataclass(frozen=True)
class JobFingerprint:
    """Coarse profile identity: two jobs with equal fingerprints are
    assumed to share QoS surfaces (until the watchdog says otherwise).

    * ``state_bytes_log2`` — checkpoint state size, log2-binned: write and
      restore durations scale with state bytes, so recovery surfaces only
      transfer between like-sized jobs;
    * ``rate_mean_bin`` / ``rate_peak_bin`` — the arrival-rate envelope
      (log2-binned mean and peak of the recorded W(t)): the throughput
      range the models were fitted over;
    * ``ci_window`` / ``num_configs`` — the plan search dimensions: models
      fitted over a different CI grid extrapolate instead of interpolate.
    """
    state_bytes_log2: int
    rate_mean_bin: int
    rate_peak_bin: int
    ci_window: tuple
    num_configs: int

    def key(self) -> str:
        return (f"sb{self.state_bytes_log2}-rm{self.rate_mean_bin}"
                f"-rp{self.rate_peak_bin}-ci{self.ci_window[0]:g}"
                f"_{self.ci_window[1]:g}-z{self.num_configs}")


def _log2_bin(x: float) -> int:
    return int(round(math.log2(max(float(x), 1.0))))


def fingerprint(cfg: KhaosConfig, recording, state_bytes: float
                ) -> JobFingerprint:
    """Fingerprint a job from its Khaos config, its Phase-1 recording and
    its checkpoint state size (``SimCostModel.state_bytes`` on the sim
    substrate, the measured snapshot size on the live one)."""
    w = recording.workload(cfg.smoothing_window)
    return JobFingerprint(
        state_bytes_log2=_log2_bin(state_bytes),
        rate_mean_bin=_log2_bin(float(np.mean(w))),
        rate_peak_bin=_log2_bin(float(np.max(w))),
        ci_window=(float(cfg.ci_min), float(cfg.ci_max)),
        num_configs=int(cfg.num_configs))


@dataclass
class RegistryEntry:
    fp: JobFingerprint
    m_l: QoSModel
    m_r: QoSModel
    source_job: str


class QoSModelRegistry:
    """Fleet-wide store of fitted (M_L, M_R) pairs, keyed by fingerprint.

    ``lookup`` is exact-match on the fingerprint key — the bins are coarse
    on purpose (factor-of-two rate/state buckets), so "near-copy" jobs
    collide and genuinely different jobs do not.  Persistence round-trips
    through JSON (``save``/``load``) so a fleet restart keeps its learned
    surfaces.
    """

    def __init__(self) -> None:
        self._entries: dict[str, RegistryEntry] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def put(self, fp: JobFingerprint, m_l: QoSModel, m_r: QoSModel,
            source_job: str) -> None:
        self._entries[fp.key()] = RegistryEntry(fp, m_l, m_r, source_job)

    def lookup(self, fp: JobFingerprint) -> Optional[RegistryEntry]:
        return self._entries.get(fp.key())

    # -- persistence ---------------------------------------------------------
    def to_dict(self) -> dict:
        return {"schema": "qos_registry/1", "entries": [
            {"fingerprint": {"state_bytes_log2": e.fp.state_bytes_log2,
                             "rate_mean_bin": e.fp.rate_mean_bin,
                             "rate_peak_bin": e.fp.rate_peak_bin,
                             "ci_window": list(e.fp.ci_window),
                             "num_configs": e.fp.num_configs},
             "m_l": e.m_l.to_dict(), "m_r": e.m_r.to_dict(),
             "source_job": e.source_job}
            for e in self._entries.values()]}

    @classmethod
    def from_dict(cls, d: dict) -> "QoSModelRegistry":
        assert d.get("schema") == "qos_registry/1", d.get("schema")
        reg = cls()
        for e in d["entries"]:
            f = e["fingerprint"]
            fp = JobFingerprint(int(f["state_bytes_log2"]),
                                int(f["rate_mean_bin"]),
                                int(f["rate_peak_bin"]),
                                tuple(f["ci_window"]),
                                int(f["num_configs"]))
            reg.put(fp, QoSModel.from_dict(e["m_l"]),
                    QoSModel.from_dict(e["m_r"]), e["source_job"])
        return reg

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)

    @classmethod
    def load(cls, path: str) -> "QoSModelRegistry":
        with open(path) as f:
            return cls.from_dict(json.load(f))


@dataclass
class DivergenceWatchdog:
    """Guards a transferred model: sustained relative error between the
    adopted M_L's prediction and the observed latency means the donor's
    surface does not describe this job — time to fall back to a real
    reprofile.  ``observe`` returns True exactly once per divergence
    episode (the supervisor's reprofile trigger)."""
    rel_err_threshold: float = 0.5
    patience: int = 3            # consecutive bad samples before firing
    _streak: int = 0
    _fired: bool = False
    history: list = field(default_factory=list)

    def observe(self, observed: float, predicted: float) -> bool:
        if not (np.isfinite(observed) and np.isfinite(predicted)):
            return False
        rel = abs(observed - predicted) / max(abs(predicted), 1e-9)
        self.history.append(rel)
        if rel > self.rel_err_threshold:
            self._streak += 1
        else:
            self._streak = 0
            self._fired = False
        if self._streak >= self.patience and not self._fired:
            self._fired = True
            return True
        return False

    def reset(self) -> None:
        """Forget the running streak — the supervisor calls this across
        unhealthy windows so a chaos excursion (downtime + backlog
        drain) is not scored as model divergence."""
        self._streak = 0
