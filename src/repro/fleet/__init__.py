"""Fleet supervision: one control plane over many Khaos jobs.

The paper optimizes checkpointing for ONE stream processing job; a real
cluster runs dozens.  This package multiplexes N jobs — each with its own
``KhaosRuntime`` phase machine and QoS constraints — onto one scheduler
tick, one pooled ``BatchedCampaign`` chaos substrate, and one bounded
metrics plane, and adds the two things a fleet enables that a single job
cannot have:

* QoS-model TRANSFER (``registry``): fitted M_L / M_R surfaces are filed
  under coarse profile fingerprints; a new job matching a fitted neighbor
  adopts its models and skips the Phase-2 campaign, guarded by a
  divergence watchdog whose trip wire is a real ``reprofile()``;
* ADMISSION CONTROL (``admission``): jobs reserve fleet capacity, and a
  what-if chaos campaign at the residual capacity rejects (or queues)
  jobs the fleet could run at steady state but not recover.

See ``supervisor`` for the architecture (supervisor/monitor split) and
the admission flow in prose.
"""
from repro.fleet.admission import (AdmissionDecision, decide_admission,
                                   reservation_eps, whatif_campaign)
from repro.fleet.registry import (DivergenceWatchdog, JobFingerprint,
                                  QoSModelRegistry, RegistryEntry,
                                  fingerprint)
from repro.fleet.supervisor import (FleetJob, FleetJobSpec, FleetSupervisor,
                                    lane_violation_seconds)

__all__ = [
    "AdmissionDecision", "decide_admission", "reservation_eps",
    "whatif_campaign",
    "DivergenceWatchdog", "JobFingerprint", "QoSModelRegistry",
    "RegistryEntry", "fingerprint",
    "FleetJob", "FleetJobSpec", "FleetSupervisor",
    "lane_violation_seconds",
]
