"""Admission control: campaign what-if against the fleet's residual
capacity before a job is allowed in.

The fleet owns a finite processing budget (``fleet_capacity_eps``, events
per second across all supervised jobs).  Each admitted job reserves its
peak recorded rate plus headroom; a candidate is admitted only when

1. its reservation fits the residual budget, and
2. a what-if chaos campaign — the candidate's recorded workload replayed
   on a cost model capped at the residual capacity, with a worst-case
   failure injected at the recorded peak — meets the job's own QoS
   constraints (pre-failure latency <= l_const, measured recovery <=
   r_const).

(1) alone would admit a job whose bursts the residual can absorb but
whose post-failure catch-up cannot drain (recovery is where capacity
slack actually matters), so the what-if replays exactly that scenario
through ``sim.BatchedCampaign`` + ``measure_profile_lanes`` — the same
machinery Phase 2 profiling trusts.  Infeasible candidates are rejected
outright, or queued (``queueable=True``) to retry when capacity frees up.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.config import KhaosConfig, replace
from repro.data.stream import WorkloadRecording, dense_rates
from repro.sim.batched import (LaneSpec, make_campaign,
                               measure_profile_lanes)
from repro.sim.costmodel import SimCostModel
from repro.ft.failures import FailureInjector


@dataclass
class AdmissionDecision:
    """The supervisor's verdict on one submitted job."""
    job: str
    action: str                  # admit | admit_transfer | queue | reject
    reason: str
    reserved_eps: float          # reservation this job would take
    residual_eps: float          # fleet budget left BEFORE this job
    whatif_latency_s: float = float("nan")
    whatif_recovery_s: float = float("nan")

    @property
    def admitted(self) -> bool:
        return self.action in ("admit", "admit_transfer")


def reservation_eps(recording: WorkloadRecording,
                    headroom: float = 0.2) -> float:
    """Capacity a job reserves: recorded peak rate plus headroom."""
    return float(np.max(recording.counts)) * (1.0 + headroom)


def whatif_campaign(cost: SimCostModel, recording: WorkloadRecording,
                    cfg: KhaosConfig, residual_eps: float,
                    warmup_s: float = 120.0, margin_s: float = 60.0,
                    max_recovery_s: float = 1800.0,
                    engine: str = "numpy") -> tuple[float, float]:
    """Replay the candidate on the residual capacity with a worst-case
    failure at the recorded peak; returns (pre-failure latency, measured
    recovery) — the numbers the admission gate checks against l_const /
    r_const.  One lane, a few thousand ticks: cheap relative to a wrong
    admit."""
    capped = replace(cost, capacity_eps=float(residual_eps))
    t_peak = float(recording.times[int(np.argmax(recording.counts))])
    t0 = max(float(recording.times[0]), t_peak - margin_s - warmup_s)
    ci = 0.5 * (cfg.ci_min + cfg.ci_max)
    inject_t = FailureInjector().worst_case_time(
        max(t_peak, t0 + margin_s), t0, ci, capped.ckpt_duration_s)
    n = int(np.ceil(inject_t + max_recovery_s - t0))
    lane = LaneSpec(rates=dense_rates(t0, n, recording=recording),
                    ci_s=ci, t0=t0, failures=((inject_t, "node"),),
                    tag={"whatif": True})
    camp = make_campaign(capped, [lane], engine=engine).run()
    msr = measure_profile_lanes(camp, [inject_t], margin_s,
                                max_recovery_s)[0]
    return msr.latency_s, msr.recovery_s


def decide_admission(job: str, cost: SimCostModel,
                     recording: WorkloadRecording, cfg: KhaosConfig,
                     residual_eps: float, headroom: float = 0.2,
                     queueable: bool = False, transfer_hit: bool = False,
                     engine: str = "numpy") -> AdmissionDecision:
    """The full admission gate (budget fit, then the what-if campaign)."""
    need = reservation_eps(recording, headroom)
    if need > residual_eps:
        action = "queue" if queueable else "reject"
        return AdmissionDecision(
            job, action,
            f"reservation {need:.0f} ev/s exceeds residual "
            f"{residual_eps:.0f} ev/s", need, residual_eps)
    lat, rec = whatif_campaign(cost, recording, cfg, residual_eps,
                               engine=engine)
    if lat > cfg.latency_constraint or rec > cfg.recovery_constraint:
        action = "queue" if queueable else "reject"
        return AdmissionDecision(
            job, action,
            f"what-if at residual capacity violates QoS "
            f"(latency {lat:.2f}s vs {cfg.latency_constraint:.2f}s, "
            f"recovery {rec:.0f}s vs {cfg.recovery_constraint:.0f}s)",
            need, residual_eps, lat, rec)
    return AdmissionDecision(
        job, "admit_transfer" if transfer_hit else "admit",
        "fits residual capacity; what-if meets QoS",
        need, residual_eps, lat, rec)
