"""``FleetSupervisor`` — one control plane over many Khaos jobs.

Architecture (the supervisor / monitor split)
---------------------------------------------

One process, two planes:

* the SUPERVISOR plane owns the control loop: one ``KhaosRuntime`` phase
  machine PER JOB (each job walks idle -> steady_state -> profiled ->
  optimizing on its own legality rules), but ONE scheduler tick driving
  them all, ONE pooled ``BatchedCampaign`` substrate for every
  lane-backed job, and ONE shared decision log every controller appends
  to (``KhaosRuntime.attach_decision_log``).  Heterogeneous substrates
  multiplex onto the same tick: lane jobs advance with the pooled
  campaign, scalar jobs (``StreamSimulator``/``SimJobHandle``) and
  external handles (e.g. ``TrainerJobHandle`` + a ticker callable)
  advance alongside, and every job's controller is polled at each chunk
  boundary.

* the MONITOR plane owns observation: a bounded ``MetricsStore`` (ring
  buffer + rollup-on-eviction, so supervising many jobs for days holds
  memory flat) with per-job series (``<job>/latency``,
  ``<job>/throughput``) and per-fleet rollups (``fleet/latency``,
  ``fleet/jobs_optimizing``), plus per-job ``DivergenceWatchdog``s
  guarding transferred QoS models.

Admission flow (in prose)
-------------------------

A submitted job is recorded first (Phase 1 runs unconditionally — the
steady state and failure points are always the job's own).  Its profile
fingerprint (state bytes, arrival-rate envelope, plan dimensions) is
looked up in the ``QoSModelRegistry``.  Admission then gates on fleet
capacity: the job's reservation (peak rate + headroom) must fit the
residual budget, and a what-if chaos campaign — the job's workload
replayed at the residual capacity with a worst-case failure at the
recorded peak — must meet the job's own QoS constraints.  Infeasible
jobs are rejected (or queued, to retry when capacity frees).  Admitted
jobs with a registry hit run a one-lane validation probe; if the donor
models predict the probe within tolerance the job ADOPTS them
(``KhaosRuntime.adopt_models`` — the steady_state -> profiled fast path,
no campaign) and is armed with a divergence watchdog whose trip wire is
a real ``reprofile()``.  Admitted jobs without a hit (or whose probe
fails) stay cold: their z x m profiling grids are POOLED — all cold
jobs' lanes concatenated into one ``BatchedCampaign`` sweep
(``run_profiling_pooled``), measurements scattered back per job, models
fitted per job and filed in the registry for the next neighbor.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.config import CheckpointPlan, KhaosConfig
from repro.core.runtime import KhaosRuntime
from repro.data.stream import (RateSchedule, WorkloadRecording, dense_rates,
                               record_workload)
from repro.fleet.admission import AdmissionDecision, decide_admission
from repro.fleet.registry import (DivergenceWatchdog, JobFingerprint,
                                  QoSModelRegistry, fingerprint)
from repro.ft.failures import FailureInjector
from repro.metrics import MetricsStore
from repro.sim.batched import (BatchedCampaign, BatchedDeployment,
                               BatchedLaneHandle, LaneSpec,
                               build_profile_lanes, make_campaign,
                               measure_profile_lanes,
                               scatter_profile_results)
from repro.sim.costmodel import SimCostModel
from repro.sim.simulator import SimJobHandle, StreamSimulator


@dataclass
class FleetJobSpec:
    """Everything the supervisor needs to admit and drive one job."""
    name: str
    cost: SimCostModel
    cfg: KhaosConfig
    schedule: Optional[RateSchedule] = None
    recording: Optional[WorkloadRecording] = None   # pre-recorded Phase 1
    substrate: str = "lane"          # lane | scalar | handle
    handle: Any = None               # substrate="handle": external JobHandle
    ticker: Optional[Callable[[float], None]] = None  # advance handle by dt
    horizon_s: float = 1800.0        # Phase-3 supervision horizon
    failures: Sequence[tuple] = ()   # (t, kind) chaos during supervision
    plan_variants: Optional[list] = None
    queueable: bool = False
    seed: int = 0
    profile_warmup_s: float = 120.0
    profile_max_recovery_s: float = 1800.0

    def __post_init__(self) -> None:
        assert self.substrate in ("lane", "scalar", "handle"), self.substrate
        if self.substrate == "handle":
            assert self.handle is not None, "substrate='handle' needs one"
        else:
            assert self.schedule is not None or self.recording is not None, \
                f"job {self.name!r} needs a schedule or a recording"


@dataclass
class FleetJob:
    """Supervisor-side state of one submitted job."""
    spec: FleetJobSpec
    status: str                          # rejected|queued|admitted|optimizing|done
    admission: AdmissionDecision
    recording: Optional[WorkloadRecording] = None
    fp: Optional[JobFingerprint] = None
    runtime: Optional[KhaosRuntime] = None
    handle: Any = None
    sim: Optional[StreamSimulator] = None    # scalar substrate
    lane: Optional[int] = None               # lane substrate: pooled index
    campaign: Optional[BatchedCampaign] = None
    transferred: bool = False
    transfer_source: Optional[str] = None
    watchdog: Optional[DivergenceWatchdog] = None
    profiling_lane_ticks: int = 0        # substrate ticks spent on Phase 2
    reprofiles: int = 0

    @property
    def name(self) -> str:
        return self.spec.name


class _PrecomputedCampaign:
    """``core.profiler.CampaignDeployment`` over measurements that already
    happened — the adapter that lets ``KhaosRuntime.run_profiling`` (and
    its phase-legality bookkeeping) consume one job's slice of the POOLED
    multi-job campaign instead of running its own."""

    def __init__(self, L: np.ndarray, R: np.ndarray):
        self._L, self._R = L, R

    def profile_campaign(self, failure_times, ci_values, margin: float
                         ) -> tuple[np.ndarray, np.ndarray]:
        assert self._L.shape == (len(failure_times), len(ci_values)), \
            (self._L.shape, len(failure_times), len(ci_values))
        return self._L, self._R


def lane_violation_seconds(camp: BatchedCampaign, lane: int, l_const: float,
                           r_const: float) -> dict:
    """QoS-violation seconds for one supervised lane: recovery excess over
    r_const plus the count of ticks whose latency exceeded l_const (the
    bench_proactive scoring, shared here for fleet twins)."""
    recs = [r["recovery_s"] for r in camp.recoveries[lane]]
    rec_viol = float(sum(max(0.0, r - r_const) for r in recs))
    ts = camp.times(lane)
    lat = camp.latency_history()[lane, :len(ts)]
    lat_viol = float(np.sum(lat > l_const))
    return {"recovery_violation_s": rec_viol,
            "latency_violation_s": lat_viol,
            "qos_violation_s": rec_viol + lat_viol}


def _cost_key(cost: SimCostModel) -> tuple:
    """Hashable identity of a cost model (campaigns share one cost model,
    so pooling groups lanes by cost-model value)."""
    return tuple(sorted((k, str(v)) for k, v in
                        dataclasses.asdict(cost).items()))


class FleetSupervisor:
    """One control plane over N jobs: admission, QoS-model transfer,
    pooled profiling, and a single multiplexed Phase-3 tick.

    ``fleet_capacity_eps`` is the total processing budget (events/s)
    admission reserves against.  ``registry`` carries fitted QoS surfaces
    across jobs (and, via save/load, across fleet restarts).
    """

    def __init__(self, fleet_capacity_eps: float,
                 registry: Optional[QoSModelRegistry] = None,
                 headroom: float = 0.2,
                 probe_tolerance: float = 0.75,
                 divergence_threshold: float = 0.5,
                 divergence_patience: int = 3,
                 metrics_maxlen: Optional[int] = 512,
                 engine: str = "numpy"):
        self.fleet_capacity_eps = float(fleet_capacity_eps)
        self.registry = registry if registry is not None else QoSModelRegistry()
        self.engine = engine                  # campaign engine for what-ifs
        self.headroom = headroom
        self.probe_tolerance = probe_tolerance
        self.divergence_threshold = divergence_threshold
        self.divergence_patience = divergence_patience
        self.jobs: dict[str, FleetJob] = {}
        self.decision_log: list = []          # (job, Decision) shared audit
        self.metrics = MetricsStore(maxlen=metrics_maxlen)
        self.reserved_eps = 0.0
        self.t = 0.0                          # fleet clock (Phase-3 seconds)
        self._campaigns: dict[tuple, BatchedCampaign] = {}
        self._started = False

    # -- capacity ------------------------------------------------------------
    @property
    def residual_eps(self) -> float:
        return self.fleet_capacity_eps - self.reserved_eps

    # -- admission (Phase 1 + gate + transfer fast path) ---------------------
    def submit(self, spec: FleetJobSpec) -> AdmissionDecision:
        assert spec.name not in self.jobs, f"duplicate job {spec.name!r}"
        recording = spec.recording if spec.recording is not None else \
            record_workload(spec.schedule, duration=spec.cfg.record_seconds,
                            seed=spec.seed)
        fp = fingerprint(spec.cfg, recording, spec.cost.state_bytes)
        dec = decide_admission(spec.name, spec.cost, recording, spec.cfg,
                               self.residual_eps, headroom=self.headroom,
                               queueable=spec.queueable, engine=self.engine)
        if not dec.admitted:
            status = "queued" if dec.action == "queue" else "rejected"
            self.jobs[spec.name] = FleetJob(spec, status, dec,
                                            recording=recording, fp=fp)
            return dec

        rt = KhaosRuntime(spec.cfg, cost=spec.cost,
                          plan_variants=spec.plan_variants)
        rt.attach_decision_log(self.decision_log, spec.name)
        rt.record_steady_state(recording)
        job = FleetJob(spec, "admitted", dec, recording=recording, fp=fp,
                       runtime=rt)
        self.jobs[spec.name] = job
        self.reserved_eps += dec.reserved_eps

        entry = self.registry.lookup(fp)
        if entry is not None and self._transfer_probe(job, entry):
            rt.adopt_models(entry.m_l, entry.m_r, source=entry.source_job)
            job.transferred = True
            job.transfer_source = entry.source_job
            job.watchdog = DivergenceWatchdog(
                rel_err_threshold=self.divergence_threshold,
                patience=self.divergence_patience)
            dec = dataclasses.replace(dec, action="admit_transfer",
                                      reason=dec.reason +
                                      f"; QoS models adopted from "
                                      f"{entry.source_job!r}")
            job.admission = dec
        # either way, arm the reprofiling fallback (divergence watchdog /
        # anomaly rung) with the job's own chaos substrate
        rt.enable_reprofiling(
            BatchedDeployment(spec.cost, recording,
                              warmup_s=spec.profile_warmup_s,
                              max_recovery_s=spec.profile_max_recovery_s))
        return dec

    def _transfer_probe(self, job: FleetJob, entry) -> bool:
        """Validate a registry hit with ONE lane before adopting: replay
        the worst-case injection at the recorded peak and require the
        donor models to predict the measured latency and recovery within
        ``probe_tolerance`` relative error.  The probe's ticks are the
        transfer job's entire Phase-2 bill (vs the cold z x m grid)."""
        spec, rec = job.spec, job.recording
        cfg, cost = spec.cfg, spec.cost
        margin = cfg.profile_margin_seconds
        t_peak = float(rec.times[int(np.argmax(rec.counts))])
        t0 = max(float(rec.times[0]),
                 t_peak - margin - spec.profile_warmup_s)
        ci = 0.5 * (cfg.ci_min + cfg.ci_max)
        inject_t = FailureInjector().worst_case_time(
            max(t_peak, t0 + margin), t0, ci, cost.ckpt_duration_s)
        n = int(np.ceil(inject_t + spec.profile_max_recovery_s - t0))
        lane = LaneSpec(rates=dense_rates(t0, n, recording=rec), ci_s=ci,
                        t0=t0, failures=((inject_t, "node"),),
                        tag={"job": job.name, "probe": True})
        camp = make_campaign(cost, [lane], engine=self.engine).run()
        msr = measure_profile_lanes(camp, [inject_t], margin,
                                    spec.profile_max_recovery_s)[0]
        job.profiling_lane_ticks += n
        tr = float(rec.counts.max())
        pred_l = float(entry.m_l.predict(np.array([ci]), np.array([tr]))[0])
        pred_r = float(entry.m_r.predict(np.array([ci]), np.array([tr]))[0])
        err_l = abs(msr.latency_s - pred_l) / max(abs(msr.latency_s), 1e-9)
        err_r = abs(msr.recovery_s - pred_r) / max(abs(msr.recovery_s), 1e-9)
        return err_l <= self.probe_tolerance and err_r <= self.probe_tolerance

    def retry_queued(self) -> list[AdmissionDecision]:
        """Re-run admission for queued jobs against the current residual
        (call after capacity frees up, e.g. a job finished)."""
        out = []
        for name, job in list(self.jobs.items()):
            if job.status != "queued":
                continue
            del self.jobs[name]
            out.append(self.submit(job.spec))
        return out

    # -- Phase 2, pooled ------------------------------------------------------
    def run_profiling_pooled(self) -> dict:
        """One ``BatchedCampaign`` sweep over EVERY cold admitted job's
        z x m profiling grid: lanes are built per job (each against its
        own steady state and CI grid), tagged with the job name,
        concatenated per cost model, run together, and the measurements
        scattered back into per-job (L, R) matrices that each job's
        ``KhaosRuntime.run_profiling`` consumes through the
        ``_PrecomputedCampaign`` adapter — N jobs profiled for the
        wall-clock of the widest grid, each phase machine still walking
        its own legal transitions."""
        cold = [j for j in self.jobs.values()
                if j.status == "admitted" and j.runtime is not None
                and j.runtime.phase == "steady_state"]
        if not cold:
            return {"jobs_profiled": 0, "pooled_lanes": 0}
        # group per cost model: a campaign prices all lanes with one cost
        groups: dict[tuple, list[FleetJob]] = {}
        for j in cold:
            groups.setdefault(_cost_key(j.spec.cost), []).append(j)
        total_lanes = 0
        for members in groups.values():
            plan: list[tuple[FleetJob, list, list, np.ndarray]] = []
            all_lanes: list[LaneSpec] = []
            all_injects: list[float] = []
            for j in members:
                cfg, rt = j.spec.cfg, j.runtime
                grid = rt.default_ci_grid()
                lanes, injects = build_profile_lanes(
                    j.spec.cost, j.recording, rt.steady.failure_times,
                    grid, cfg.profile_margin_seconds,
                    warmup_s=j.spec.profile_warmup_s,
                    max_recovery_s=j.spec.profile_max_recovery_s,
                    job=j.name)
                plan.append((j, lanes, injects, grid))
                all_lanes.extend(lanes)
                all_injects.extend(injects)
                j.profiling_lane_ticks += sum(len(l.rates) for l in lanes)
            camp = make_campaign(members[0].spec.cost, all_lanes,
                                 engine=self.engine).run()
            total_lanes += len(all_lanes)
            off = 0
            for j, lanes, injects, grid in plan:
                margin = j.spec.cfg.profile_margin_seconds
                meas = measure_profile_lanes(
                    camp, injects, margin, j.spec.profile_max_recovery_s,
                    lanes=range(off, off + len(lanes)))
                L, R = scatter_profile_results(
                    lanes, meas, len(j.runtime.steady.failure_times),
                    len(grid))
                j.runtime.run_profiling(_PrecomputedCampaign(L, R),
                                        ci_values=grid, margin=margin)
                self.registry.put(j.fp, j.runtime.m_l, j.runtime.m_r, j.name)
                off += len(lanes)
        return {"jobs_profiled": len(cold), "pooled_lanes": total_lanes}

    # -- Phase 3, multiplexed -------------------------------------------------
    def start(self) -> None:
        """Enter Phase 3 for every profiled job: build the shared
        supervision campaign(s) — one lane per lane-substrate job, grouped
        by cost model — instantiate scalar sims, and ``attach`` every
        job's handle to its runtime."""
        assert not self._started, "start() already ran"
        ready = [j for j in self.jobs.values()
                 if j.runtime is not None and j.runtime.phase == "profiled"]
        lane_groups: dict[tuple, list[FleetJob]] = {}
        for j in ready:
            if j.spec.substrate == "lane":
                lane_groups.setdefault(_cost_key(j.spec.cost), []).append(j)
        for key, members in lane_groups.items():
            lanes = []
            for i, j in enumerate(members):
                n = int(j.spec.horizon_s)
                rates = dense_rates(0.0, n, recording=None,
                                    schedule=j.spec.schedule) \
                    if j.spec.schedule is not None else \
                    dense_rates(float(j.recording.times[0]), n,
                                recording=j.recording)
                t0 = 0.0 if j.spec.schedule is not None \
                    else float(j.recording.times[0])
                lanes.append(LaneSpec(
                    rates=rates, ci_s=self._initial_ci(j), t0=t0,
                    failures=tuple(j.spec.failures),
                    tag={"job": j.name}))
                j.lane = i
            # hot reconfiguration on the supervised substrate (same choice
            # as the drive_campaign benches): a controller-in-the-loop
            # plan switch must not pay a savepoint-restart, or every
            # post-failure reconfigure compounds the very backlog it is
            # trying to drain
            camp = make_campaign(members[0].spec.cost, lanes,
                                 engine=self.engine,
                                 flink_semantics=False)
            self._campaigns[key] = camp
            for j in members:
                j.campaign = camp
                j.handle = BatchedLaneHandle(camp, j.lane)
        for j in ready:
            if j.spec.substrate == "scalar":
                sim = StreamSimulator(j.spec.cost,
                                      ci_s=self._initial_ci(j),
                                      schedule=j.spec.schedule,
                                      recording=j.spec.recording,
                                      seed=j.spec.seed)
                for t, kind in j.spec.failures:
                    sim.inject_failure(float(t), kind)
                j.sim = sim
                j.handle = SimJobHandle(sim)
            elif j.spec.substrate == "handle":
                j.handle = j.spec.handle
            j.runtime.attach(j.handle)
            j.status = "optimizing"
        self._started = True

    def _initial_ci(self, job: FleetJob) -> float:
        """Eq.-8 optimum at the recorded mean rate, falling back to the
        grid midpoint when infeasible there."""
        tr = float(np.mean(job.recording.counts)) if job.recording is not \
            None else 0.0
        ci = job.runtime.initial_ci(tr) if tr > 0 else None
        cfg = job.spec.cfg
        return float(ci) if ci is not None else \
            0.5 * (cfg.ci_min + cfg.ci_max)

    def run(self, duration_s: float, chunk_s: float = 60.0) -> dict:
        """The multiplexed controller tick: advance every substrate by
        ``chunk_s`` fleet-seconds, then poll every optimizing job's
        controller once, feed the monitor plane, and let divergence
        watchdogs trip transferred jobs into ``reprofile()``."""
        assert self._started, "call start() first"
        t_end = self.t + duration_s
        while self.t < t_end:
            self.t += chunk_s
            for camp in self._campaigns.values():
                if not camp.done:
                    camp.run(n_ticks=int(chunk_s))
            n_live = 0
            lat_sum = 0.0
            for j in self.jobs.values():
                if j.status != "optimizing":
                    continue
                if j.spec.substrate == "scalar":
                    j.sim.run_until(self.t)
                elif j.spec.substrate == "handle" and j.spec.ticker:
                    j.spec.ticker(chunk_s)
                dec = j.runtime.step()
                if dec is not None and np.isfinite(dec.latency) \
                        and np.isfinite(dec.tr_avg):
                    # the controller just measured this window — reuse
                    # its observations instead of slicing twice
                    lat, tr = dec.latency, dec.tr_avg
                else:
                    lat = j.handle.avg_latency(
                        j.spec.cfg.optimization_period)
                    tr = j.handle.avg_throughput(
                        j.spec.cfg.optimization_period)
                if np.isfinite(lat):
                    self.metrics.record(f"{j.name}/latency", self.t, lat)
                    lat_sum += lat
                    n_live += 1
                if np.isfinite(tr):
                    self.metrics.record(f"{j.name}/throughput", self.t, tr)
                self._feed_watchdog(j, lat, tr, fresh_poll=dec is not None)
                if j.spec.substrate == "lane" and j.campaign.done:
                    self._finish(j)
                elif j.spec.substrate == "scalar" and \
                        self.t >= j.spec.horizon_s:
                    self._finish(j)
            if n_live:
                self.metrics.record("fleet/latency", self.t,
                                    lat_sum / n_live)
            self.metrics.record("fleet/jobs_optimizing", self.t,
                                float(n_live))
        return self.status()

    def _feed_watchdog(self, job: FleetJob, lat: float, tr: float,
                       fresh_poll: bool = False) -> None:
        """Compare the adopted M_L against the observed latency; a
        sustained divergence means the donor surface does not describe
        this job — fall back to a REAL reprofile (the legal Phase-2
        re-entry), disarm the watchdog, and file the self-fitted models
        so the registry heals."""
        if job.watchdog is None or not np.isfinite(lat) \
                or not np.isfinite(tr):
            return
        if not job.handle.healthy():
            # downtime + backlog drain is chaos, not model divergence —
            # the same freeze the runtime's anomaly detector applies to
            # unhealthy samples (``observe_metrics(healthy=False)``)
            job.watchdog.reset()
            return
        if job.handle.current_plan().name != CheckpointPlan().name:
            # the fitted surfaces (donor's AND a cold job's own) are
            # measured under the full-sync baseline; once the controller
            # switches the checkpoint mechanism, a misprediction can no
            # longer separate "donor surface wrong for this job" from
            # "any baseline-fitted surface wrong for this plan" — the
            # cold twin's self-fitted M_L mispredicts identically.
            # Divergence judgment is only valid in the surface's domain.
            job.watchdog.reset()
            return
        rt = job.runtime
        pred = rt.controller.last_pred_lat if fresh_poll else float("nan")
        if not np.isfinite(pred):
            # no fresh controller evaluation this poll — pay our own
            pred = float(rt.m_l.predict(
                np.array([float(job.handle.current_ci())]),
                np.array([tr]))[0])
        if job.watchdog.observe(lat, pred):
            rt.reprofile(reason="transfer-divergence")
            job.reprofiles += 1
            job.transferred = False
            job.watchdog = None
            self.registry.put(job.fp, rt.m_l, rt.m_r, job.name)

    def _finish(self, job: FleetJob) -> None:
        job.status = "done"
        self.reserved_eps -= job.admission.reserved_eps

    # -- monitor-plane queries -----------------------------------------------
    def qos_violations(self, name: str, l_const: Optional[float] = None,
                       r_const: Optional[float] = None) -> dict:
        """QoS-violation seconds for one supervised lane job."""
        j = self.jobs[name]
        assert j.spec.substrate == "lane" and j.campaign is not None, \
            "violation scoring reads lane histories"
        cfg = j.spec.cfg
        return lane_violation_seconds(
            j.campaign, j.lane,
            cfg.latency_constraint if l_const is None else l_const,
            cfg.recovery_constraint if r_const is None else r_const)

    def status(self) -> dict:
        kinds: dict[str, int] = {}
        for _label, d in self.decision_log:
            kinds[d.kind] = kinds.get(d.kind, 0) + 1
        return {
            "t": self.t,
            "jobs": {n: {
                "status": j.status,
                "phase": j.runtime.phase if j.runtime else None,
                "admission": j.admission.action,
                "transferred": j.transferred,
                "transfer_source": j.transfer_source,
                "profiling_lane_ticks": j.profiling_lane_ticks,
                "reprofiles": j.reprofiles,
            } for n, j in self.jobs.items()},
            "reserved_eps": self.reserved_eps,
            "residual_eps": self.residual_eps,
            "decisions_by_kind": kinds,
            "shared_campaigns": len(self._campaigns),
        }
