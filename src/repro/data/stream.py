"""Streaming workload substrate — the framework's "Kafka".

The paper's Phase 1 records the incoming event stream `D` and extracts the
workload function ``W(t) = |E^(t)|`` (events per second).  Here the stream
carries *training events* (documents of tokens, or serving requests); the
producer rate follows a RateSchedule.  The stream is recordable and
replayable at the recorded rate — exactly what Phase 2 needs to drive the
parallel profiling deployments.

Two workload shapes reproduce the paper's experiments:
  * ``diurnal_rate``  — IoT-Vehicles analogue (TAPASCologne-like daily cycle)
  * ``ctr_rate``      — YSB analogue (ad-click CTR-like bursty rate)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

RateSchedule = Callable[[float], float]   # t (seconds) -> events/second


def constant_rate(rate: float) -> RateSchedule:
    return lambda t: float(rate)


def diurnal_rate(base: float = 1000.0, amplitude: float = 0.6,
                 period: float = 86_400.0, noise: float = 0.05,
                 seed: int = 0) -> RateSchedule:
    """Vehicle-traffic-like daily cycle: morning/evening peaks + noise."""
    rng = np.random.default_rng(seed)
    # fixed random phases for harmonics -> deterministic per seed
    phases = rng.uniform(0, 2 * np.pi, size=3)

    def rate(t: float) -> float:
        x = 2 * np.pi * (t % period) / period
        day = 0.5 * (1 - np.cos(x))                       # one broad daily bump
        rush = 0.35 * (np.sin(2 * x + phases[0]) ** 2)     # two rush-hour peaks
        wiggle = 0.08 * np.sin(7 * x + phases[1]) + 0.05 * np.sin(13 * x + phases[2])
        level = base * (1.0 + amplitude * (day + rush + wiggle - 0.5))
        jitter = 1.0 + noise * np.sin(t * 0.37 + phases[0] * 11.3)
        return float(max(1.0, level * jitter))

    return rate


def ctr_rate(base: float = 2000.0, seed: int = 1, period: float = 86_400.0) -> RateSchedule:
    """Ad-click-like workload: plateau + bursts (YSB analogue)."""
    rng = np.random.default_rng(seed)
    n_bursts = 6
    centers = rng.uniform(0, period, n_bursts)
    widths = rng.uniform(0.01, 0.04, n_bursts) * period
    heights = rng.uniform(0.3, 0.9, n_bursts)

    def rate(t: float) -> float:
        tt = t % period
        x = 2 * np.pi * tt / period
        level = base * (1.0 + 0.25 * np.sin(x) + 0.12 * np.sin(3 * x + 1.1))
        for c, w, h in zip(centers, widths, heights):
            level += base * h * np.exp(-0.5 * ((tt - c) / w) ** 2)
        return float(max(1.0, level))

    return rate


# ---------------------------------------------------------------------------
# Recording (Phase 1 artifact)
# ---------------------------------------------------------------------------

@dataclass
class WorkloadRecording:
    """The paper's dataset D, reduced to per-second arrival counts.

    ``times[i]`` is the i-th second of the recording window and
    ``counts[i] = |E^(t_i)| = W(t_i)``.
    """
    times: np.ndarray
    counts: np.ndarray

    def __post_init__(self) -> None:
        self.times = np.asarray(self.times, dtype=np.float64)
        self.counts = np.asarray(self.counts, dtype=np.float64)
        assert self.times.shape == self.counts.shape

    @property
    def duration(self) -> float:
        return float(self.times[-1] - self.times[0]) if len(self.times) > 1 else 0.0

    def workload(self, smoothing_window: int = 1) -> np.ndarray:
        """W(t), optionally smoothed with the paper's averaging window."""
        if smoothing_window <= 1:
            return self.counts.copy()
        k = np.ones(smoothing_window) / smoothing_window
        pad = smoothing_window // 2
        vp = np.pad(self.counts, (pad, smoothing_window - 1 - pad), mode="edge")
        return np.convolve(vp, k, mode="valid")

    def rate_at(self, t: float) -> float:
        i = int(np.clip(np.searchsorted(self.times, t), 0, len(self.times) - 1))
        return float(self.counts[i])

    def rates_at(self, times) -> np.ndarray:
        """Vectorized ``rate_at`` — one searchsorted for a whole time grid
        (the batched simulator's per-lane λ arrays come from here)."""
        times = np.asarray(times, dtype=np.float64)
        idx = np.clip(np.searchsorted(self.times, times), 0, len(self.times) - 1)
        return self.counts[idx]

    def rates_until(self, t_end: float, t0: Optional[float] = None,
                    tick: float = 1.0) -> np.ndarray:
        """Dense per-tick rate array for [t0, t_end) — precomputed once so a
        simulator pays an array index per tick instead of a Python call."""
        start = float(self.times[0]) if t0 is None else float(t0)
        n = max(0, int(np.ceil((t_end - start) / tick)))
        return self.rates_at(start + np.arange(n) * tick)

    def slice(self, t0: float, t1: float) -> "WorkloadRecording":
        m = (self.times >= t0) & (self.times <= t1)
        return WorkloadRecording(self.times[m], self.counts[m])


def dense_rates(t0: float, n_ticks: int,
                recording: Optional[WorkloadRecording] = None,
                schedule: Optional[RateSchedule] = None,
                tick: float = 1.0) -> np.ndarray:
    """Precompute λ(t) for ``n_ticks`` ticks starting at ``t0``.

    A recording resolves with one vectorized searchsorted; a schedule is a
    Python callable so it is sampled once here — either way the simulators
    stop paying a per-tick Python call on their hot loop.  The time grid
    ``t0 + k*tick`` matches the scalar simulator's clock exactly (its clock
    advances by exact float increments), so the values are identical to
    per-tick ``rate_at`` calls.
    """
    times = t0 + np.arange(n_ticks) * tick
    if recording is not None:
        return recording.rates_at(times)
    assert schedule is not None, "need a recording or a schedule"
    return np.array([schedule(float(t)) for t in times], dtype=np.float64)


def record_workload(schedule: RateSchedule, duration: float, t0: float = 0.0,
                    tick: float = 1.0, seed: int = 0,
                    poisson: bool = True) -> WorkloadRecording:
    """Phase 1 recording: sample arrivals for ``duration`` seconds.

    With ``poisson=True`` the per-tick count is Poisson(rate*tick) —
    realistic arrival noise the smoothing window then removes, as in the
    paper.
    """
    rng = np.random.default_rng(seed)
    n = int(round(duration / tick))
    times = t0 + np.arange(n) * tick
    rates = np.array([schedule(t) for t in times]) * tick
    counts = rng.poisson(rates).astype(np.float64) if poisson else rates
    return WorkloadRecording(times, counts)


# ---------------------------------------------------------------------------
# Live stream with lag accounting (the "messaging queue")
# ---------------------------------------------------------------------------

@dataclass
class EventStream:
    """Producer/consumer queue with offsets, the unit the trainer consumes.

    * producer side advances with time according to a schedule or recording
      (``produce_until``),
    * consumer side takes events in order (``consume``),
    * ``lag`` is the paper's *consumer lag* metric.

    Events are abstract here; the data pipeline maps offsets -> token
    batches deterministically, so an offset is a complete cursor (this is
    what makes checkpoint/restore exactly-once, cf. DESIGN.md §7.7).
    """
    schedule: Optional[RateSchedule] = None
    recording: Optional[WorkloadRecording] = None
    produced: float = 0.0       # fractional produced offset
    consumed: int = 0
    _last_t: float = 0.0        # stream production starts at t=0

    def rate_at(self, t: float) -> float:
        if self.recording is not None:
            return self.recording.rate_at(t)
        assert self.schedule is not None
        return self.schedule(t)

    def produce_until(self, t: float) -> None:
        if t == self._last_t:
            return
        if t < self._last_t:
            raise ValueError("time went backwards")
        # integrate the rate over [last_t, t] with 1s midpoint steps
        span = t - self._last_t
        steps = max(1, int(span))
        dt = span / steps
        for i in range(steps):
            tm = self._last_t + (i + 0.5) * dt
            self.produced += self.rate_at(tm) * dt
        self._last_t = t

    @property
    def lag(self) -> int:
        return max(0, int(self.produced) - self.consumed)

    def consume(self, n: int) -> int:
        """Take up to n events; returns how many were actually available."""
        take = min(n, self.lag)
        self.consumed += take
        return take

    # -- checkpoint support -------------------------------------------------
    def cursor(self) -> dict:
        return {"consumed": self.consumed}

    def restore(self, cursor: dict) -> None:
        self.consumed = int(cursor["consumed"])
