from repro.data.stream import (
    RateSchedule,
    constant_rate,
    diurnal_rate,
    ctr_rate,
    WorkloadRecording,
    record_workload,
    EventStream,
)
from repro.data.pipeline import StreamingBatcher, PipelineCursor

__all__ = [
    "RateSchedule",
    "constant_rate",
    "diurnal_rate",
    "ctr_rate",
    "WorkloadRecording",
    "record_workload",
    "EventStream",
    "StreamingBatcher",
    "PipelineCursor",
]
