"""Deterministic tokenized batch pipeline over the event stream.

An *event* is a fixed-length document of tokens generated deterministically
from its global offset (counter-based RNG), so any host can materialize any
event independently — this is what makes elastic rescaling and exactly-once
recovery trivial: the checkpointed cursor fully determines the remaining
stream.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.data.stream import EventStream


@dataclass
class PipelineCursor:
    offset: int = 0        # next global event index to emit

    def to_dict(self) -> dict:
        return {"offset": int(self.offset)}

    @staticmethod
    def from_dict(d: dict) -> "PipelineCursor":
        return PipelineCursor(offset=int(d["offset"]))


def _tokens_for_events(offsets: np.ndarray, seq_len: int, vocab: int,
                       seed: int) -> np.ndarray:
    """Counter-based deterministic token generation: event offset -> tokens.

    Philox-style: each event's tokens depend only on (seed, offset), never
    on consumption history.  Sequences follow an affine successor process
    t_{i+1} = (a * t_i + b) mod vocab with a random start per event, so the
    synthetic stream is LEARNABLE (a model can drive CE toward zero) while
    staying fully deterministic — needed both for exactly-once tests and
    for meaningful end-to-end training demos.
    """
    a, b = 31, 7
    out = np.empty((len(offsets), seq_len), dtype=np.int64)
    starts = np.empty(len(offsets), dtype=np.int64)
    for i, off in enumerate(offsets):
        rng = np.random.default_rng(np.uint64(seed * 2654435761 + int(off)))
        starts[i] = rng.integers(0, vocab)
    out[:, 0] = starts
    for j in range(1, seq_len):
        out[:, j] = (a * out[:, j - 1] + b) % vocab
    return out.astype(np.int32)


class StreamingBatcher:
    """Assemble (global_batch, seq_len) token batches from an EventStream.

    One event == one sequence.  ``next_batch`` returns None when the stream
    has not yet produced a full batch (the trainer then idles — underload),
    otherwise consumes ``global_batch`` events and returns tokens+labels.
    """

    def __init__(self, stream: EventStream, global_batch: int, seq_len: int,
                 vocab: int, seed: int = 0,
                 cursor: Optional[PipelineCursor] = None) -> None:
        self.stream = stream
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.vocab = vocab
        self.seed = seed
        self.cursor = cursor or PipelineCursor()
        # keep stream consumption consistent with a restored cursor
        self.stream.consumed = max(self.stream.consumed, self.cursor.offset)

    def ready(self) -> bool:
        return self.stream.lag >= self.global_batch

    def next_batch(self) -> Optional[dict]:
        if not self.ready():
            return None
        taken = self.stream.consume(self.global_batch)
        assert taken == self.global_batch
        offs = np.arange(self.cursor.offset, self.cursor.offset + taken)
        tokens = _tokens_for_events(offs, self.seq_len + 1, self.vocab, self.seed)
        self.cursor.offset += taken
        return {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
            "first_offset": int(offs[0]),
        }

    # -- checkpoint integration ---------------------------------------------
    def state_dict(self) -> dict:
        return {"cursor": self.cursor.to_dict(), "stream": self.stream.cursor()}

    def restore(self, state: dict) -> None:
        self.cursor = PipelineCursor.from_dict(state["cursor"])
        self.stream.restore(state["stream"])
        self.stream.consumed = max(self.stream.consumed, self.cursor.offset)
