from repro.sim.costmodel import SimCostModel, costmodel_from_arch, levels_due
from repro.sim.simulator import StreamSimulator, SimDeployment, SimJobHandle

__all__ = ["SimCostModel", "costmodel_from_arch", "levels_due",
           "StreamSimulator", "SimDeployment", "SimJobHandle"]
