from repro.sim.costmodel import SimCostModel, costmodel_from_arch
from repro.sim.simulator import StreamSimulator, SimDeployment, SimJobHandle

__all__ = ["SimCostModel", "costmodel_from_arch", "StreamSimulator",
           "SimDeployment", "SimJobHandle"]
