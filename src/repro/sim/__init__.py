from repro.sim.costmodel import SimCostModel, costmodel_from_arch, levels_due
from repro.sim.simulator import StreamSimulator, SimDeployment, SimJobHandle
from repro.sim.batched import (BatchedCampaign, BatchedDeployment,
                               BatchedLaneHandle, LaneSpec,
                               build_profile_lanes, make_plan_verifier,
                               measure_profile_lanes,
                               scatter_profile_results)

__all__ = ["SimCostModel", "costmodel_from_arch", "levels_due",
           "StreamSimulator", "SimDeployment", "SimJobHandle",
           "BatchedCampaign", "BatchedDeployment", "BatchedLaneHandle",
           "LaneSpec", "build_profile_lanes", "make_plan_verifier",
           "measure_profile_lanes", "scatter_profile_results"]
