"""Simulation package — a three-engine hierarchy, each bit-exact against
the one above it:

  ``StreamSimulator``  the scalar ORACLE: one job, a readable Python tick
                       loop; authoritative for tick semantics.
  ``BatchedCampaign``  NumPy LANES: N jobs advanced by one fused array
                       tick (~27x scalar); authoritative for the
                       vectorized floating-point order.
  ``DeviceCampaign``   the DEVICE engine (``sim.device``): the same tick
                       jitted into one ``lax.fori_loop`` program for
                       10^5+-lane mega-campaigns and exhaustive plan
                       sweeps; must match the NumPy lanes bit-for-bit.

Pick an engine with ``make_campaign(cost, lanes, engine="numpy"|"device")``.
``DeviceCampaign`` is exported lazily so importing ``repro.sim`` stays
jax-free for NumPy-only consumers.
"""
from repro.sim.costmodel import SimCostModel, costmodel_from_arch, levels_due
from repro.sim.simulator import StreamSimulator, SimDeployment, SimJobHandle
from repro.sim.batched import (BatchedCampaign, BatchedDeployment,
                               BatchedLaneHandle, LaneSpec,
                               build_profile_lanes, make_campaign,
                               make_plan_verifier, measure_profile_lanes,
                               scatter_profile_results)

__all__ = ["SimCostModel", "costmodel_from_arch", "levels_due",
           "StreamSimulator", "SimDeployment", "SimJobHandle",
           "BatchedCampaign", "BatchedDeployment", "BatchedLaneHandle",
           "DeviceCampaign", "LaneSpec", "build_profile_lanes",
           "make_campaign", "make_plan_verifier", "measure_profile_lanes",
           "scatter_profile_results"]


def __getattr__(name):
    if name == "DeviceCampaign":
        from repro.sim.device import DeviceCampaign
        return DeviceCampaign
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
