"""Lane-batched chaos campaigns — the vectorized twin of StreamSimulator.

Khaos exploits "the parallel processing capabilities of virtual cloud
automation" to run many chaos experiments concurrently (paper §III-C).
This module maps the paper's parallel VMs onto ARRAY LANES: one
``BatchedCampaign`` advances N independent simulator lanes (CI grid x
failure-kind mix x worst-case injection points x mechanism variants x
workload schedules) with one fused NumPy tick over all lanes.  Per-lane
state — ``(t, lag, offset_by_level, ckpt_in_flight, recovery_state)`` — is
held as ``(N,)`` / ``(N, 3)`` arrays; λ(t) schedules are precomputed into
dense per-tick rate matrices (``data.stream.dense_rates``) so the hot loop
contains no per-tick Python calls at all.

The scalar ``StreamSimulator`` stays as the oracle: every update below
mirrors its ``tick``/``_begin_failure`` statement-for-statement IN THE
SAME FLOATING-POINT ORDER, so a fixed-seed lane reproduces its scalar twin
bit-for-bit (tests/test_batched_sim.py asserts equivalence across plans
and all three failure kinds).  On top of the raw engine sit:

  * ``BatchedDeployment`` — the Phase-2 profiler substrate that runs all
    z CIs x m failure points as lanes of ONE campaign (retiring the
    "deployments execute sequentially" deviation in ``core/profiler.py``);
  * ``make_plan_verifier`` — the ``optimize_plan`` simulate-to-verify hook
    that replays top-k plan candidates through a campaign instead of
    trusting re-priced QoS surfaces alone;
  * ``BatchedLaneHandle`` — the full ``core.controller.JobHandle`` over
    ONE lane, with real per-lane actuation (``lane_set_ci``/
    ``lane_set_plan`` mirror the scalar ``set_ci``/``set_plan`` savepoint
    + restart statement-for-statement), so ``KhaosRuntime.drive_campaign``
    runs Phase 3 controller-IN-THE-LOOP across every lane at once.

Lane-level early exit: a campaign used to step every lane to the longest
horizon.  ``run`` now periodically COMPACTS finished lanes out of the
array state — terminal lanes (past their own horizon) always, recovered
chaos lanes too when ``early_exit=True`` — so mixed-horizon grids stop
paying the longest lane's tail.  Compaction is invisible to results:
dropped lanes' final state is parked in full-size master arrays and
scattered back on completion, and per-lane arithmetic is elementwise, so
fixed-seed lanes stay bit-exact against their scalar twins.
``compactions``/``lanes_compacted`` count the events (recorded in
``BENCH_sim.json``'s grid section).

``benchmarks/bench_recovery.py`` measures the engine (lane-ticks/s vs the
scalar loop) and emits the ``BENCH_sim.json`` artifact (schema
"bench_sim/3").

This engine is the middle tier of the sim package's three-engine
hierarchy: ``StreamSimulator`` (the scalar oracle — authoritative for
tick SEMANTICS) -> ``BatchedCampaign`` (NumPy lanes — authoritative for
the vectorized floating-point ORDER, bit-exact against the oracle) ->
``sim.device.DeviceCampaign`` (the jitted/vmapped device engine for
10^5+-lane mega-campaigns, bit-exact against THIS engine).  Campaign
consumers select a tier with ``make_campaign(..., engine=)`` /
``BatchedDeployment(engine=)`` / ``make_plan_verifier(engine=)``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from math import lcm
from typing import Optional, Sequence

import numpy as np

from repro.config import CheckpointPlan
from repro.data.stream import (RateSchedule, WorkloadRecording, dense_rates)
from repro.ft.failures import (DEGRADATION_KINDS, DIRECTIONS, Degradation,
                               FailureInjector, jitter_phase)
from repro.sim.costmodel import SimCostModel

#: fixed level order; column index == level, ordered fastest-restore first
#: (matches simulator._LEVEL_SPEED: memory=2, local=1, remote=0)
LEVELS = ("memory", "local", "remote")
KINDS = ("task", "node", "cluster")
_KIND_ID = {k: i for i, k in enumerate(KINDS)}
# gray failures ride a separate event stream: they never touch the
# wipe/survival/restore tables (the job stays up), they bend the tick
# dynamics through per-lane window state instead
_DEG_ID = {k: i for i, k in enumerate(DEGRADATION_KINDS)}
_DIR_ID = {d: i for i, d in enumerate(DIRECTIONS)}
# NOTE: the per-kind wipe/survival/restore tables are PER PLAN now — the
# replication factor decides whether a node failure takes the local level
# with it — so they live in ``_PlanTable`` (built from the same
# ``cost.wiped_levels``/``restore_duration_for`` the scalar oracle calls)
# instead of module-level constants.


@dataclass
class LaneSpec:
    """One scenario lane: a (CI, plan, workload, injection) combination.

    ``rates`` is the dense per-tick λ array starting at ``t0`` (tick = 1s);
    build it with ``data.stream.dense_rates`` / ``WorkloadRecording.
    rates_until``.  ``failures`` are (time, kind) injections, matching
    ``StreamSimulator.inject_failure``.  ``tag`` is free-form caller
    bookkeeping (e.g. which (ci, failure-point) cell the lane measures).
    """
    rates: np.ndarray
    ci_s: float = 60.0
    t0: float = 0.0
    plan: Optional[CheckpointPlan] = None
    failures: Sequence[tuple[float, str]] = ()
    degradations: Sequence[Degradation] = ()
    tag: Optional[dict] = None

    def resolved_plan(self, cost: SimCostModel) -> CheckpointPlan:
        # identical plan resolution to StreamSimulator.__init__
        return replace(self.plan or CheckpointPlan(sync=not cost.async_mode),
                       interval_s=self.ci_s)


class _PlanTable:
    """Per-distinct-plan pricing, precomputed once per campaign.

    Trigger durations / level routing are produced by the SAME cost-model
    methods the scalar simulator calls per trigger
    (``trigger_write_duration`` / ``levels_due``), folded over one cadence
    period (lcm of the every-Nth counts) into dense lookup tables the tick
    loop gathers from.
    """

    def __init__(self, cost: SimCostModel, plans: list[CheckpointPlan]):
        P = len(plans)
        self.plans = plans
        self.names = [p.name for p in plans]
        self.period = np.array(
            [lcm(max(p.full_every, 1), max(p.local_every, 1),
                 max(p.remote_every, 1)) for p in plans], dtype=np.int64)
        maxp = int(self.period.max()) if P else 1
        self.trig_dur = np.zeros((P, maxp))
        self.trig_lvls = np.zeros((P, maxp, 3), dtype=bool)
        self.sync = np.array([p.sync for p in plans], dtype=bool)
        self.level_mask = np.zeros((P, 3), dtype=bool)   # plan.levels, by column
        # restore duration is (plan, KIND, level): a node failure restoring
        # from replicated level-2 is a degraded partial restore with its
        # own price (cost.restore_duration_for)
        self.restore_dur = np.zeros((P, len(KINDS), 3))
        self.cold_restore = np.zeros(P)
        self.surviving = np.zeros((P, len(KINDS), 3), dtype=bool)
        # levels each kind destroys under this plan (replication-derived)
        self.wipes = np.zeros((P, len(KINDS), 3), dtype=bool)
        for pi, plan in enumerate(plans):
            for level in plan.levels:
                self.level_mask[pi, LEVELS.index(level)] = True
            for i in range(int(self.period[pi])):
                self.trig_dur[pi, i] = max(
                    cost.trigger_write_duration(plan, i), 1e-3)
                for level, _kind in plan.levels_due(i):
                    self.trig_lvls[pi, i, LEVELS.index(level)] = True
            for ki, kind in enumerate(KINDS):
                for li, level in enumerate(LEVELS):
                    self.restore_dur[pi, ki, li] = \
                        cost.restore_duration_for(plan, kind, level)
                for level in cost.surviving_levels(plan, kind):
                    self.surviving[pi, ki, LEVELS.index(level)] = True
                for level in cost.wiped_levels(plan, kind):
                    self.wipes[pi, ki, LEVELS.index(level)] = True
            self.cold_restore[pi] = cost.restore_duration("remote")


class BatchedCampaign:
    """N independent StreamSimulator lanes advanced by one fused tick.

    All lanes share one ``SimCostModel``; everything else (CI, plan,
    workload, t0, injections) varies per lane.  ``run()`` advances every
    lane to the end of its rate array; per-lane results are then read from
    the history matrices (``lag_history`` and the derived
    ``latency_history``) and the ``recoveries`` lists, which carry the same
    records ``StreamSimulator.recoveries`` does.

    ``flink_semantics`` governs the per-lane actuation (``lane_set_ci``/
    ``lane_set_plan``): savepoint + controlled restart (the scalar
    default) vs hot swap.  ``early_exit=True`` additionally lets the
    periodic compaction (every ``compact_every`` ticks) retire lanes whose
    chaos is fully resolved — all injections fired and recovered — before
    their horizon; lag histories and event tallies of retired lanes are
    then truncated at retirement, so leave it off when post-hoc
    trajectory measurement (``measure_profile_lanes``) must cover the
    full horizon.
    """

    def __init__(self, cost: SimCostModel, lanes: Sequence[LaneSpec],
                 record_history: bool = True, flink_semantics: bool = True,
                 early_exit: bool = False, compact_every: int = 256):
        assert lanes, "a campaign needs at least one lane"
        self.cost = cost
        self.lanes = list(lanes)
        self.flink_semantics = flink_semantics
        self.early_exit = early_exit
        self.compact_every = int(compact_every)
        N = self.n_lanes = len(self.lanes)
        self._ar = np.arange(N)

        # -- plan tables (dedup by value; interval is a per-lane array) -----
        resolved = [l.resolved_plan(cost) for l in self.lanes]
        keys = [replace(p, interval_s=0.0, levels=tuple(p.levels))
                for p in resolved]
        uniq: dict = {}
        self.plan_id = np.zeros(N, dtype=np.int64)
        for i, k in enumerate(keys):
            self.plan_id[i] = uniq.setdefault(k, len(uniq))
        self._plan_keys = uniq              # grows when lane_set_plan adds one
        self.table = _PlanTable(cost, list(uniq.keys()))
        self.lane_plan_name = [self.table.names[pid] for pid in self.plan_id]
        self._period = self.table.period[self.plan_id]
        self._sync = self.table.sync[self.plan_id]

        # -- dense λ matrix, padded past each lane's horizon ----------------
        # time-major layout: the per-step row read/write is contiguous
        self.lane_ticks = np.array([len(l.rates) for l in self.lanes],
                                   dtype=np.int64)
        T = self.horizon = int(self.lane_ticks.max())
        self._min_ticks = int(self.lane_ticks.min())
        self._rates_tm = np.zeros((T, N))
        for i, l in enumerate(self.lanes):
            r = np.asarray(l.rates, dtype=np.float64)
            self._rates_tm[:len(r), i] = r
            if len(r) < T and len(r):
                self._rates_tm[len(r):, i] = r[-1]

        # -- per-lane scalar-simulator state --------------------------------
        self.t0 = np.array([l.t0 for l in self.lanes])
        self.t = self.t0.copy()
        self.interval = np.array([l.ci_s for l in self.lanes])
        self.lag = np.zeros(N)
        self.produced = np.zeros(N)
        self.consumed = np.zeros(N)
        self.processed_total = np.zeros(N)   # scalar: throughput-series sum
                                             # (consumed net of rollbacks)
        self.pol_last = self.t0.copy()            # CheckpointPolicy.reset(t0)
        self.off_lvl = np.zeros((N, 3))           # offset_by_level
        self.last_off = np.zeros(N)
        self.ck_active = np.zeros(N, dtype=bool)  # ckpt_in_progress is not None
        self.ck_end = np.zeros(N)
        self.ck_off = np.zeros(N)
        self.ck_lvls = np.zeros((N, 3), dtype=bool)
        self.ckpt_count = np.zeros(N, dtype=np.int64)
        self.save_count = np.zeros(N, dtype=np.int64)
        self.down = np.zeros(N, dtype=bool)       # down_until is not None
        self.down_until = np.zeros(N)
        self.pending_ro = np.zeros(N)
        self.steady_lag = np.zeros(N)
        # active-failure bookkeeping (scalar's _active_failure dict)
        self.af_active = np.zeros(N, dtype=bool)
        self.af_t0 = np.zeros(N)
        self.af_kind = np.zeros(N, dtype=np.int64)
        self.af_ci = np.zeros(N)
        self.af_level = np.full(N, -1, dtype=np.int64)
        self.recoveries: list[list[dict]] = [[] for _ in range(N)]

        # -- injections: (N, K) time/kind arrays, +inf padded ---------------
        K = max(1, max((len(l.failures) for l in self.lanes), default=1))
        self.fail_t = np.full((N, K), np.inf)
        self.fail_kind = np.zeros((N, K), dtype=np.int64)
        self._n_fail = K
        for i, l in enumerate(self.lanes):
            for j, (ft, kind) in enumerate(sorted(l.failures)):
                self.fail_t[i, j] = ft
                self.fail_kind[i, j] = _KIND_ID[kind]
        self.fptr = np.zeros(N, dtype=np.int64)
        self._next_fail = self.fail_t[:, 0].copy()   # fail_t[i, fptr[i]] cache

        # -- gray-failure injections: per-lane event queues + window state --
        # (mirrors StreamSimulator.degradations / dg_* scalars exactly)
        D = max(1, max((len(l.degradations) for l in self.lanes), default=1))
        self.deg_t = np.full((N, D), np.inf)
        self.deg_kind = np.zeros((N, D), dtype=np.int64)
        self.deg_dur = np.zeros((N, D))
        self.deg_sev = np.zeros((N, D))
        self.deg_jit = np.zeros((N, D))
        self.deg_dir = np.zeros((N, D), dtype=np.int64)
        self._n_deg = D
        for i, l in enumerate(self.lanes):
            for j, d in enumerate(sorted(l.degradations, key=lambda d: d.t)):
                self.deg_t[i, j] = d.t
                self.deg_kind[i, j] = _DEG_ID[d.kind]
                self.deg_dur[i, j] = d.duration_s
                self.deg_sev[i, j] = d.severity
                self.deg_jit[i, j] = d.jitter_s
                self.deg_dir[i, j] = _DIR_ID[d.direction]
        self.dptr = np.zeros(N, dtype=np.int64)
        self._next_deg = self.deg_t[:, 0].copy()
        self._any_deg = bool(np.isfinite(self.deg_t).any())
        self.dg_cap_scale = np.ones(N)
        self.dg_cap_until = np.full(N, -np.inf)
        self.dg_ck_delay = np.zeros(N)
        self.dg_ck_jitter = np.zeros(N)
        self.dg_ck_t0 = np.zeros(N)
        self.dg_ck_until = np.full(N, -np.inf)
        self.dg_lat_delay = np.zeros(N)
        self.dg_lat_jitter = np.zeros(N)
        self.dg_lat_t0 = np.zeros(N)
        self.dg_lat_until = np.full(N, -np.inf)
        self.dg_bp_until = np.full(N, -np.inf)
        self.bp_suppressed = np.zeros(N, dtype=np.int64)

        self.record_history = record_history
        self._lag_hist_tm = np.zeros((T, N)) if record_history else None
        # to-source net delay inflates reported latency without touching
        # lag; its per-tick penalty needs its own history column so the
        # derived latency_history stays exact (allocated only when a lane
        # actually carries one)
        lat_deg = any(d.kind == "net_delay" and d.direction == "to_source"
                      for l in self.lanes for d in l.degradations)
        self._lat_extra_tm = np.zeros((T, N)) \
            if (record_history and lat_deg) else None
        self._step_idx = 0
        # hoisted per-step constants
        self._mu_ck = np.where(
            self._sync, cost.capacity_eps * (1.0 - cost.ckpt_sync_penalty),
            cost.capacity_eps * (1.0 - cost.async_overhead))
        self._all = np.ones(N, dtype=bool)

        # -- compaction state (lane-level early exit) -----------------------
        # working arrays hold only the ACTIVE lanes; `_active` maps compact
        # column -> original lane index, `_pos` the inverse (-1 = retired).
        # `_final` (allocated at first compaction) parks full-size masters
        # that retired lanes' terminal state is scattered into; on
        # completion `_finalize` restores every public array to full size
        # in original lane order, so results are read exactly as before.
        self._active = np.arange(N)
        self._pos = np.arange(N)
        self._final: Optional[dict] = None
        self._finished = False
        self._exec_override = np.full(N, -1, dtype=np.int64)
        self._had_fail = np.isfinite(self.fail_t).any(axis=1)
        self._t0_all = self.t0
        self._lane_ticks_all = self.lane_ticks
        self.compactions = 0
        self.lanes_compacted = 0

    #: per-lane working arrays compaction slices / finalize restores
    _PER_LANE = ("lane_ticks", "t0", "t", "interval", "lag", "produced",
                 "consumed", "processed_total",
                 "pol_last", "off_lvl", "last_off", "ck_active",
                 "ck_end", "ck_off", "ck_lvls", "ckpt_count", "save_count",
                 "down", "down_until", "pending_ro", "steady_lag",
                 "af_active", "af_t0", "af_kind", "af_ci", "af_level",
                 "plan_id", "_period", "_sync", "_mu_ck",
                 "fail_t", "fail_kind", "fptr", "_next_fail", "_had_fail",
                 "deg_t", "deg_kind", "deg_dur", "deg_sev", "deg_jit",
                 "deg_dir", "dptr", "_next_deg",
                 "dg_cap_scale", "dg_cap_until", "dg_ck_delay",
                 "dg_ck_jitter", "dg_ck_t0", "dg_ck_until", "dg_lat_delay",
                 "dg_lat_jitter", "dg_lat_t0", "dg_lat_until",
                 "dg_bp_until", "bp_suppressed")

    # -- compaction -----------------------------------------------------
    def _refresh_lane_cache(self) -> None:
        n = self._active.size
        self._ar = np.arange(n)
        self._all = np.ones(n, dtype=bool)
        self._min_ticks = int(self.lane_ticks.min()) if n else 0

    def _maybe_compact(self) -> None:
        if not self._active.size:
            return
        drop = self._step_idx >= self.lane_ticks          # past own horizon
        if self.early_exit:
            # chaos resolved: every injection fired and recovered, no
            # degradation pending or still bending capacity
            drop = drop | (self._had_fail & np.isinf(self._next_fail)
                           & ~self.down & ~self.af_active
                           & np.isinf(self._next_deg)
                           & (self.t >= self.dg_cap_until))
        nd = int(drop.sum())
        if nd == 0 or nd * 8 < drop.size:                 # amortize copies
            return
        self._compact(drop)

    def _compact(self, drop: np.ndarray) -> None:
        full_idx = self._active
        dropped = full_idx[drop]
        self._exec_override[dropped] = np.minimum(self.lane_ticks[drop],
                                                  self._step_idx)
        if self._final is None:
            # first compaction: the working arrays ARE the full-size
            # masters — park them (retired entries keep terminal values)
            self._final = {n: getattr(self, n) for n in self._PER_LANE}
            self._final["_rates_tm"] = self._rates_tm
        else:
            for n in self._PER_LANE:
                self._final[n][full_idx] = getattr(self, n)
            # λ columns are immutable: the master already holds every lane
        self._active = full_idx[~drop]
        self._pos = np.full(self.n_lanes, -1, dtype=np.int64)
        self._pos[self._active] = np.arange(self._active.size)
        for n in self._PER_LANE:
            setattr(self, n, self._final[n][self._active].copy())
        self._rates_tm = np.ascontiguousarray(
            self._final["_rates_tm"][:, self._active])
        self._refresh_lane_cache()
        self.compactions += 1
        self.lanes_compacted += len(dropped)

    def _finalize(self) -> None:
        """Restore full-size arrays in original lane order once stepping is
        over (results are then indexed exactly as in a compaction-free
        run)."""
        if self._finished:
            return
        self._finished = True
        if self._final is None:
            return
        full_idx = self._active
        for n in self._PER_LANE:
            self._final[n][full_idx] = getattr(self, n)
            setattr(self, n, self._final[n])
        self._rates_tm = self._final["_rates_tm"]
        self._final = None
        self._active = np.arange(self.n_lanes)
        self._pos = np.arange(self.n_lanes)
        self._refresh_lane_cache()

    def _lane_value(self, name: str, lane: int):
        """Read a per-lane field by ORIGINAL lane index, live or retired."""
        pos = int(self._pos[lane])
        if pos >= 0:
            return getattr(self, name)[pos]
        return self._final[name][lane]

    # ------------------------------------------------------------------
    def _begin_failure(self, mask: np.ndarray, kind: np.ndarray,
                       ev_t: np.ndarray) -> None:
        """Vectorized StreamSimulator._begin_failure for lanes in ``mask``
        (already-down lanes consume the event but take no action).
        ``ev_t`` is the injection instant — possibly fractional, strictly
        earlier than the tick that pops it, exactly as the scalar event."""
        act = mask & ~self.down
        if not act.any():
            return
        cost, tbl = self.cost, self.table
        self.ck_active &= ~act       # in-flight checkpoint dies with the job
        surv = tbl.surviving[self.plan_id, kind]          # (N, 3)
        offs = np.where(surv, self.off_lvl, -np.inf)
        best = offs.max(axis=1)
        has = surv.any(axis=1)
        # columns are ordered fastest-first, so first argmax == the scalar's
        # max((offset, speed, level)) tie-break toward the fastest level
        lvl = np.argmax(offs == best[:, None], axis=1)
        restore = np.where(has, tbl.restore_dur[self.plan_id, kind, lvl],
                           tbl.cold_restore[self.plan_id])
        offset = np.where(has, best, 0.0)
        # the failure destroys the levels it doesn't survive at (per-plan:
        # replication decides whether node loss takes local disk)
        wipe = tbl.wipes[self.plan_id, kind]              # (N, 3)
        self.off_lvl = np.where(act[:, None] & wipe, 0.0, self.off_lvl)
        self.down_until = np.where(
            act, ev_t + cost.detect_s + cost.restart_s + restore,
            self.down_until)
        self.pending_ro = np.where(act, offset, self.pending_ro)
        self.down |= act
        self.af_active |= act
        self.af_t0 = np.where(act, ev_t, self.af_t0)
        self.af_kind = np.where(act, kind, self.af_kind)
        self.af_ci = np.where(act, self.interval, self.af_ci)
        self.af_level = np.where(act, np.where(has, lvl, -1), self.af_level)

    def _begin_degradation(self, mask: np.ndarray, cur: np.ndarray) -> None:
        """Vectorized StreamSimulator._begin_degradation for lanes in
        ``mask``: activate each lane's current queued window (last-writer
        semantics on overlap, exactly as the scalar's sorted pop)."""
        ar = self._ar
        kind = self.deg_kind[ar, cur]
        ev_t = self.deg_t[ar, cur]
        until = ev_t + self.deg_dur[ar, cur]
        sev = self.deg_sev[ar, cur]
        jit = self.deg_jit[ar, cur]
        dirn = self.deg_dir[ar, cur]
        m = mask & (kind == _DEG_ID["straggler"])
        if m.any():
            self.dg_cap_scale = np.where(
                m, self.cost.straggler_capacity_scale(sev),
                self.dg_cap_scale)
            self.dg_cap_until = np.where(m, until, self.dg_cap_until)
        nd = mask & (kind == _DEG_ID["net_delay"])
        m = nd & (dirn == _DIR_ID["to_ckpt_store"])
        if m.any():
            self.dg_ck_delay = np.where(m, sev, self.dg_ck_delay)
            self.dg_ck_jitter = np.where(m, jit, self.dg_ck_jitter)
            self.dg_ck_t0 = np.where(m, ev_t, self.dg_ck_t0)
            self.dg_ck_until = np.where(m, until, self.dg_ck_until)
        m = nd & (dirn == _DIR_ID["to_source"])
        if m.any():
            self.dg_lat_delay = np.where(m, sev, self.dg_lat_delay)
            self.dg_lat_jitter = np.where(m, jit, self.dg_lat_jitter)
            self.dg_lat_t0 = np.where(m, ev_t, self.dg_lat_t0)
            self.dg_lat_until = np.where(m, until, self.dg_lat_until)
        m = mask & (kind == _DEG_ID["backpressure"])
        if m.any():
            self.dg_bp_until = np.where(m, until, self.dg_bp_until)

    def _step(self) -> None:
        k = self._step_idx
        all_alive = k < self._min_ticks
        alive = self._all if all_alive else (k < self.lane_ticks)
        if not all_alive and not alive.any():
            self._step_idx += 1
            return
        t = self.t
        lam = self._rates_tm[k] if all_alive \
            else np.where(alive, self._rates_tm[k], 0.0)
        self.produced += lam

        # pending failures (cheap compare against the cached next event)
        if self._n_fail:
            while True:
                pend = self._next_fail <= t
                if not all_alive:
                    pend &= alive
                if not pend.any():
                    break
                cur = np.minimum(self.fptr, self._n_fail - 1)
                self._begin_failure(pend, self.fail_kind[self._ar, cur],
                                    self._next_fail)
                self.fptr = np.where(pend, self.fptr + 1, self.fptr)
                nxt = np.minimum(self.fptr, self._n_fail - 1)
                self._next_fail = np.where(
                    self.fptr < self._n_fail, self.fail_t[self._ar, nxt],
                    np.inf)

        # pending gray-failure windows (mirrors the scalar's second pop)
        if self._any_deg:
            while True:
                pend = self._next_deg <= t
                if not all_alive:
                    pend &= alive
                if not pend.any():
                    break
                cur = np.minimum(self.dptr, self._n_deg - 1)
                self._begin_degradation(pend, cur)
                self.dptr = np.where(pend, self.dptr + 1, self.dptr)
                nxt = np.minimum(self.dptr, self._n_deg - 1)
                self._next_deg = np.where(
                    self.dptr < self._n_deg, self.deg_t[self._ar, nxt],
                    np.inf)

        down_any = self.down.any()
        if down_any:
            down = self.down if all_alive else (alive & self.down)
            up = ~self.down if all_alive else (alive & ~self.down)
            # job down: arrivals accumulate, nothing processed
            self.lag = np.where(down, self.lag + lam, self.lag)
            restart = down & (t >= self.down_until)
            if restart.any():
                # restart completes: roll back to checkpointed offset
                # (parenthesized as the scalar's `lag += consumed - ro` so
                # the FP rounding matches bit-for-bit)
                rb = restart & (self.pending_ro < self.consumed)
                self.lag = np.where(rb, self.lag + (self.consumed
                                                    - self.pending_ro),
                                    self.lag)
                self.consumed = np.where(rb, self.pending_ro, self.consumed)
                self.down &= ~restart
                self.pol_last = np.where(restart, t, self.pol_last)
        else:
            up = alive

        up_all = all_alive and not down_any    # every mask below collapses
        if down_any and not up.any():
            pass
        else:
            # checkpoint completion: commit the offset at every level the
            # trigger wrote (sparse — only the few completing lanes touched)
            comp = (t >= self.ck_end) & self.ck_active if up_all \
                else up & self.ck_active & (t >= self.ck_end)
            ci_ = np.flatnonzero(comp)
            if ci_.size:
                off = self.ck_off[ci_]
                self.off_lvl[ci_] = np.where(self.ck_lvls[ci_], off[:, None],
                                             self.off_lvl[ci_])
                self.last_off[ci_] = np.maximum(self.last_off[ci_], off)
                self.ckpt_count[ci_] += 1
                self.ck_active[ci_] = False
            # checkpoint start: levels due at this trigger index define the
            # composite write's duration (gathered from the plan table)
            due = (t - self.pol_last >= self.interval) & ~self.ck_active
            if not up_all:
                due &= up
            if self._any_deg:
                # backpressured lanes: the barrier cannot propagate, the
                # trigger slips past its cadence slot (counted per lane)
                bp = due & (t < self.dg_bp_until)
                if bp.any():
                    self.bp_suppressed += bp
                    due &= ~bp
            di = np.flatnonzero(due)
            if di.size:
                td = t[di]
                self.pol_last[di] = td
                pid = self.plan_id[di]
                idx = self.save_count[di] % self._period[di]
                self.save_count[di] += 1
                dur = self.table.trig_dur[pid, idx]
                if self._any_deg:
                    ckd = td < self.dg_ck_until[di]
                    if ckd.any():
                        # to-checkpoint-store net delay under the barrier
                        dur = dur + np.where(
                            ckd, self.cost.net_delay_barrier_penalty(
                                self.dg_ck_delay[di], self.dg_ck_jitter[di],
                                jitter_phase(td, self.dg_ck_t0[di])), 0.0)
                # barrier semantics: snapshot the offset at start
                self.ck_end[di] = td + dur
                self.ck_off[di] = self.consumed[di]
                self.ck_lvls[di] = self.table.trig_lvls[pid, idx]
                self.ck_active[di] = True
            # in-flight writes after both transitions == the scalar's
            # per-tick `checkpointing` flag
            checkpointing = self.ck_active if up_all else up & self.ck_active
            if self._any_deg:
                # straggler window expiry + capacity scale (x1.0 exact
                # identity on undegraded lanes, matching the scalar)
                reset = (t >= self.dg_cap_until) if up_all \
                    else (up & (t >= self.dg_cap_until))
                self.dg_cap_scale = np.where(reset, 1.0, self.dg_cap_scale)
                mu = np.where(checkpointing, self._mu_ck,
                              self.cost.capacity_eps) * self.dg_cap_scale
            else:
                mu = np.where(checkpointing, self._mu_ck,
                              self.cost.capacity_eps)
            inflow = self.lag + lam
            if down_any or not all_alive:
                processed = np.where(up, np.minimum(inflow, mu), 0.0)
                self.lag = np.where(up, np.maximum(0.0, inflow - processed),
                                    self.lag)
            else:
                processed = np.minimum(inflow, mu)
                self.lag = np.maximum(0.0, inflow - processed)
            self.consumed += processed
            self.processed_total += processed

        if self._lag_hist_tm is not None:
            if self._final is None:
                self._lag_hist_tm[k] = self.lag
            else:      # compacted: scatter into the full-width history row
                self._lag_hist_tm[k, self._active] = self.lag
        if self._lat_extra_tm is not None:
            la = t < self.dg_lat_until
            if not all_alive:
                la &= alive
            if la.any():
                # to-source net delay: latency penalty recorded alongside
                # lag (the scalar adds it to its per-tick latency metric)
                pen = np.where(la, self.cost.net_delay_latency_penalty(
                    self.dg_lat_delay, self.dg_lat_jitter,
                    jitter_phase(t, self.dg_lat_t0)), 0.0)
                if self._final is None:
                    self._lat_extra_tm[k] = pen
                else:
                    self._lat_extra_tm[k, self._active] = pen

        # recovery bookkeeping (ground truth: lag back to steady envelope)
        if self.af_active.any():
            # EWMA update set decided BEFORE clearing: a lane recovering this
            # tick skips the update (the scalar's if/elif)
            env = self.lag <= np.maximum(2.0 * lam,
                                         1.05 * self.steady_lag + 1.0)
            if not down_any and all_alive:
                upd = ~self.af_active
                near = self.af_active & env
            else:
                settled = ~self.down if all_alive else (alive & ~self.down)
                upd = settled & ~self.af_active
                near = self.af_active & settled & env
            if near.any():
                for i in np.flatnonzero(near):
                    lvl = int(self.af_level[i])
                    oi = int(self._active[i])     # original lane index
                    self.recoveries[oi].append({
                        "t_start": float(self.af_t0[i]),
                        "kind": KINDS[int(self.af_kind[i])],
                        "ci": float(self.af_ci[i]),
                        "restore_level": LEVELS[lvl] if lvl >= 0 else None,
                        "plan": self.lane_plan_name[oi],
                        "t_end": float(t[i]),
                        "recovery_s": float(t[i] - self.af_t0[i]),
                    })
                self.af_active &= ~near
            self.steady_lag = np.where(
                upd, 0.9 * self.steady_lag + 0.1 * self.lag, self.steady_lag)
        elif not down_any and all_alive:
            self.steady_lag *= 0.9
            self.steady_lag += 0.1 * self.lag
        else:
            upd = (~self.down if all_alive else (alive & ~self.down))
            self.steady_lag = np.where(
                upd, 0.9 * self.steady_lag + 0.1 * self.lag, self.steady_lag)

        if all_alive:
            self.t += 1.0          # in-place: nothing holds the old clock
        else:
            self.t = np.where(alive, t + 1.0, t)
        self._step_idx += 1

    def run(self, n_ticks: Optional[int] = None) -> "BatchedCampaign":
        end = self.horizon if n_ticks is None \
            else min(self.horizon, self._step_idx + n_ticks)
        ce = self.compact_every
        while self._step_idx < end and self._active.size:
            self._step()
            if ce and self._step_idx % ce == 0:
                self._maybe_compact()
        if self.done:
            self._finalize()
        return self

    @property
    def done(self) -> bool:
        """True once no lane has work left (horizon reached, or every lane
        retired by compaction)."""
        return (self._finished or self._step_idx >= self.horizon
                or not self._active.size)

    # -- results --------------------------------------------------------
    @property
    def rates(self) -> np.ndarray:
        """(N, T) dense λ matrix (lane-major view of the time-major store)."""
        src = self._final["_rates_tm"] if self._final is not None \
            else self._rates_tm
        return src.T

    @property
    def lag_hist(self) -> Optional[np.ndarray]:
        """(N, T) consumer-lag history, one row per lane."""
        return None if self._lag_hist_tm is None else self._lag_hist_tm.T

    @property
    def ticks_run(self) -> int:
        """Total alive lane-ticks advanced so far (the throughput unit);
        early-exited lanes count the ticks they actually executed."""
        executed = np.where(self._exec_override >= 0, self._exec_override,
                            np.minimum(self._lane_ticks_all, self._step_idx))
        return int(executed.sum())

    def times(self, lane: int) -> np.ndarray:
        """The tick clock of ``lane`` (t values its samples were taken at)."""
        return self._t0_all[lane] + np.arange(int(self._lane_ticks_all[lane]))

    def latency_history(self) -> np.ndarray:
        """(N, T) end-to-end latency, derived exactly as the scalar tick
        derives its 'latency' metric from lag."""
        assert self._lag_hist_tm is not None, \
            "campaign ran with record_history=False"
        steady_mu = max(self.cost.capacity_eps, 1e-9)
        lat = self.cost.base_latency_s + self.lag_hist / steady_mu
        if self._lat_extra_tm is not None:
            lat = lat + self._lat_extra_tm.T   # to-source net-delay penalty
        return lat

    def lane_recovery(self, lane: int) -> Optional[float]:
        """First recorded recovery_s of ``lane`` (scalar: recoveries[0])."""
        r = self.recoveries[lane]
        return float(r[0]["recovery_s"]) if r else None

    def lane_rates(self, lane: int) -> np.ndarray:
        """(T,) dense λ column for an ORIGINAL lane index (valid whether or
        not the lane is currently compacted away — λ is immutable)."""
        src = self._final["_rates_tm"] if self._final is not None \
            else self._rates_tm
        return src[:, lane]

    def lane_plan(self, lane: int) -> CheckpointPlan:
        """The plan currently in force on ``lane`` (original index), with
        its live interval."""
        pid = int(self._lane_value("plan_id", lane))
        ci = float(self._lane_value("interval", lane))
        return replace(self.table.plans[pid], interval_s=ci)

    # -- per-lane actuation (the controller's knobs) --------------------
    def _plan_index(self, plan: CheckpointPlan) -> int:
        """Table id for ``plan``, extending the pricing tables when the
        controller actuates a mechanism the campaign has not seen yet."""
        key = replace(plan, interval_s=0.0, levels=tuple(plan.levels))
        pid = self._plan_keys.get(key)
        if pid is None:
            pid = self._plan_keys.setdefault(key, len(self._plan_keys))
            self.table = _PlanTable(self.cost, list(self._plan_keys.keys()))
        return pid

    def lane_set_ci(self, lane: int, ci_s: float) -> None:
        """Per-lane ``StreamSimulator.set_ci``: hot CI change, or savepoint
        + controlled restart under flink semantics — statement-for-
        statement the scalar actuation, so a controller-in-the-loop lane
        stays bit-exact against its scalar twin.

        Actuating a RETIRED lane (past its horizon and compacted out) is
        an inert no-op: the scalar runtime's post-loop actuation on a
        finished job changes nothing either, so supervisors holding
        ``BatchedLaneHandle``s can keep polling/actuating while the pooled
        campaign compacts finished lanes away."""
        i = int(self._pos[lane])
        if i < 0:
            return
        self.interval[i] = float(ci_s)
        if self.flink_semantics:
            # savepoint immediately, restart; no offset rollback
            self.ck_active[i] = False
            self.last_off[i] = self.consumed[i]
            lvls = self.table.level_mask[self.plan_id[i]]
            self.off_lvl[i, lvls] = self.consumed[i]
            self.down[i] = True
            self.down_until[i] = self.t[i] + self.cost.reconfig_restart_s
            self.pending_ro[i] = self.consumed[i]   # savepoint: nothing lost

    def lane_set_plan(self, lane: int, plan: CheckpointPlan) -> None:
        """Per-lane ``StreamSimulator.set_plan``: controlled mechanism
        switch (savepoint + restart under flink semantics).  Inert on
        retired lanes, exactly as ``lane_set_ci``."""
        i = int(self._pos[lane])
        if i < 0:
            return
        pid = self._plan_index(plan)
        self.ck_active[i] = False      # in-flight write dies with the switch
        # levels absent from the new plan drop their offsets (the scalar
        # rebuilds its offset dict over plan.levels; missing levels read 0)
        self.off_lvl[i, ~self.table.level_mask[pid]] = 0.0
        self.plan_id[i] = pid
        self._period[i] = self.table.period[pid]
        self._sync[i] = self.table.sync[pid]
        self._mu_ck[i] = self.cost.capacity_eps * (
            1.0 - (self.cost.ckpt_sync_penalty if self.table.sync[pid]
                   else self.cost.async_overhead))
        self.lane_plan_name[lane] = self.table.names[pid]
        self.save_count[i] = 0
        self.lane_set_ci(lane, plan.interval_s)


#: campaign engine registry (see the module docstring's three-engine
#: hierarchy); "device" resolves lazily so NumPy-only users never import jax
CAMPAIGN_ENGINES = ("numpy", "device")


def make_campaign(cost: SimCostModel, lanes: Sequence[LaneSpec],
                  engine: str = "numpy", **kwargs) -> BatchedCampaign:
    """Construct a campaign on the requested engine: ``"numpy"`` (the
    ``BatchedCampaign`` reference) or ``"device"`` (the jitted
    ``sim.device.DeviceCampaign``, bit-exact against it)."""
    if engine == "device":
        from repro.sim.device import DeviceCampaign
        return DeviceCampaign(cost, lanes, **kwargs)
    if engine != "numpy":
        raise ValueError(f"unknown campaign engine {engine!r} "
                         f"(expected one of {CAMPAIGN_ENGINES})")
    return BatchedCampaign(cost, lanes, **kwargs)


class BatchedLaneHandle:
    """``core.controller.JobHandle`` over ONE lane of a running campaign.

    N of these under N independent ``KhaosController`` instances turn a
    fixed-plan campaign into a controller-IN-THE-LOOP one
    (``core.runtime.KhaosRuntime.drive_campaign``): the campaign advances
    all lanes with the fused tick, and at optimization-period boundaries
    each lane's controller observes its windows and actuates its knobs —
    the vectorized twin of the scalar ``SimJobHandle`` loop.  Requires the
    campaign to record history (the latency window reads it).
    """

    def __init__(self, camp: BatchedCampaign, lane: int):
        assert camp._lag_hist_tm is not None, \
            "controller-in-the-loop lanes need record_history=True"
        self.camp = camp
        self.lane = int(lane)
        self.reconfigurations: list[tuple[float, float]] = []
        self.plan_changes: list[tuple[float, str]] = []

    def alive(self) -> bool:
        """Lane still stepping (not past its horizon, not compacted out)."""
        i = int(self.camp._pos[self.lane])
        return i >= 0 and self.camp._step_idx < int(self.camp.lane_ticks[i])

    # -- observation ----------------------------------------------------
    def now(self) -> float:
        return float(self.camp._lane_value("t", self.lane))

    def current_ci(self) -> float:
        return float(self.camp._lane_value("interval", self.lane))

    def current_plan(self) -> CheckpointPlan:
        return self.camp.lane_plan(self.lane)

    def _window(self, window_s: float) -> slice:
        """Sample indices with t in [now - window, now] — the same
        inclusive window ``TimeSeries.mean_over`` resolves for the scalar
        handle (samples land on the tick clock t0 + k)."""
        camp, lane = self.camp, self.lane
        n = min(camp._step_idx, int(camp._lane_ticks_all[lane]))
        t_now = self.now()
        t0 = float(camp._t0_all[lane])
        lo = max(0, int(np.ceil(t_now - window_s - t0)))
        hi = min(n, int(np.floor(t_now - t0)) + 1)
        return slice(lo, max(lo, hi))

    def avg_latency(self, window_s: float) -> float:
        camp = self.camp
        sl = self._window(window_s)
        lag = camp._lag_hist_tm[sl, self.lane]
        if not lag.size:
            return float("nan")
        steady_mu = max(camp.cost.capacity_eps, 1e-9)
        vals = camp.cost.base_latency_s + lag / steady_mu
        if camp._lat_extra_tm is not None:
            vals = vals + camp._lat_extra_tm[sl, self.lane]
        return float(np.mean(vals))

    def avg_throughput(self, window_s: float) -> float:
        lam = self.camp.lane_rates(self.lane)[self._window(window_s)]
        return float(np.mean(lam)) if lam.size else float("nan")

    def healthy(self) -> bool:
        i = int(self.camp._pos[self.lane])
        if i < 0:
            return True
        return not (self.camp.down[i] or self.camp.af_active[i])

    # -- actuation ------------------------------------------------------
    def drain(self) -> None:
        """No-op by design: the flink-semantics controlled restart in
        ``reconfigure``/``reconfigure_plan`` takes the savepoint."""

    def reconfigure(self, new_ci: float) -> None:
        self.reconfigurations.append((self.now(), new_ci))
        self.camp.lane_set_ci(self.lane, new_ci)

    def reconfigure_plan(self, plan: CheckpointPlan) -> None:
        self.reconfigurations.append((self.now(), plan.interval_s))
        self.plan_changes.append((self.now(), plan.name))
        self.camp.lane_set_plan(self.lane, plan)


# ---------------------------------------------------------------------------
# Profile-style measurement (SimDeployment.profile_failure semantics)
# ---------------------------------------------------------------------------

@dataclass
class LaneMeasurement:
    latency_s: float
    recovery_s: float
    recovered: bool


def measure_profile_lanes(camp: BatchedCampaign, inject_ts: Sequence[float],
                          margin: float, max_recovery_s: float,
                          lanes: Optional[Sequence[int]] = None
                          ) -> list[LaneMeasurement]:
    """Post-hoc replication of ``SimDeployment.profile_failure``'s on_tick
    measurement over a finished campaign: per lane, pre-failure latency
    (capped median over the margin window) and recovery (consumer lag back
    inside the pre-failure envelope, after the detection timeout).  The
    scalar path computes these inside the tick loop; with full lag
    histories recorded they are pure array reductions.

    The recovery scan runs as ONE NumPy pass over an (M, T) time matrix
    (the per-lane Python loop was a measurable fraction of large-campaign
    post-processing); only the short pre-window ``mean``/``median``
    reductions stay per lane, on the SAME contiguous slices the scan
    identifies — NumPy's pairwise summation makes a masked full-row
    reduction group differently, so slicing is what keeps results
    bit-identical to the per-lane reference
    (``_measure_profile_lanes_loop``, asserted in tests).

    ``lanes`` selects which campaign lanes ``inject_ts`` refers to
    (default: lanes 0..len(inject_ts)-1) — the pooled multi-job profiling
    path measures each job's contiguous lane slice with that job's own
    margin/horizon.
    """
    cost = camp.cost
    lane_ids = np.asarray(list(range(len(inject_ts)) if lanes is None
                               else lanes), dtype=np.int64)
    inj = np.asarray(inject_ts, dtype=np.float64)
    M = min(lane_ids.size, inj.size)          # zip() truncation semantics
    lane_ids, inj = lane_ids[:M], inj[:M]
    if M == 0:
        return []
    lat_hist = camp.latency_history()[lane_ids]
    lag_hist = camp.lag_hist[lane_ids]
    ns = camp._lane_ticks_all[lane_ids]
    T = int(ns.max())
    k = np.arange(T)
    ts = camp._t0_all[lane_ids][:, None] + k          # (M, T) tick clocks
    valid = k < ns[:, None]
    lag = lag_hist[:, :T]
    rows = np.arange(M)
    inj_c = inj[:, None]
    # pre-failure margin window: monotone clocks make the mask one
    # contiguous run per lane — reduce it to (start, count) slice bounds
    pre = (ts >= inj_c - margin) & (ts < inj_c) & valid
    pre_lo = pre.argmax(axis=1)
    pre_n = pre.sum(axis=1)
    # steady threshold fixed at the first post-injection tick
    post = (ts >= inj_c) & valid
    has_post = post.any(axis=1)
    k0 = post.argmax(axis=1)
    base = np.zeros(M)
    for i in np.flatnonzero(pre_n):
        base[i] = np.mean(lag[i, pre_lo[i]:pre_lo[i] + pre_n[i]])
    lam_k0 = camp.rates[lane_ids][rows, k0]
    steady = np.maximum(2.0 * lam_k0, 1.2 * base + 1.0)
    ok = (ts > inj_c + cost.detect_s) & (ts >= inj_c) \
        & (ts < inj_c + max_recovery_s) & (lag <= steady[:, None]) & valid
    hit = ok.any(axis=1) & has_post
    first = ok.argmax(axis=1)
    recovery = np.where(hit, ts[rows, first] - inj,
                        float(max_recovery_s))
    out: list[LaneMeasurement] = []
    for i in range(M):
        if pre_n[i]:
            sl = lat_hist[i, pre_lo[i]:pre_lo[i] + pre_n[i]]
            latency = float(min(np.median(sl), 30.0))
        else:
            latency = cost.base_latency_s
        out.append(LaneMeasurement(latency, float(recovery[i]),
                                   bool(hit[i])))
    return out


def _measure_profile_lanes_loop(camp: BatchedCampaign,
                                inject_ts: Sequence[float],
                                margin: float, max_recovery_s: float,
                                lanes: Optional[Sequence[int]] = None
                                ) -> list[LaneMeasurement]:
    """Per-lane reference implementation of ``measure_profile_lanes``
    (kept verbatim; the vectorized pass must match it bit-for-bit)."""
    cost = camp.cost
    lat_hist = camp.latency_history()
    out: list[LaneMeasurement] = []
    lane_ids = range(len(inject_ts)) if lanes is None else lanes
    for i, inject_t in zip(lane_ids, inject_ts):
        ts = camp.times(i)
        n = len(ts)
        lag = camp.lag_hist[i, :n]
        lam = camp.rates[i, :n]
        pre = (ts >= inject_t - margin) & (ts < inject_t)
        lat_samples = lat_hist[i, :n][pre]
        lag_samples = lag[pre]
        # steady threshold fixed at the first post-injection tick
        post = np.flatnonzero(ts >= inject_t)
        recovery, recovered = max_recovery_s, False
        if post.size:
            k0 = post[0]
            base = float(np.mean(lag_samples)) if lag_samples.size else 0.0
            steady = max(2.0 * float(lam[k0]), 1.2 * base + 1.0)
            t_end = inject_t + max_recovery_s
            ok = (ts > inject_t + cost.detect_s) & (ts >= inject_t) \
                & (ts < t_end) & (lag <= steady)
            hit = np.flatnonzero(ok)
            if hit.size:
                recovery, recovered = float(ts[hit[0]] - inject_t), True
        if lat_samples.size:
            latency = float(min(np.median(lat_samples), 30.0))
        else:
            latency = cost.base_latency_s
        out.append(LaneMeasurement(latency, recovery, recovered))
    return out


# ---------------------------------------------------------------------------
# Phase-2 profiling over lanes (implements core.profiler.CampaignDeployment)
# ---------------------------------------------------------------------------

def build_profile_lanes(cost: SimCostModel, recording: WorkloadRecording,
                        failure_times, ci_values, margin: float,
                        warmup_s: float = 300.0,
                        max_recovery_s: float = 7200.0,
                        job: Optional[str] = None
                        ) -> tuple[list[LaneSpec], list[float]]:
    """Lane specs + injection times for one job's z x m Phase-2 grid.

    Module-level (rather than a ``BatchedDeployment`` method) so a fleet
    supervisor can build grids for MANY jobs, concatenate the lanes into
    one pooled ``BatchedCampaign``, and scatter the measurements back per
    job via the ``job`` tag each lane carries.
    """
    ci_values = np.asarray(ci_values, dtype=np.float64)
    failure_times = np.asarray(failure_times, dtype=np.float64)
    injector = FailureInjector()
    lanes: list[LaneSpec] = []
    inject_ts: list[float] = []
    for j, ci in enumerate(ci_values):
        for i, ft in enumerate(failure_times):
            t0 = max(float(recording.times[0]),
                     float(ft) - margin - warmup_s)
            # worst case: just before the next checkpoint completes
            inject_t = injector.worst_case_time(
                float(ft), t0, float(ci), cost.ckpt_duration_s)
            n = int(np.ceil(inject_t + max_recovery_s - t0))
            tag = {"ci_index": j, "fp_index": i}
            if job is not None:
                tag["job"] = job
            lanes.append(LaneSpec(
                rates=dense_rates(t0, n, recording=recording),
                ci_s=float(ci), t0=t0, failures=((inject_t, "node"),),
                tag=tag))
            inject_ts.append(inject_t)
    return lanes, inject_ts


def scatter_profile_results(lanes: Sequence[LaneSpec],
                            meas: Sequence[LaneMeasurement],
                            n_failure_points: int, n_ci: int
                            ) -> tuple[np.ndarray, np.ndarray]:
    """Scatter per-lane measurements back into (m, z) latency / recovery
    matrices using each lane's grid-index tag.  In the pooled multi-job
    case, call once per job with that job's lane/measurement slice."""
    L = np.zeros((n_failure_points, n_ci))
    R = np.zeros((n_failure_points, n_ci))
    for lane, msr in zip(lanes, meas):
        L[lane.tag["fp_index"], lane.tag["ci_index"]] = msr.latency_s
        R[lane.tag["fp_index"], lane.tag["ci_index"]] = msr.recovery_s
    return L, R


class BatchedDeployment:
    """All z CIs x m failure points profiled in ONE batched sweep.

    The paper runs its profiling deployments in parallel on Kubernetes;
    here each (CI, failure point) pair is one lane of a single
    ``BatchedCampaign``, so the whole Phase-2 grid advances together —
    statistics identical to the sequential ``SimDeployment`` loop (same
    worst-case injection, same lag-envelope recovery signal), wall-clock
    divided by the lane count.
    """

    def __init__(self, cost: SimCostModel, recording: WorkloadRecording,
                 warmup_s: float = 300.0, max_recovery_s: float = 7200.0,
                 engine: str = "numpy"):
        self.cost = cost
        self.recording = recording
        self.warmup_s = warmup_s
        self.max_recovery_s = max_recovery_s
        self.engine = engine
        self.last_campaign: Optional[BatchedCampaign] = None

    def profile_campaign(self, failure_times, ci_values, margin: float
                         ) -> tuple[np.ndarray, np.ndarray]:
        """(m, z) latency and recovery matrices for the full grid."""
        lanes, inject_ts = build_profile_lanes(
            self.cost, self.recording, failure_times, ci_values, margin,
            warmup_s=self.warmup_s, max_recovery_s=self.max_recovery_s)
        camp = make_campaign(self.cost, lanes, engine=self.engine).run()
        self.last_campaign = camp
        meas = measure_profile_lanes(camp, inject_ts, margin,
                                     self.max_recovery_s)
        return scatter_profile_results(lanes, meas, len(failure_times),
                                       len(ci_values))


# ---------------------------------------------------------------------------
# optimize_plan simulate-to-verify hook
# ---------------------------------------------------------------------------

def make_plan_verifier(cost: SimCostModel,
                       recording: Optional[WorkloadRecording] = None,
                       schedule: Optional[RateSchedule] = None,
                       failure_mix: Sequence[tuple[str, float]] = (
                           ("task", 0.30), ("node", 0.65), ("cluster", 0.05)),
                       warmup_s: float = 300.0, margin_s: float = 90.0,
                       max_recovery_s: float = 3600.0,
                       engine: str = "numpy"):
    """Build the ``optimize_plan(verifier=...)`` callback: top-k plan
    candidates are replayed through one batched campaign — one lane per
    (candidate, failure kind) with worst-case injection — and scored by
    MEASURED pre-failure latency and kind-mixed recovery, instead of the
    re-priced QoS surfaces alone.

    ``engine`` picks the campaign engine (it is also exposed as a mutable
    ``verify.engine`` attribute, which ``optimize_plan(engine=...)`` sets
    — an exhaustive sweep over the full candidate grid wants the device
    engine; both engines measure bit-identically)."""
    assert recording is not None or schedule is not None

    def verify(cands: Sequence[tuple[CheckpointPlan, float]]) -> list[dict]:
        lanes, inject_ts = [], []
        injector = FailureInjector()
        for plan, ci in cands:
            t_req = warmup_s + 3.0 * ci + 5.0
            inject_t = injector.worst_case_time(t_req, 0.0, ci,
                                                cost.ckpt_duration_s)
            n = int(np.ceil(inject_t + max_recovery_s))
            rates = dense_rates(0.0, n, recording, schedule)
            for kind, _w in failure_mix:
                lanes.append(LaneSpec(
                    rates=rates, ci_s=float(ci), plan=plan,
                    failures=((inject_t, kind),), tag={"kind": kind}))
                inject_ts.append(inject_t)
        camp = make_campaign(cost, lanes, engine=verify.engine).run()
        meas = measure_profile_lanes(camp, inject_ts, margin_s,
                                     max_recovery_s)
        out: list[dict] = []
        k = len(failure_mix)
        for c in range(len(cands)):
            block = meas[c * k:(c + 1) * k]
            per_kind = {kind: block[j].recovery_s
                        for j, (kind, _w) in enumerate(failure_mix)}
            recovery = sum(w * block[j].recovery_s
                           for j, (_kind, w) in enumerate(failure_mix))
            out.append({"latency_s": block[0].latency_s,
                        "recovery_s": float(recovery),
                        "per_kind": per_kind})
        return out

    verify.engine = engine
    return verify
