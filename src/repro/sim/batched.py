"""Lane-batched chaos campaigns — the vectorized twin of StreamSimulator.

Khaos exploits "the parallel processing capabilities of virtual cloud
automation" to run many chaos experiments concurrently (paper §III-C).
This module maps the paper's parallel VMs onto ARRAY LANES: one
``BatchedCampaign`` advances N independent simulator lanes (CI grid x
failure-kind mix x worst-case injection points x mechanism variants x
workload schedules) with one fused NumPy tick over all lanes.  Per-lane
state — ``(t, lag, offset_by_level, ckpt_in_flight, recovery_state)`` — is
held as ``(N,)`` / ``(N, 3)`` arrays; λ(t) schedules are precomputed into
dense per-tick rate matrices (``data.stream.dense_rates``) so the hot loop
contains no per-tick Python calls at all.

The scalar ``StreamSimulator`` stays as the oracle: every update below
mirrors its ``tick``/``_begin_failure`` statement-for-statement IN THE
SAME FLOATING-POINT ORDER, so a fixed-seed lane reproduces its scalar twin
bit-for-bit (tests/test_batched_sim.py asserts equivalence across plans
and all three failure kinds).  On top of the raw engine sit:

  * ``BatchedDeployment`` — the Phase-2 profiler substrate that runs all
    z CIs x m failure points as lanes of ONE campaign (retiring the
    "deployments execute sequentially" deviation in ``core/profiler.py``);
  * ``make_plan_verifier`` — the ``optimize_plan`` simulate-to-verify hook
    that replays top-k plan candidates through a campaign instead of
    trusting re-priced QoS surfaces alone.

``benchmarks/bench_recovery.py`` measures the engine (lane-ticks/s vs the
scalar loop) and emits the ``BENCH_sim.json`` artifact (schema
"bench_sim/1").
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from math import lcm
from typing import Optional, Sequence

import numpy as np

from repro.config import CheckpointPlan
from repro.data.stream import (RateSchedule, WorkloadRecording, dense_rates)
from repro.ft.failures import FailureInjector
from repro.sim.costmodel import SimCostModel

#: fixed level order; column index == level, ordered fastest-restore first
#: (matches simulator._LEVEL_SPEED: memory=2, local=1, remote=0)
LEVELS = ("memory", "local", "remote")
KINDS = ("task", "node", "cluster")
_KIND_ID = {k: i for i, k in enumerate(KINDS)}
#: levels a failure kind destroys (simulator._begin_failure's wipe rule)
_WIPES = {"task": (), "node": ("memory",), "cluster": ("memory", "local")}


@dataclass
class LaneSpec:
    """One scenario lane: a (CI, plan, workload, injection) combination.

    ``rates`` is the dense per-tick λ array starting at ``t0`` (tick = 1s);
    build it with ``data.stream.dense_rates`` / ``WorkloadRecording.
    rates_until``.  ``failures`` are (time, kind) injections, matching
    ``StreamSimulator.inject_failure``.  ``tag`` is free-form caller
    bookkeeping (e.g. which (ci, failure-point) cell the lane measures).
    """
    rates: np.ndarray
    ci_s: float = 60.0
    t0: float = 0.0
    plan: Optional[CheckpointPlan] = None
    failures: Sequence[tuple[float, str]] = ()
    tag: Optional[dict] = None

    def resolved_plan(self, cost: SimCostModel) -> CheckpointPlan:
        # identical plan resolution to StreamSimulator.__init__
        return replace(self.plan or CheckpointPlan(sync=not cost.async_mode),
                       interval_s=self.ci_s)


class _PlanTable:
    """Per-distinct-plan pricing, precomputed once per campaign.

    Trigger durations / level routing are produced by the SAME cost-model
    methods the scalar simulator calls per trigger
    (``trigger_write_duration`` / ``levels_due``), folded over one cadence
    period (lcm of the every-Nth counts) into dense lookup tables the tick
    loop gathers from.
    """

    def __init__(self, cost: SimCostModel, plans: list[CheckpointPlan]):
        P = len(plans)
        self.plans = plans
        self.names = [p.name for p in plans]
        self.period = np.array(
            [lcm(max(p.full_every, 1), max(p.local_every, 1),
                 max(p.remote_every, 1)) for p in plans], dtype=np.int64)
        maxp = int(self.period.max()) if P else 1
        self.trig_dur = np.zeros((P, maxp))
        self.trig_lvls = np.zeros((P, maxp, 3), dtype=bool)
        self.sync = np.array([p.sync for p in plans], dtype=bool)
        self.restore_dur = np.zeros((P, 3))
        self.cold_restore = np.zeros(P)
        self.surviving = np.zeros((P, len(KINDS), 3), dtype=bool)
        for pi, plan in enumerate(plans):
            for i in range(int(self.period[pi])):
                self.trig_dur[pi, i] = max(
                    cost.trigger_write_duration(plan, i), 1e-3)
                for level, _kind in plan.levels_due(i):
                    self.trig_lvls[pi, i, LEVELS.index(level)] = True
            for li, level in enumerate(LEVELS):
                with_delta = plan.mode == "incremental" and level != "memory"
                self.restore_dur[pi, li] = cost.restore_duration(level,
                                                                 with_delta)
            self.cold_restore[pi] = cost.restore_duration("remote")
            for ki, kind in enumerate(KINDS):
                for level in cost.surviving_levels(plan, kind):
                    self.surviving[pi, ki, LEVELS.index(level)] = True


class BatchedCampaign:
    """N independent StreamSimulator lanes advanced by one fused tick.

    All lanes share one ``SimCostModel``; everything else (CI, plan,
    workload, t0, injections) varies per lane.  ``run()`` advances every
    lane to the end of its rate array; per-lane results are then read from
    the history matrices (``lag_history`` and the derived
    ``latency_history``) and the ``recoveries`` lists, which carry the same
    records ``StreamSimulator.recoveries`` does.
    """

    def __init__(self, cost: SimCostModel, lanes: Sequence[LaneSpec],
                 record_history: bool = True):
        assert lanes, "a campaign needs at least one lane"
        self.cost = cost
        self.lanes = list(lanes)
        N = self.n_lanes = len(self.lanes)
        self._ar = np.arange(N)

        # -- plan tables (dedup by value; interval is a per-lane array) -----
        resolved = [l.resolved_plan(cost) for l in self.lanes]
        keys = [replace(p, interval_s=0.0, levels=tuple(p.levels))
                for p in resolved]
        uniq: dict = {}
        self.plan_id = np.zeros(N, dtype=np.int64)
        for i, k in enumerate(keys):
            self.plan_id[i] = uniq.setdefault(k, len(uniq))
        self.table = _PlanTable(cost, list(uniq.keys()))
        self.lane_plan_name = [self.table.names[pid] for pid in self.plan_id]
        self._period = self.table.period[self.plan_id]
        self._sync = self.table.sync[self.plan_id]

        # -- dense λ matrix, padded past each lane's horizon ----------------
        # time-major layout: the per-step row read/write is contiguous
        self.lane_ticks = np.array([len(l.rates) for l in self.lanes],
                                   dtype=np.int64)
        T = self.horizon = int(self.lane_ticks.max())
        self._min_ticks = int(self.lane_ticks.min())
        self._rates_tm = np.zeros((T, N))
        for i, l in enumerate(self.lanes):
            r = np.asarray(l.rates, dtype=np.float64)
            self._rates_tm[:len(r), i] = r
            if len(r) < T and len(r):
                self._rates_tm[len(r):, i] = r[-1]

        # -- per-lane scalar-simulator state --------------------------------
        self.t0 = np.array([l.t0 for l in self.lanes])
        self.t = self.t0.copy()
        self.interval = np.array([l.ci_s for l in self.lanes])
        self.lag = np.zeros(N)
        self.produced = np.zeros(N)
        self.consumed = np.zeros(N)
        self.pol_last = self.t0.copy()            # CheckpointPolicy.reset(t0)
        self.off_lvl = np.zeros((N, 3))           # offset_by_level
        self.last_off = np.zeros(N)
        self.ck_active = np.zeros(N, dtype=bool)  # ckpt_in_progress is not None
        self.ck_end = np.zeros(N)
        self.ck_off = np.zeros(N)
        self.ck_lvls = np.zeros((N, 3), dtype=bool)
        self.ckpt_count = np.zeros(N, dtype=np.int64)
        self.save_count = np.zeros(N, dtype=np.int64)
        self.down = np.zeros(N, dtype=bool)       # down_until is not None
        self.down_until = np.zeros(N)
        self.pending_ro = np.zeros(N)
        self.steady_lag = np.zeros(N)
        # active-failure bookkeeping (scalar's _active_failure dict)
        self.af_active = np.zeros(N, dtype=bool)
        self.af_t0 = np.zeros(N)
        self.af_kind = np.zeros(N, dtype=np.int64)
        self.af_ci = np.zeros(N)
        self.af_level = np.full(N, -1, dtype=np.int64)
        self.recoveries: list[list[dict]] = [[] for _ in range(N)]

        # -- injections: (N, K) time/kind arrays, +inf padded ---------------
        K = max(1, max((len(l.failures) for l in self.lanes), default=1))
        self.fail_t = np.full((N, K), np.inf)
        self.fail_kind = np.zeros((N, K), dtype=np.int64)
        self._n_fail = K
        for i, l in enumerate(self.lanes):
            for j, (ft, kind) in enumerate(sorted(l.failures)):
                self.fail_t[i, j] = ft
                self.fail_kind[i, j] = _KIND_ID[kind]
        self.fptr = np.zeros(N, dtype=np.int64)
        self._next_fail = self.fail_t[:, 0].copy()   # fail_t[i, fptr[i]] cache

        self.record_history = record_history
        self._lag_hist_tm = np.zeros((T, N)) if record_history else None
        self._step_idx = 0
        # hoisted per-step constants
        self._mu_ck = np.where(
            self._sync, cost.capacity_eps * (1.0 - cost.ckpt_sync_penalty),
            cost.capacity_eps * (1.0 - cost.async_overhead))
        self._all = np.ones(N, dtype=bool)

    # ------------------------------------------------------------------
    def _begin_failure(self, mask: np.ndarray, kind: np.ndarray,
                       ev_t: np.ndarray) -> None:
        """Vectorized StreamSimulator._begin_failure for lanes in ``mask``
        (already-down lanes consume the event but take no action).
        ``ev_t`` is the injection instant — possibly fractional, strictly
        earlier than the tick that pops it, exactly as the scalar event."""
        act = mask & ~self.down
        if not act.any():
            return
        cost, tbl = self.cost, self.table
        self.ck_active &= ~act       # in-flight checkpoint dies with the job
        surv = tbl.surviving[self.plan_id, kind]          # (N, 3)
        offs = np.where(surv, self.off_lvl, -np.inf)
        best = offs.max(axis=1)
        has = surv.any(axis=1)
        # columns are ordered fastest-first, so first argmax == the scalar's
        # max((offset, speed, level)) tie-break toward the fastest level
        lvl = np.argmax(offs == best[:, None], axis=1)
        restore = np.where(has, tbl.restore_dur[self.plan_id, lvl],
                           tbl.cold_restore[self.plan_id])
        offset = np.where(has, best, 0.0)
        # the failure destroys the levels it covers
        wipe = _WIPE_MASK[kind]                           # (N, 3)
        self.off_lvl = np.where(act[:, None] & wipe, 0.0, self.off_lvl)
        self.down_until = np.where(
            act, ev_t + cost.detect_s + cost.restart_s + restore,
            self.down_until)
        self.pending_ro = np.where(act, offset, self.pending_ro)
        self.down |= act
        self.af_active |= act
        self.af_t0 = np.where(act, ev_t, self.af_t0)
        self.af_kind = np.where(act, kind, self.af_kind)
        self.af_ci = np.where(act, self.interval, self.af_ci)
        self.af_level = np.where(act, np.where(has, lvl, -1), self.af_level)

    def _step(self) -> None:
        k = self._step_idx
        all_alive = k < self._min_ticks
        alive = self._all if all_alive else (k < self.lane_ticks)
        if not all_alive and not alive.any():
            self._step_idx += 1
            return
        t = self.t
        lam = self._rates_tm[k] if all_alive \
            else np.where(alive, self._rates_tm[k], 0.0)
        self.produced += lam

        # pending failures (cheap compare against the cached next event)
        if self._n_fail:
            while True:
                pend = self._next_fail <= t
                if not all_alive:
                    pend &= alive
                if not pend.any():
                    break
                cur = np.minimum(self.fptr, self._n_fail - 1)
                self._begin_failure(pend, self.fail_kind[self._ar, cur],
                                    self._next_fail)
                self.fptr = np.where(pend, self.fptr + 1, self.fptr)
                nxt = np.minimum(self.fptr, self._n_fail - 1)
                self._next_fail = np.where(
                    self.fptr < self._n_fail, self.fail_t[self._ar, nxt],
                    np.inf)

        down_any = self.down.any()
        if down_any:
            down = self.down if all_alive else (alive & self.down)
            up = ~self.down if all_alive else (alive & ~self.down)
            # job down: arrivals accumulate, nothing processed
            self.lag = np.where(down, self.lag + lam, self.lag)
            restart = down & (t >= self.down_until)
            if restart.any():
                # restart completes: roll back to checkpointed offset
                # (parenthesized as the scalar's `lag += consumed - ro` so
                # the FP rounding matches bit-for-bit)
                rb = restart & (self.pending_ro < self.consumed)
                self.lag = np.where(rb, self.lag + (self.consumed
                                                    - self.pending_ro),
                                    self.lag)
                self.consumed = np.where(rb, self.pending_ro, self.consumed)
                self.down &= ~restart
                self.pol_last = np.where(restart, t, self.pol_last)
        else:
            up = alive

        up_all = all_alive and not down_any    # every mask below collapses
        if down_any and not up.any():
            pass
        else:
            # checkpoint completion: commit the offset at every level the
            # trigger wrote (sparse — only the few completing lanes touched)
            comp = (t >= self.ck_end) & self.ck_active if up_all \
                else up & self.ck_active & (t >= self.ck_end)
            ci_ = np.flatnonzero(comp)
            if ci_.size:
                off = self.ck_off[ci_]
                self.off_lvl[ci_] = np.where(self.ck_lvls[ci_], off[:, None],
                                             self.off_lvl[ci_])
                self.last_off[ci_] = np.maximum(self.last_off[ci_], off)
                self.ckpt_count[ci_] += 1
                self.ck_active[ci_] = False
            # checkpoint start: levels due at this trigger index define the
            # composite write's duration (gathered from the plan table)
            due = (t - self.pol_last >= self.interval) & ~self.ck_active
            if not up_all:
                due &= up
            di = np.flatnonzero(due)
            if di.size:
                td = t[di]
                self.pol_last[di] = td
                pid = self.plan_id[di]
                idx = self.save_count[di] % self._period[di]
                self.save_count[di] += 1
                # barrier semantics: snapshot the offset at start
                self.ck_end[di] = td + self.table.trig_dur[pid, idx]
                self.ck_off[di] = self.consumed[di]
                self.ck_lvls[di] = self.table.trig_lvls[pid, idx]
                self.ck_active[di] = True
            # in-flight writes after both transitions == the scalar's
            # per-tick `checkpointing` flag
            checkpointing = self.ck_active if up_all else up & self.ck_active
            mu = np.where(checkpointing, self._mu_ck, self.cost.capacity_eps)
            inflow = self.lag + lam
            if down_any or not all_alive:
                processed = np.where(up, np.minimum(inflow, mu), 0.0)
                self.lag = np.where(up, np.maximum(0.0, inflow - processed),
                                    self.lag)
            else:
                processed = np.minimum(inflow, mu)
                self.lag = np.maximum(0.0, inflow - processed)
            self.consumed += processed

        if self._lag_hist_tm is not None:
            self._lag_hist_tm[k] = self.lag

        # recovery bookkeeping (ground truth: lag back to steady envelope)
        if self.af_active.any():
            # EWMA update set decided BEFORE clearing: a lane recovering this
            # tick skips the update (the scalar's if/elif)
            env = self.lag <= np.maximum(2.0 * lam,
                                         1.05 * self.steady_lag + 1.0)
            if not down_any and all_alive:
                upd = ~self.af_active
                near = self.af_active & env
            else:
                settled = ~self.down if all_alive else (alive & ~self.down)
                upd = settled & ~self.af_active
                near = self.af_active & settled & env
            if near.any():
                for i in np.flatnonzero(near):
                    lvl = int(self.af_level[i])
                    self.recoveries[i].append({
                        "t_start": float(self.af_t0[i]),
                        "kind": KINDS[int(self.af_kind[i])],
                        "ci": float(self.af_ci[i]),
                        "restore_level": LEVELS[lvl] if lvl >= 0 else None,
                        "plan": self.lane_plan_name[i],
                        "t_end": float(t[i]),
                        "recovery_s": float(t[i] - self.af_t0[i]),
                    })
                self.af_active &= ~near
            self.steady_lag = np.where(
                upd, 0.9 * self.steady_lag + 0.1 * self.lag, self.steady_lag)
        elif not down_any and all_alive:
            self.steady_lag *= 0.9
            self.steady_lag += 0.1 * self.lag
        else:
            upd = (~self.down if all_alive else (alive & ~self.down))
            self.steady_lag = np.where(
                upd, 0.9 * self.steady_lag + 0.1 * self.lag, self.steady_lag)

        if all_alive:
            self.t += 1.0          # in-place: nothing holds the old clock
        else:
            self.t = np.where(alive, t + 1.0, t)
        self._step_idx += 1

    def run(self, n_ticks: Optional[int] = None) -> "BatchedCampaign":
        end = self.horizon if n_ticks is None \
            else min(self.horizon, self._step_idx + n_ticks)
        while self._step_idx < end:
            self._step()
        return self

    # -- results --------------------------------------------------------
    @property
    def rates(self) -> np.ndarray:
        """(N, T) dense λ matrix (lane-major view of the time-major store)."""
        return self._rates_tm.T

    @property
    def lag_hist(self) -> Optional[np.ndarray]:
        """(N, T) consumer-lag history, one row per lane."""
        return None if self._lag_hist_tm is None else self._lag_hist_tm.T

    @property
    def ticks_run(self) -> int:
        """Total alive lane-ticks advanced so far (the throughput unit)."""
        return int(np.minimum(self.lane_ticks, self._step_idx).sum())

    def times(self, lane: int) -> np.ndarray:
        """The tick clock of ``lane`` (t values its samples were taken at)."""
        return self.t0[lane] + np.arange(int(self.lane_ticks[lane]))

    def latency_history(self) -> np.ndarray:
        """(N, T) end-to-end latency, derived exactly as the scalar tick
        derives its 'latency' metric from lag."""
        assert self._lag_hist_tm is not None, \
            "campaign ran with record_history=False"
        steady_mu = max(self.cost.capacity_eps, 1e-9)
        return self.cost.base_latency_s + self.lag_hist / steady_mu

    def lane_recovery(self, lane: int) -> Optional[float]:
        """First recorded recovery_s of ``lane`` (scalar: recoveries[0])."""
        r = self.recoveries[lane]
        return float(r[0]["recovery_s"]) if r else None


# boolean wipe masks indexed by kind id, built once at import
_WIPE_MASK = np.zeros((len(KINDS), 3), dtype=bool)
for _k, _levels in _WIPES.items():
    for _l in _levels:
        _WIPE_MASK[_KIND_ID[_k], LEVELS.index(_l)] = True


# ---------------------------------------------------------------------------
# Profile-style measurement (SimDeployment.profile_failure semantics)
# ---------------------------------------------------------------------------

@dataclass
class LaneMeasurement:
    latency_s: float
    recovery_s: float
    recovered: bool


def measure_profile_lanes(camp: BatchedCampaign, inject_ts: Sequence[float],
                          margin: float, max_recovery_s: float
                          ) -> list[LaneMeasurement]:
    """Post-hoc replication of ``SimDeployment.profile_failure``'s on_tick
    measurement over a finished campaign: per lane, pre-failure latency
    (capped median over the margin window) and recovery (consumer lag back
    inside the pre-failure envelope, after the detection timeout).  The
    scalar path computes these inside the tick loop; with full lag
    histories recorded they are pure array reductions.
    """
    cost = camp.cost
    lat_hist = camp.latency_history()
    out: list[LaneMeasurement] = []
    for i, inject_t in enumerate(inject_ts):
        ts = camp.times(i)
        n = len(ts)
        lag = camp.lag_hist[i, :n]
        lam = camp.rates[i, :n]
        pre = (ts >= inject_t - margin) & (ts < inject_t)
        lat_samples = lat_hist[i, :n][pre]
        lag_samples = lag[pre]
        # steady threshold fixed at the first post-injection tick
        post = np.flatnonzero(ts >= inject_t)
        recovery, recovered = max_recovery_s, False
        if post.size:
            k0 = post[0]
            base = float(np.mean(lag_samples)) if lag_samples.size else 0.0
            steady = max(2.0 * float(lam[k0]), 1.2 * base + 1.0)
            t_end = inject_t + max_recovery_s
            ok = (ts > inject_t + cost.detect_s) & (ts >= inject_t) \
                & (ts < t_end) & (lag <= steady)
            hit = np.flatnonzero(ok)
            if hit.size:
                recovery, recovered = float(ts[hit[0]] - inject_t), True
        if lat_samples.size:
            latency = float(min(np.median(lat_samples), 30.0))
        else:
            latency = cost.base_latency_s
        out.append(LaneMeasurement(latency, recovery, recovered))
    return out


# ---------------------------------------------------------------------------
# Phase-2 profiling over lanes (implements core.profiler.CampaignDeployment)
# ---------------------------------------------------------------------------

class BatchedDeployment:
    """All z CIs x m failure points profiled in ONE batched sweep.

    The paper runs its profiling deployments in parallel on Kubernetes;
    here each (CI, failure point) pair is one lane of a single
    ``BatchedCampaign``, so the whole Phase-2 grid advances together —
    statistics identical to the sequential ``SimDeployment`` loop (same
    worst-case injection, same lag-envelope recovery signal), wall-clock
    divided by the lane count.
    """

    def __init__(self, cost: SimCostModel, recording: WorkloadRecording,
                 warmup_s: float = 300.0, max_recovery_s: float = 7200.0):
        self.cost = cost
        self.recording = recording
        self.warmup_s = warmup_s
        self.max_recovery_s = max_recovery_s
        self.last_campaign: Optional[BatchedCampaign] = None

    def profile_campaign(self, failure_times, ci_values, margin: float
                         ) -> tuple[np.ndarray, np.ndarray]:
        """(m, z) latency and recovery matrices for the full grid."""
        ci_values = np.asarray(ci_values, dtype=np.float64)
        failure_times = np.asarray(failure_times, dtype=np.float64)
        injector = FailureInjector()
        lanes, inject_ts = [], []
        for j, ci in enumerate(ci_values):
            for i, ft in enumerate(failure_times):
                t0 = max(float(self.recording.times[0]),
                         float(ft) - margin - self.warmup_s)
                # worst case: just before the next checkpoint completes
                inject_t = injector.worst_case_time(
                    float(ft), t0, float(ci), self.cost.ckpt_duration_s)
                n = int(np.ceil(inject_t + self.max_recovery_s - t0))
                lanes.append(LaneSpec(
                    rates=dense_rates(t0, n, recording=self.recording),
                    ci_s=float(ci), t0=t0, failures=((inject_t, "node"),),
                    tag={"ci_index": j, "fp_index": i}))
                inject_ts.append(inject_t)
        camp = BatchedCampaign(self.cost, lanes).run()
        self.last_campaign = camp
        meas = measure_profile_lanes(camp, inject_ts, margin,
                                     self.max_recovery_s)
        z, m = len(ci_values), len(failure_times)
        L = np.zeros((m, z))
        R = np.zeros((m, z))
        for lane, msr in zip(lanes, meas):
            L[lane.tag["fp_index"], lane.tag["ci_index"]] = msr.latency_s
            R[lane.tag["fp_index"], lane.tag["ci_index"]] = msr.recovery_s
        return L, R


# ---------------------------------------------------------------------------
# optimize_plan simulate-to-verify hook
# ---------------------------------------------------------------------------

def make_plan_verifier(cost: SimCostModel,
                       recording: Optional[WorkloadRecording] = None,
                       schedule: Optional[RateSchedule] = None,
                       failure_mix: Sequence[tuple[str, float]] = (
                           ("task", 0.30), ("node", 0.65), ("cluster", 0.05)),
                       warmup_s: float = 300.0, margin_s: float = 90.0,
                       max_recovery_s: float = 3600.0):
    """Build the ``optimize_plan(verifier=...)`` callback: top-k plan
    candidates are replayed through one batched campaign — one lane per
    (candidate, failure kind) with worst-case injection — and scored by
    MEASURED pre-failure latency and kind-mixed recovery, instead of the
    re-priced QoS surfaces alone."""
    assert recording is not None or schedule is not None

    def verify(cands: Sequence[tuple[CheckpointPlan, float]]) -> list[dict]:
        lanes, inject_ts = [], []
        injector = FailureInjector()
        for plan, ci in cands:
            t_req = warmup_s + 3.0 * ci + 5.0
            inject_t = injector.worst_case_time(t_req, 0.0, ci,
                                                cost.ckpt_duration_s)
            n = int(np.ceil(inject_t + max_recovery_s))
            rates = dense_rates(0.0, n, recording, schedule)
            for kind, _w in failure_mix:
                lanes.append(LaneSpec(
                    rates=rates, ci_s=float(ci), plan=plan,
                    failures=((inject_t, kind),), tag={"kind": kind}))
                inject_ts.append(inject_t)
        camp = BatchedCampaign(cost, lanes).run()
        meas = measure_profile_lanes(camp, inject_ts, margin_s,
                                     max_recovery_s)
        out: list[dict] = []
        k = len(failure_mix)
        for c in range(len(cands)):
            block = meas[c * k:(c + 1) * k]
            per_kind = {kind: block[j].recovery_s
                        for j, (kind, _w) in enumerate(failure_mix)}
            recovery = sum(w * block[j].recovery_s
                           for j, (_kind, w) in enumerate(failure_mix))
            out.append({"latency_s": block[0].latency_s,
                        "recovery_s": float(recovery),
                        "per_kind": per_kind})
        return out

    return verify
