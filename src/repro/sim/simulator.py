"""Discrete-event (1s-tick) simulator of a checkpointed streaming job.

Models exactly the dynamics the paper measures:
  * variable arrival rate λ(t) from a recording or schedule;
  * service capacity μ with checkpoint overhead (sync pause or async tax);
  * consumer lag queueing and end-to-end latency ≈ base + lag/μ;
  * failures: detect (heartbeat timeout) → restart → restore → offset
    rollback to the last *completed* checkpoint → catch-up at full rate
    while arrivals continue — recovery ends when the job produces results
    at the latest offset again (lag back to steady state);
  * controlled reconfiguration (savepoint + restart, no offset rollback).

The checkpoint plane is a full ``CheckpointPlan``: each trigger writes the
levels due at that trigger (memory/local/remote, full or delta per the
plan's cadences — the same routing ``CheckpointManager`` executes) with
per-kind durations from the cost model, offsets are tracked per level, and
a failure rolls back to the newest offset on a level that *survives its
kind* — so an incremental or multi-level plan prices differently from the
full-sync baseline, which is exactly what the plan optimizer searches over.

The same engine backs Phase-2 profiling deployments (``SimDeployment``),
the paper's static-CI baselines and the Khaos-controlled runs (via
``SimJobHandle`` which implements core.controller.JobHandle).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import numpy as np

from repro.checkpoint.policy import CheckpointPolicy
from repro.config import CheckpointPlan
from repro.core.anomaly import AnomalyDetector
from repro.data.stream import RateSchedule, WorkloadRecording, dense_rates
from repro.ft.failures import (CRASH_KINDS, Degradation, FailureInjector,
                               jitter_phase)
from repro.metrics import MetricsStore
from repro.sim.costmodel import SimCostModel, levels_due

_LEVEL_SPEED = {"memory": 2, "local": 1, "remote": 0}
_RATE_CHUNK = 4096    # ticks of λ(t) precomputed per refill (see rates_until)


@dataclass
class FailureEvent:
    t: float
    kind: str = "node"


class StreamSimulator:
    def __init__(self, cost: SimCostModel, ci_s: float,
                 recording: Optional[WorkloadRecording] = None,
                 schedule: Optional[RateSchedule] = None,
                 t0: float = 0.0, seed: int = 0,
                 flink_semantics: bool = True,
                 plan: Optional[CheckpointPlan] = None):
        assert recording is not None or schedule is not None
        self.cost = cost
        self.recording = recording
        self.schedule = schedule
        # the mechanism half of the plan; ci_s remains the cadence knob
        self.plan = replace(plan or CheckpointPlan(sync=not cost.async_mode),
                            interval_s=ci_s)
        self.policy = CheckpointPolicy(ci_s)
        self.policy.reset(t0)
        self.flink_semantics = flink_semantics
        self.t = t0
        self.metrics = MetricsStore()
        self.lag = 0.0
        self.produced = 0.0
        self.consumed = 0.0
        # checkpoint machinery: per-level completed offsets + one in-flight
        # composite write (end_t, offset, levels written this trigger)
        self.ckpt_in_progress: Optional[tuple[float, float, tuple]] = None
        self.offset_by_level: dict[str, float] = {l: 0.0 for l in self.plan.levels}
        self.last_ckpt_offset = 0.0
        self.last_ckpt_completed_t = t0
        self.ckpt_count = 0
        self.save_count = 0            # trigger index (drives level cadences)
        # failure machinery
        self.down_until: Optional[float] = None
        self.pending_restore_offset: Optional[float] = None
        self.failures: list[FailureEvent] = []
        self.recoveries: list[dict] = []
        self._active_failure: Optional[dict] = None
        self._steady_lag = 0.0
        # gray-failure machinery (ft.failures.DEGRADATION_KINDS): pending
        # windows plus the active-window state each kind bends —
        # capacity scale (straggler), barrier-write penalty (net_delay
        # to_ckpt_store), latency penalty (net_delay to_source), trigger
        # suppression (backpressure).  The batched engine mirrors every
        # field as a per-lane array with identical update order.
        self.degradations: list[Degradation] = []
        self.dg_cap_scale = 1.0
        self.dg_cap_until = -np.inf
        self.dg_ck_delay = 0.0
        self.dg_ck_jitter = 0.0
        self.dg_ck_t0 = 0.0
        self.dg_ck_until = -np.inf
        self.dg_lat_delay = 0.0
        self.dg_lat_jitter = 0.0
        self.dg_lat_t0 = 0.0
        self.dg_lat_until = -np.inf
        self.dg_bp_until = -np.inf
        self.bp_suppressed = 0     # triggers delayed past their cadence slot
        # dense λ(t) buffer: the tick loop reads an array slot instead of
        # paying a Python call per tick (recordings resolve vectorized)
        self._rate_buf: Optional[np.ndarray] = None
        self._rate_idx = 0

    # ------------------------------------------------------------------
    def rate_at(self, t: float) -> float:
        if self.recording is not None:
            return self.recording.rate_at(t)
        return self.schedule(t)

    def rates_until(self, t_end: float) -> np.ndarray:
        """Dense per-tick λ array for [self.t, t_end) — the precomputed form
        both this simulator's tick loop and the batched engine consume."""
        n = max(0, int(np.ceil(t_end - self.t)))
        return dense_rates(self.t, n, self.recording, self.schedule)

    def _next_rate(self) -> float:
        """λ at the current tick, from the dense buffer (refilled in
        ``_RATE_CHUNK``-tick blocks).  The buffer's time grid is exactly the
        tick clock (t advances by exact +1.0 steps), so values match
        per-tick ``rate_at`` calls bit-for-bit."""
        if self._rate_buf is None or self._rate_idx >= len(self._rate_buf):
            self._rate_buf = dense_rates(self.t, _RATE_CHUNK,
                                         self.recording, self.schedule)
            self._rate_idx = 0
        lam = float(self._rate_buf[self._rate_idx])
        self._rate_idx += 1
        return lam

    def inject_failure(self, t: float, kind: str = "node") -> None:
        if kind not in CRASH_KINDS:
            raise ValueError(f"unknown crash kind {kind!r}; expected one of "
                             f"{CRASH_KINDS} (use inject_degradation for "
                             f"gray failures)")
        self.failures.append(FailureEvent(t, kind))
        self.failures.sort(key=lambda f: f.t)

    def inject_degradation(self, t: float, kind: str, duration_s: float,
                           severity: float = 0.0, jitter_s: float = 0.0,
                           direction: str = "to_source") -> None:
        """Schedule a gray-failure window (validated by ``Degradation``)."""
        self.degradations.append(Degradation(
            t=t, kind=kind, duration_s=duration_s, severity=severity,
            jitter_s=jitter_s, direction=direction))
        self.degradations.sort(key=lambda d: d.t)

    def set_ci(self, ci_s: float) -> None:
        """Hot CI change (TPU semantics) or controlled restart (Flink)."""
        self.policy.set_interval(ci_s, self.t)
        self.plan = replace(self.plan, interval_s=ci_s)
        if self.flink_semantics:
            # savepoint immediately, restart; no offset rollback
            self.ckpt_in_progress = None
            self.last_ckpt_offset = self.consumed
            self.offset_by_level = {l: self.consumed for l in self.plan.levels}
            self.last_ckpt_completed_t = self.t
            self.down_until = self.t + self.cost.reconfig_restart_s
            self.pending_restore_offset = self.consumed  # savepoint: nothing lost

    def set_plan(self, plan: CheckpointPlan) -> None:
        """Controlled mechanism switch (savepoint + restart under Flink
        semantics): the Khaos actuation when the optimizer changes the
        checkpoint *mode*, not just the interval."""
        old_offsets = self.offset_by_level
        self.ckpt_in_progress = None   # in-flight write dies with the switch
        self.plan = plan
        self.offset_by_level = {l: old_offsets.get(l, 0.0) for l in plan.levels}
        self.save_count = 0
        self.set_ci(plan.interval_s)

    # ------------------------------------------------------------------
    def tick(self) -> dict:
        """Advance one second; returns the metrics sample emitted."""
        t = self.t
        lam = self._next_rate()
        self.produced += lam
        cost = self.cost

        # pending failures
        while self.failures and self.failures[0].t <= t:
            ev = self.failures.pop(0)
            self._begin_failure(ev)
        # pending gray-failure windows
        while self.degradations and self.degradations[0].t <= t:
            self._begin_degradation(self.degradations.pop(0))

        if self.down_until is not None:
            # job down: arrivals accumulate, nothing processed
            self.lag += lam
            if t >= self.down_until:
                # restart completes: roll back to checkpointed offset
                ro = self.pending_restore_offset
                if ro is not None and ro < self.consumed:
                    self.lag += self.consumed - ro    # events to reprocess
                    self.consumed = ro
                self.down_until = None
                self.pending_restore_offset = None
                self.policy.reset(t)
            mu = 0.0
            processed = 0.0
        else:
            checkpointing = False
            # checkpoint completion: commit the offset at every level the
            # trigger wrote
            if self.ckpt_in_progress is not None:
                end_t, offset, levels = self.ckpt_in_progress
                if t >= end_t:
                    for level in levels:
                        self.offset_by_level[level] = offset
                    self.last_ckpt_offset = max(self.last_ckpt_offset, offset)
                    self.last_ckpt_completed_t = t
                    self.ckpt_in_progress = None
                    self.ckpt_count += 1
                else:
                    checkpointing = True
            # checkpoint start: the levels due at this trigger index define
            # the composite write's duration (full vs delta, per level)
            if self.ckpt_in_progress is None and self.policy.due(t):
                if t < self.dg_bp_until:
                    # backpressured source: the barrier cannot propagate,
                    # the trigger slips past its cadence slot — lost work
                    # at the next crash grows with the slip
                    self.bp_suppressed += 1
                else:
                    self.policy.mark(t)
                    due = levels_due(self.plan, self.save_count)
                    duration = max(cost.trigger_write_duration(
                        self.plan, self.save_count), 1e-3)
                    if t < self.dg_ck_until:
                        # to-checkpoint-store net delay under the barrier
                        duration = duration + cost.net_delay_barrier_penalty(
                            self.dg_ck_delay, self.dg_ck_jitter,
                            jitter_phase(t, self.dg_ck_t0))
                    self.save_count += 1
                    # barrier semantics: snapshot the offset at start
                    self.ckpt_in_progress = (t + duration, self.consumed,
                                             tuple(l for l, _ in due))
                    checkpointing = True
            if t >= self.dg_cap_until:
                self.dg_cap_scale = 1.0    # straggler window expired
            mu = cost.effective_capacity(checkpointing, sync=self.plan.sync) \
                * self.dg_cap_scale
            processed = min(self.lag + lam, mu)
            self.lag = max(0.0, self.lag + lam - processed)
            self.consumed += processed

        steady_mu = cost.capacity_eps
        latency = cost.base_latency_s + self.lag / max(steady_mu, 1e-9)
        if t < self.dg_lat_until:
            # to-source net delay sits on the source->job path: end-to-end
            # latency inflates, lag does not (arrivals are offset-stamped)
            latency = latency + cost.net_delay_latency_penalty(
                self.dg_lat_delay, self.dg_lat_jitter,
                jitter_phase(t, self.dg_lat_t0))
        self.metrics.record("throughput", t, processed)
        self.metrics.record("consumer_lag", t, self.lag)
        self.metrics.record("latency", t, latency)
        self.metrics.record("arrival_rate", t, lam)

        # recovery bookkeeping (ground truth: caught up == lag back to steady)
        if self._active_failure is not None and self.down_until is None:
            near_steady = self.lag <= max(2.0 * lam, 1.05 * self._steady_lag + 1.0)
            if near_steady:
                self._active_failure["t_end"] = t
                self._active_failure["recovery_s"] = t - self._active_failure["t_start"]
                self.recoveries.append(self._active_failure)
                self._active_failure = None
        elif self._active_failure is None and self.down_until is None:
            self._steady_lag = 0.9 * self._steady_lag + 0.1 * self.lag

        self.t += 1.0
        return {"t": t, "throughput": processed, "consumer_lag": self.lag,
                "latency": latency, "arrival_rate": lam}

    def _begin_degradation(self, d: Degradation) -> None:
        """Activate one gray-failure window.  Overlapping windows of the
        same kind: the newest wins (last-writer semantics, mirrored by the
        batched engine's vectorized activation)."""
        until = d.t + d.duration_s
        if d.kind == "straggler":
            self.dg_cap_scale = self.cost.straggler_capacity_scale(d.severity)
            self.dg_cap_until = until
        elif d.kind == "net_delay":
            if d.direction == "to_ckpt_store":
                self.dg_ck_delay = d.severity
                self.dg_ck_jitter = d.jitter_s
                self.dg_ck_t0 = d.t
                self.dg_ck_until = until
            else:
                self.dg_lat_delay = d.severity
                self.dg_lat_jitter = d.jitter_s
                self.dg_lat_t0 = d.t
                self.dg_lat_until = until
        else:   # backpressure
            self.dg_bp_until = until

    def _begin_failure(self, ev: FailureEvent) -> None:
        if self.down_until is not None:
            return   # already down
        self.ckpt_in_progress = None   # in-flight checkpoint dies with the job
        # roll back to the newest offset on a level that survives this
        # failure kind (ties: fastest level restores)
        surviving = self.cost.surviving_levels(self.plan, ev.kind)
        candidates = [(self.offset_by_level[l], _LEVEL_SPEED[l], l)
                      for l in surviving]
        if candidates:
            offset, _, level = max(candidates)
            # restore_duration_for folds in the delta-apply term and the
            # degraded-partial path (node failure + replicated level-2)
            restore_s = self.cost.restore_duration_for(self.plan, ev.kind,
                                                       level)
        else:
            # nothing survives: cold restart, reprocess everything
            offset, level = 0.0, None
            restore_s = self.cost.restore_duration("remote")
        # the failure destroys the levels it doesn't survive at — derived
        # from the plan's replication factor (an un-replicated plan loses
        # its local level to a node failure)
        for wiped in self.cost.wiped_levels(self.plan, ev.kind):
            if wiped in self.offset_by_level:
                self.offset_by_level[wiped] = 0.0
        self.down_until = ev.t + self.cost.detect_s + self.cost.restart_s \
            + restore_s
        self.pending_restore_offset = offset
        self._active_failure = {"t_start": ev.t, "kind": ev.kind,
                                "ci": self.policy.interval_s,
                                "restore_level": level,
                                "plan": self.plan.name}

    def run_until(self, t_end: float,
                  on_tick: Optional[Callable[[dict], None]] = None) -> None:
        while self.t < t_end:
            sample = self.tick()
            if on_tick:
                on_tick(sample)


# ---------------------------------------------------------------------------
# Phase-2 profiling deployment (implements core.profiler.Deployment)
# ---------------------------------------------------------------------------

class SimDeployment:
    """One short-lived profiling pipeline with a fixed CI.

    Replays the recording around each failure point (the paper's margin
    optimization) and measures recovery with the online-ARIMA anomaly
    detector trained on the pre-failure (positive) window.
    """

    def __init__(self, ci_s: float, recording: WorkloadRecording,
                 cost: SimCostModel, warmup_s: float = 300.0,
                 max_recovery_s: float = 7200.0):
        self.ci_s = ci_s
        self.recording = recording
        self.cost = cost
        self.warmup_s = warmup_s
        self.max_recovery_s = max_recovery_s
        self.injector = FailureInjector()

    def profile_failure(self, failure_time: float, margin: float) -> tuple[float, float]:
        """Recovery per the paper's availability definition (§III-C): from
        the failure instant until the job is producing results at the
        latest offset again.  The primary signal is CONSUMER LAG returning
        to its pre-failure envelope — directly observable at the messaging
        queue, exactly what the paper's detector watches; the online-ARIMA
        detector runs alongside and its interval is kept as a secondary
        measurement (core/anomaly.py has its own tests)."""
        t0 = max(float(self.recording.times[0]),
                 failure_time - margin - self.warmup_s)
        sim = StreamSimulator(self.cost, self.ci_s, recording=self.recording, t0=t0)
        det = AnomalyDetector()
        # worst case: just before the next checkpoint completes (§III-C)
        inject_t = self.injector.worst_case_time(
            failure_time, t0, self.ci_s, self.cost.ckpt_duration_s)
        sim.inject_failure(inject_t)

        lat_samples: list[float] = []
        lag_samples: list[float] = []
        recovery = [None]
        steady = [None]

        def on_tick(s):
            in_failure = inject_t <= s["t"] and recovery[0] is None
            det.observe(s["t"], {"throughput": s["throughput"],
                                 "consumer_lag": s["consumer_lag"]},
                        learn=not in_failure)
            if inject_t - margin <= s["t"] < inject_t:
                lat_samples.append(s["latency"])
                lag_samples.append(s["consumer_lag"])
            if s["t"] >= inject_t and steady[0] is None:
                base = np.mean(lag_samples) if lag_samples else 0.0
                steady[0] = max(2.0 * s["arrival_rate"], 1.2 * base + 1.0)
            if in_failure and s["t"] > inject_t + self.cost.detect_s:
                if s["consumer_lag"] <= steady[0]:
                    recovery[0] = s["t"] - inject_t

        t_end = inject_t + self.max_recovery_s
        while sim.t < t_end and recovery[0] is None:
            on_tick(sim.tick())
        if recovery[0] is None:
            recovery[0] = self.max_recovery_s
        # the paper averages over the 99th percentile to filter outliers; a
        # diverging deployment (capacity < arrival rate at this CI) would
        # otherwise poison M_L — use the median and cap.
        if lat_samples:
            avg_latency = float(min(np.median(lat_samples), 30.0))
        else:
            avg_latency = self.cost.base_latency_s
        return avg_latency, float(recovery[0])


# ---------------------------------------------------------------------------
# JobHandle adapter for the Khaos controller (Phase 3)
# ---------------------------------------------------------------------------

class SimJobHandle:
    """``core.controller.JobHandle`` over a running StreamSimulator — the
    complete protocol (including ``drain``/``reconfigure_plan``), so the
    controller and ``KhaosRuntime`` drive the sim and the live trainer
    identically."""

    def __init__(self, sim: StreamSimulator):
        self.sim = sim
        self.reconfigurations: list[tuple[float, float]] = []
        self.plan_changes: list[tuple[float, str]] = []

    def now(self) -> float:
        return self.sim.t

    def current_ci(self) -> float:
        return self.sim.policy.interval_s

    def current_plan(self) -> CheckpointPlan:
        return self.sim.plan

    def avg_latency(self, window_s: float) -> float:
        return self.sim.metrics.series("latency").mean_over(
            self.sim.t - window_s, self.sim.t)

    def avg_throughput(self, window_s: float) -> float:
        return self.sim.metrics.series("arrival_rate").mean_over(
            self.sim.t - window_s, self.sim.t)

    def healthy(self) -> bool:
        return self.sim.down_until is None and self.sim._active_failure is None

    def drain(self) -> None:
        """No-op by design: the simulator's reconfigure path IS a drain —
        under flink semantics ``set_ci``/``set_plan`` take a savepoint
        (checkpoint-now, no offset rollback) before restarting."""

    def reconfigure(self, new_ci: float) -> None:
        self.reconfigurations.append((self.sim.t, new_ci))
        self.sim.set_ci(new_ci)

    def reconfigure_plan(self, plan: CheckpointPlan) -> None:
        """Mechanism switch: one controlled restart applies mode + CI."""
        self.reconfigurations.append((self.sim.t, plan.interval_s))
        self.plan_changes.append((self.sim.t, plan.name))
        self.sim.set_plan(plan)
