"""Cost model for the discrete-event simulator.

Two calibration sources:
  * the paper's cluster scale (E1/E2 analogues) — defaults below;
  * a real architecture: ``costmodel_from_arch`` derives checkpoint bytes
    from the TrainState size and step capacity from the dry-run roofline
    record (bound_step_s), so the same simulator answers "what CI should a
    grok-1 training job on 2 pods use?".
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional


@dataclass(frozen=True)
class SimCostModel:
    capacity_eps: float = 3000.0      # events/s the job sustains at steady state
    base_latency_s: float = 0.45      # floor end-to-end latency
    ckpt_duration_s: float = 2.5      # sync write duration (bytes / bw)
    ckpt_sync_penalty: float = 1.0    # fraction of capacity lost while writing (sync)
    async_mode: bool = False
    async_overhead: float = 0.12      # capacity fraction lost while async write in flight
    detect_s: float = 50.0            # failure detection timeout (Flink default)
    restart_s: float = 30.0           # scheduler/restart/init time
    restore_s: float = 10.0           # state restore time
    reconfig_restart_s: float = 30.0  # controlled restart (savepoint -> restart)

    def effective_capacity(self, checkpointing: bool) -> float:
        if not checkpointing:
            return self.capacity_eps
        if self.async_mode:
            return self.capacity_eps * (1.0 - self.async_overhead)
        return self.capacity_eps * (1.0 - self.ckpt_sync_penalty)

    def downtime_s(self) -> float:
        return self.detect_s + self.restart_s + self.restore_s


def costmodel_from_arch(param_count: int, bound_step_s: float,
                        tokens_per_step: float, seq_len: int,
                        n_hosts: int = 64, disk_bw_per_host: float = 1.0e9,
                        opt_state_bytes_per_param: float = 12.0,
                        async_mode: bool = False) -> SimCostModel:
    """Calibrate the simulator for a real training job.

    * one "event" = one sequence (seq_len tokens), matching the data
      pipeline's event == document semantics;
    * capacity = sequences/s from the roofline-bound step time;
    * checkpoint duration = full TrainState over the per-host disk bw.
    """
    seqs_per_step = tokens_per_step / seq_len
    capacity = seqs_per_step / max(bound_step_s, 1e-6)
    state_bytes = param_count * opt_state_bytes_per_param
    ckpt_duration = state_bytes / (n_hosts * disk_bw_per_host)
    return SimCostModel(
        capacity_eps=capacity,
        base_latency_s=bound_step_s,
        ckpt_duration_s=max(ckpt_duration, 0.05),
        async_mode=async_mode,
        restore_s=max(ckpt_duration, 0.05),
    )
