"""Cost model for the discrete-event simulator.

Two calibration sources:
  * the paper's cluster scale (E1/E2 analogues) — defaults below;
  * a real architecture: ``costmodel_from_arch`` derives checkpoint bytes
    from the TrainState size and step capacity from the dry-run roofline
    record (bound_step_s), so the same simulator answers "what CI should a
    grok-1 training job on 2 pods use?".

The model prices the whole checkpoint *plane*, not just one write: per-kind
durations (full snapshot vs compressed delta), per-level write/restore
factors (in-RAM snapshot vs node-local disk vs durable remote store), the
async commit tax, AND the host CPU an incremental trigger burns encoding +
compressing the delta (``delta_encode_s_per_byte * state_bytes`` — on
small states the encode can exceed the write win, so an uncalibrated model
over-recommends delta plans).  Instead of hand-setting those knobs, load
them from the artifact ``benchmarks/bench_ckpt.py`` measures:

    cost = SimCostModel.from_calibration("BENCH_ckpt.json",
                                         capacity_eps=3000.0)

``write_duration``/``restore_duration``/``plan_*`` are the single source
the simulator, the plan optimizer and the controller all price a
``CheckpointPlan`` with; ``ckpt_duration_s`` remains the full-sync-local
reference point so existing calibrations keep their meaning.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, fields
from typing import Any, Optional, Union

from functools import lru_cache

import numpy as np

from repro.config import CheckpointPlan

#: required keys of the BENCH_ckpt.json calibration artifact (written by
#: benchmarks/bench_ckpt.py and validated by ``benchmarks/run.py --smoke``)
CALIBRATION_KEYS = ("schema", "state_bytes", "full_write_s", "restore_s",
                    "delta_fraction", "delta_int8_fraction",
                    "delta_encode_s_per_byte")

#: accepted artifact schemas; "bench_ckpt/2" adds the ``device`` section
#: (per-codec on-device encode measurements); "bench_ckpt/3" re-measures it
#: for the FLAT fused encode and adds ``pack_s`` (the per-trigger pack
#: dispatch) and ``per_leaf_encode_s`` (the pre-flat per-leaf dispatch
#: baseline the CI gate regresses against).  Older artifacts stay loadable:
#: /1 keeps the device fields at their modeled defaults, /2 keeps pack_s
#: at 0 (the per-leaf path had no pack step)
CALIBRATION_SCHEMAS = ("bench_ckpt/1", "bench_ckpt/2", "bench_ckpt/3")

#: per-codec keys of each ``device`` entry in a bench_ckpt/2 artifact
DEVICE_CALIBRATION_KEYS = ("bytes_on_link", "link_fraction", "encode_s")

#: additional per-codec keys a bench_ckpt/3 ``device`` entry must carry
DEVICE_CALIBRATION_KEYS_V3 = DEVICE_CALIBRATION_KEYS + (
    "pack_s", "per_leaf_encode_s")


def levels_due(plan: CheckpointPlan, trigger_index: int
               ) -> list[tuple[str, str]]:
    """Which (level, kind) writes trigger number ``trigger_index`` performs
    — the routing itself lives on the plan (``CheckpointPlan.levels_due``)
    so the manager executes and this model prices the SAME schedule.  The
    model idealizes away runtime self-healing (a delta upgraded to a full
    after an async skip or a post-failure base reset)."""
    return plan.levels_due(trigger_index)


@dataclass(frozen=True)
class SimCostModel:
    capacity_eps: float = 3000.0      # events/s the job sustains at steady state
    base_latency_s: float = 0.45      # floor end-to-end latency
    ckpt_duration_s: float = 2.5      # full sync local write duration (bytes / bw)
    ckpt_sync_penalty: float = 1.0    # fraction of capacity lost while writing (sync)
    async_mode: bool = False
    async_overhead: float = 0.12      # capacity fraction lost while async write in flight
    detect_s: float = 50.0            # failure detection timeout (Flink default)
    restart_s: float = 30.0           # scheduler/restart/init time
    restore_s: float = 10.0           # full local state restore time
    reconfig_restart_s: float = 30.0  # controlled restart (savepoint -> restart)
    # -- checkpoint-plane structure (full vs delta, per-level costs) --------
    delta_fraction: float = 0.15      # lossless delta bytes / full bytes
    delta_int8_fraction: float = 0.05 # int8 group-quantized delta fraction
    memory_write_factor: float = 0.02 # RAM snapshot vs local disk write
    remote_write_factor: float = 4.0  # durable remote store vs local disk
    memory_restore_factor: float = 0.05
    remote_restore_factor: float = 4.0
    delta_apply_factor: float = 0.25  # delta decode+apply, fraction of restore_s
    # -- measured host-CPU cost of the delta encode (calibrated) ------------
    delta_encode_s_per_byte: float = 0.0   # encode+compress CPU s per STATE byte
    state_bytes: float = 0.0               # full state size the above scales by
    # -- device-placement delta encode (plan.encode_placement == "device"):
    #    the ckpt_delta kernels run in front of D2H, so the host-CPU encode
    #    term above is replaced by the measured on-device encode+payload-
    #    transfer seconds, and bytes on the link shrink to the payload.
    #    Defaults model the payload sizes analytically (lossless: f32 delta
    #    + skipped all-zero residual ~= 1.0x; int8: q + 1/256 scales
    #    ~= 0.26x); bench_ckpt/2 artifacts replace all four with measured
    #    values
    device_link_fraction: float = 1.0       # lossless payload / state bytes
    device_link_fraction_int8: float = 0.26 # int8 payload / state bytes
    device_encode_s: float = 0.0            # per-trigger device encode (lossless)
    device_encode_s_int8: float = 0.0       # per-trigger device encode (int8)
    # the flat path's per-trigger pack dispatch (the new state's f32
    # subtree -> one mega-buffer) — measured separately from encode_s so
    # the bench can regress the fused encode against the per-leaf baseline
    # without the pack term muddying the comparison
    device_pack_s: float = 0.0              # per-trigger pack (lossless)
    device_pack_s_int8: float = 0.0         # per-trigger pack (int8)
    # -- peer-replication plane (checkpoint/replication.py) ------------------
    #    level-2 survival of a node loss is DERIVED from the plan's
    #    replication factor (k ring-peer replicas per shard), and its price
    #    has two sides: each level-2 write additionally pushes k copies of
    #    its payload over the node interconnect (replica_push_factor x the
    #    local write duration per copy — 0 models the push as fully
    #    overlapped with the primary write, the transfer-pool behavior
    #    measured on this substrate), and a node-failure restore at the
    #    local level is a DEGRADED PARTIAL restore (only the dead host's
    #    shards pulled from peers) scaled by replica_restore_factor
    #    (1.0 = neutral: same duration as a healthy local restore)
    replica_push_factor: float = 0.0
    replica_restore_factor: float = 1.0

    # 7) degradation pricing (gray failures, ft.failures.DEGRADATION_KINDS):
    #    a straggler's inflated step time hits capacity through the
    #    synchronous barrier — straggler_barrier_fraction is how much of
    #    the pipeline the slowest host gates (1.0 = fully barriered, the
    #    data-parallel default; 0.0 = fully decoupled, stragglers free);
    #    net_delay_*_factor scale how much of a directional network delay
    #    lands on the checkpoint barrier (to_ckpt_store) vs the reported
    #    end-to-end latency (to_source)
    straggler_barrier_fraction: float = 1.0
    net_delay_store_factor: float = 1.0
    net_delay_source_factor: float = 1.0

    def __post_init__(self) -> None:
        # the priced restore paths hang off the survival derivation in
        # checkpoint.multilevel; assert the mechanism-backed rule (k>=1
        # ring replicas -> node failures survive at level-2, k=0 -> they
        # degrade to remote) still matches the documented LEVEL_COVERAGE
        # table so the store substrate and the priced model cannot
        # silently diverge
        from repro.checkpoint.multilevel import (LEVEL_COVERAGE,
                                                 derived_coverage)
        assert derived_coverage(1) == LEVEL_COVERAGE == \
            {"task": "memory", "node": "local", "cluster": "remote"}, (
            f"survival derivation drifted: derived_coverage(1)="
            f"{derived_coverage(1)!r} vs LEVEL_COVERAGE={LEVEL_COVERAGE!r} "
            "— the replicated-store mechanism and this cost model price "
            "the same rule; recalibrate before relaxing it")
        assert derived_coverage(0)["node"] == "remote", (
            "with replication disabled a node failure must degrade to the "
            f"remote level, got {derived_coverage(0)!r}")

    # -- calibration ---------------------------------------------------------
    @classmethod
    def from_calibration(cls, source: Union[str, "os.PathLike[str]", dict],
                         **overrides: Any) -> "SimCostModel":
        """Build a cost model from ``benchmarks/bench_ckpt.py``'s
        ``BENCH_ckpt.json`` artifact (path or already-loaded dict),
        replacing the hand-set ``delta_fraction``/level knobs with the
        measured ones.  ``overrides`` pass through any field the artifact
        does not cover (``capacity_eps``, ``detect_s``, ...)."""
        if isinstance(source, dict):
            cal = source
        else:
            with open(source) as f:
                cal = json.load(f)
        missing = [k for k in CALIBRATION_KEYS if k not in cal]
        if missing:
            raise ValueError(f"calibration artifact missing keys {missing}")
        if cal["schema"] not in CALIBRATION_SCHEMAS:
            raise ValueError(f"unknown calibration schema {cal['schema']!r}")
        kw: dict[str, Any] = {
            "ckpt_duration_s": float(cal["full_write_s"]),
            "restore_s": float(cal["restore_s"]),
            "delta_fraction": float(cal["delta_fraction"]),
            "delta_int8_fraction": float(cal["delta_int8_fraction"]),
            "delta_encode_s_per_byte": float(cal["delta_encode_s_per_byte"]),
            "state_bytes": float(cal["state_bytes"]),
        }
        if cal["schema"] in ("bench_ckpt/2", "bench_ckpt/3"):
            dev = cal.get("device")
            if not isinstance(dev, dict):
                raise ValueError(f"{cal['schema']} artifact missing the "
                                 "'device' measurement section")
            required = (DEVICE_CALIBRATION_KEYS_V3
                        if cal["schema"] == "bench_ckpt/3"
                        else DEVICE_CALIBRATION_KEYS)
            for codec in ("lossless", "int8"):
                entry = dev.get(codec)
                bad = [k for k in required
                       if not isinstance((entry or {}).get(k), (int, float))]
                if entry is None or bad:
                    raise ValueError(
                        f"device section entry {codec!r} missing or "
                        f"non-numeric keys {bad or list(required)}")
            kw["device_link_fraction"] = float(dev["lossless"]["link_fraction"])
            kw["device_link_fraction_int8"] = float(dev["int8"]["link_fraction"])
            kw["device_encode_s"] = float(dev["lossless"]["encode_s"])
            kw["device_encode_s_int8"] = float(dev["int8"]["encode_s"])
            if cal["schema"] == "bench_ckpt/3":
                kw["device_pack_s"] = float(dev["lossless"]["pack_s"])
                kw["device_pack_s_int8"] = float(dev["int8"]["pack_s"])
        # bench_ckpt/1: device fields keep their modeled defaults (the
        # versioned fallback — old artifacts stay loadable); bench_ckpt/2:
        # pack_s stays 0 (the per-leaf path packed nothing)
        known = {f.name for f in fields(cls)}
        unknown = set(overrides) - known
        if unknown:
            raise TypeError(f"unknown SimCostModel fields {sorted(unknown)}")
        kw.update(overrides)
        return cls(**kw)

    # -- legacy single-knob interface ---------------------------------------
    def effective_capacity(self, checkpointing: bool,
                           sync: Optional[bool] = None) -> float:
        if not checkpointing:
            return self.capacity_eps
        if sync is None:
            sync = not self.async_mode
        if not sync:
            return self.capacity_eps * (1.0 - self.async_overhead)
        return self.capacity_eps * (1.0 - self.ckpt_sync_penalty)

    def downtime_s(self) -> float:
        return self.detect_s + self.restart_s + self.restore_s

    # -- degradation pricing (gray failures) --------------------------------
    # Elementwise on arrays AND exact on scalars: the scalar simulator and
    # the batched lanes call the same methods, so the priced effect is
    # bit-identical in both engines (the parity invariant).
    def straggler_capacity_scale(self, slow_factor):
        """Capacity multiplier while one host runs ``slow_factor`` x slower:
        under a barrier fraction f the effective step time inflates to
        ``1 + f*(slow_factor - 1)`` of nominal."""
        return 1.0 / (1.0 + self.straggler_barrier_fraction
                      * (np.maximum(slow_factor, 1.0) - 1.0))

    def net_delay_barrier_penalty(self, delay_s, jitter_s, phase):
        """Extra seconds a to-checkpoint-store network delay adds to one
        trigger's composite write (``phase`` = ±1 from ``jitter_phase``)."""
        return self.net_delay_store_factor * delay_s + jitter_s * phase

    def net_delay_latency_penalty(self, delay_s, jitter_s, phase):
        """Extra end-to-end latency seconds a to-source network delay adds
        at one tick (``phase`` = ±1 from ``jitter_phase``)."""
        return self.net_delay_source_factor * delay_s + jitter_s * phase

    # -- per-kind / per-level pricing ---------------------------------------
    def write_duration(self, kind: str = "full", level: str = "local",
                       encoding: str = "lossless",
                       placement: str = "host", replicas: int = 0) -> float:
        """Seconds one write of ``kind`` takes at ``level``.  A host-encoded
        delta write additionally pays the host encode+compress CPU (which
        reads the whole state regardless of how small the delta
        compresses) — priced so ``optimize_plan`` stops recommending delta
        plans whose encode exceeds the write win.  A device-encoded delta
        (``plan.encode_placement == "device"``) replaces that term with the
        measured per-trigger pack + fused on-device encode+payload-transfer
        seconds — the placement dimension the optimizer searches over.
        ``replicas`` peers each receiving a copy of a LOCAL write's payload
        add ``replica_push_factor`` x the payload-move duration per copy
        (0.0 models pushes fully overlapped with the primary write)."""
        d = self.ckpt_duration_s * {"memory": self.memory_write_factor,
                                    "local": 1.0,
                                    "remote": self.remote_write_factor}[level]
        if kind == "delta":
            d *= (self.delta_int8_fraction if encoding == "int8"
                  else self.delta_fraction)
        if level == "local" and replicas > 0:
            d += d * replicas * self.replica_push_factor
        if kind == "delta":
            if placement == "device":
                d += (self.device_pack_s_int8 + self.device_encode_s_int8
                      if encoding == "int8"
                      else self.device_pack_s + self.device_encode_s)
            else:
                d += self.delta_encode_s_per_byte * self.state_bytes
        return d

    def restore_duration(self, level: str = "local",
                         with_delta: bool = False,
                         degraded: bool = False) -> float:
        """``degraded=True`` prices the replicated store's partial restore
        (surviving shards read locally, only the dead host's shards pulled
        from peer replicas) — the level term scales by
        ``replica_restore_factor``; 1.0 keeps it at the healthy price."""
        d = self.restore_s * {"memory": self.memory_restore_factor,
                              "local": 1.0,
                              "remote": self.remote_restore_factor}[level]
        if degraded:
            d *= self.replica_restore_factor
        if with_delta:
            d += self.restore_s * self.delta_apply_factor
        return d

    def restore_duration_for(self, plan: CheckpointPlan, failure_kind: str,
                             level: str) -> float:
        """The restore price of recovering ``plan`` from ``level`` after
        ``failure_kind`` — folds in the delta-apply term (incremental
        plans) and the degraded-partial path (a node failure restoring
        from replicated level-2 pulls only the dead host's shards)."""
        with_delta = plan.mode == "incremental" and level != "memory"
        degraded = (failure_kind == "node" and level == "local"
                    and plan.effective_replication >= 1)
        return self.restore_duration(level, with_delta, degraded=degraded)

    def wiped_levels(self, plan: CheckpointPlan,
                     failure_kind: str) -> tuple[str, ...]:
        """Levels ``failure_kind`` destroys under this plan — derived from
        the same ``level_survives`` rule the store substrate implements
        (node loss wipes local disk only when no peer holds replicas)."""
        from repro.checkpoint.multilevel import _LEVELS, level_survives
        return tuple(l for l in _LEVELS
                     if not level_survives(l, failure_kind,
                                           plan.effective_replication))

    # -- plan pricing --------------------------------------------------------
    def trigger_write_duration(self, plan: CheckpointPlan,
                               trigger_index: int) -> float:
        """Total write seconds for trigger number ``trigger_index``."""
        return sum(self.write_duration(kind, level, plan.delta_codec,
                                       plan.encode_placement,
                                       replicas=plan.effective_replication)
                   for level, kind in levels_due(plan, trigger_index))

    @lru_cache(maxsize=4096)
    def avg_write_duration(self, plan: CheckpointPlan) -> float:
        """Steady-state average write seconds per checkpoint trigger.
        Memoized: both ``self`` and ``plan`` are frozen (value-hashable)
        and the cadence walk is pure, so the Eq.-8 searches that re-price
        the same variants every optimization period hit the cache."""
        period = self._cadence_period(plan)
        return sum(self.trigger_write_duration(plan, i)
                   for i in range(period)) / period

    @staticmethod
    def _cadence_period(plan: CheckpointPlan) -> int:
        import math
        return max(1, math.lcm(max(plan.full_every, 1),
                               max(plan.local_every, 1),
                               max(plan.remote_every, 1)))

    # -- link-traffic accounting (bytes_on_link, priced per trigger) ---------
    def trigger_link_bytes(self, plan: CheckpointPlan,
                           trigger_index: int) -> float:
        """Pre-compression bytes trigger ``trigger_index`` moves across the
        device->host link — the modeled twin of ``SaveReport.bytes_on_link``.
        Host placement ships the raw state every trigger (the snapshot IS
        the transfer); device placement ships only the encoded payload
        (``device_link_fraction*``), plus the raw state again whenever a
        disk level takes a FULL this trigger (remote cadence / self-heal
        fulls pull raw leaves even from a delta source)."""
        due = plan.levels_due(trigger_index)
        if plan.encode_placement != "device" \
                or plan.is_full_trigger(trigger_index):
            return self.state_bytes
        frac = (self.device_link_fraction_int8
                if plan.delta_codec == "int8" else self.device_link_fraction)
        link = self.state_bytes * frac
        if any(kind == "full" for level, kind in due if level != "memory"):
            link += self.state_bytes
        return link

    def avg_link_bytes(self, plan: CheckpointPlan) -> float:
        """Steady-state average ``bytes_on_link`` per trigger — what the
        Jayasekara-style transfer term costs in bytes under each
        (placement, codec); calibrated by the bench_ckpt/2 ``device``
        section and compared against the measured per-plan
        ``bytes_on_link_per_trigger`` in ``benchmarks/bench_ckpt.py``."""
        period = self._cadence_period(plan)
        return sum(self.trigger_link_bytes(plan, i)
                   for i in range(period)) / period

    # -- replica-traffic accounting (bytes over the node interconnect) -------
    def trigger_replica_bytes(self, plan: CheckpointPlan,
                              trigger_index: int) -> float:
        """Replica bytes trigger ``trigger_index`` pushes over the peer
        interconnect: k copies of each level-2 payload (full state, or the
        delta fraction for delta triggers) — the modeled twin of the
        replicated store's ``ReplicaStats.replica_bytes``.  Zero when the
        plan has no local level or replication is disabled."""
        k = plan.effective_replication
        if k == 0:
            return 0.0
        out = 0.0
        for level, kind in plan.levels_due(trigger_index):
            if level != "local":
                continue
            frac = 1.0 if kind == "full" else (
                self.delta_int8_fraction if plan.delta_codec == "int8"
                else self.delta_fraction)
            out += k * frac * self.state_bytes
        return out

    def avg_replica_bytes(self, plan: CheckpointPlan) -> float:
        """Steady-state average replica bytes per trigger — what the
        controller trades against recovery time when it searches the
        ``replication_factor`` plan dimension."""
        period = self._cadence_period(plan)
        return sum(self.trigger_replica_bytes(plan, i)
                   for i in range(period)) / period

    def plan_overhead_fraction(self, plan: CheckpointPlan,
                               ci_s: Optional[float] = None) -> float:
        """Steady-state fraction of capacity spent on checkpointing: the
        write duty cycle scaled by the sync pause (or the async tax over
        the write window)."""
        ci = ci_s if ci_s is not None else plan.interval_s
        duty = self.avg_write_duration(plan) / max(ci, 1e-9)
        tax = self.ckpt_sync_penalty if plan.sync else self.async_overhead
        return min(1.0, duty * tax)

    def plan_overhead_fractions(self, plan: CheckpointPlan,
                                ci_values) -> np.ndarray:
        """``plan_overhead_fraction`` vectorized over a CI grid.  The
        average write duration is CI-independent, so it is priced ONCE and
        divided across the grid — the plan optimizer sweeps grid x
        variants every re-plan, and walking the cadence period per grid
        point is what used to dominate the controller tick."""
        ci = np.maximum(np.asarray(ci_values, np.float64), 1e-9)
        tax = self.ckpt_sync_penalty if plan.sync else self.async_overhead
        return np.minimum(1.0, self.avg_write_duration(plan) / ci * tax)

    @lru_cache(maxsize=4096)
    def surviving_levels(self, plan: CheckpointPlan,
                         failure_kind: str) -> tuple[str, ...]:
        """Plan levels surviving ``failure_kind`` (fastest first), DERIVED
        from the plan's replication factor: with k>=1 ring replicas the
        level-2 store survives a node loss (the PeerReplicatedStore
        mechanism), with k=0 a node failure degrades to remote.  Raises
        ``ValueError`` on an unknown failure kind — silently defaulting
        would price a typo'd kind as an arbitrary recovery path."""
        from repro.checkpoint.multilevel import allowed_levels
        return tuple(
            l for l in allowed_levels(failure_kind,
                                      plan.effective_replication)
            if l in plan.levels)

    def restore_level(self, plan: CheckpointPlan,
                      failure_kind: str) -> Optional[str]:
        """The fastest level that survives ``failure_kind`` under the plan
        (restore walks newest-first, and faster levels are written at least
        as often as slower ones)."""
        surviving = self.surviving_levels(plan, failure_kind)
        return surviving[0] if surviving else None

    @lru_cache(maxsize=4096)
    def plan_downtime_s(self, plan: CheckpointPlan, failure_kind: str = "node"
                        ) -> float:
        level = self.restore_level(plan, failure_kind)
        if level is None:
            # nothing survives: model a cold restart at the worst price
            return self.detect_s + self.restart_s + self.restore_duration("remote")
        return (self.detect_s + self.restart_s
                + self.restore_duration_for(plan, failure_kind, level))

    @lru_cache(maxsize=4096)
    def plan_lost_work_multiplier(self, plan: CheckpointPlan,
                                  failure_kind: str = "node") -> float:
        """Lost work after a failure, as a multiple of the base CI: the
        cadence of the fastest *surviving* level (a cluster failure falls
        back to the remote level's every-Nth-trigger fulls)."""
        level = self.restore_level(plan, failure_kind)
        if level is None:
            return float("inf")
        return {"memory": 1.0, "local": float(plan.local_every),
                "remote": float(plan.remote_every)}[level]


def costmodel_from_arch(param_count: int, bound_step_s: float,
                        tokens_per_step: float, seq_len: int,
                        n_hosts: int = 64, disk_bw_per_host: float = 1.0e9,
                        opt_state_bytes_per_param: float = 12.0,
                        async_mode: bool = False) -> SimCostModel:
    """Calibrate the simulator for a real training job.

    * one "event" = one sequence (seq_len tokens), matching the data
      pipeline's event == document semantics;
    * capacity = sequences/s from the roofline-bound step time;
    * checkpoint duration = full TrainState over the per-host disk bw.
    """
    seqs_per_step = tokens_per_step / seq_len
    capacity = seqs_per_step / max(bound_step_s, 1e-6)
    state_bytes = param_count * opt_state_bytes_per_param
    ckpt_duration = state_bytes / (n_hosts * disk_bw_per_host)
    return SimCostModel(
        capacity_eps=capacity,
        base_latency_s=bound_step_s,
        ckpt_duration_s=max(ckpt_duration, 0.05),
        async_mode=async_mode,
        restore_s=max(ckpt_duration, 0.05),
    )
