"""Device-resident mega-campaigns: the jitted/vmapped third engine.

The sim package is a three-engine hierarchy (ROADMAP item 4):

  1. ``StreamSimulator`` — the scalar ORACLE.  One job, one Python tick
     loop, statement-level readability.  Ground truth for semantics.
  2. ``BatchedCampaign`` — the NumPy LANE engine.  N jobs as array lanes,
     one fused NumPy tick, bit-exact against the oracle (~27x scalar).
     Ground truth for the vectorized tick ORDER.
  3. ``DeviceCampaign`` (this module) — the DEVICE engine.  The same fused
     tick traced once into a jitted ``lax.fori_loop`` program and executed
     on the accelerator: struct-of-arrays lane state lives in device
     buffers, λ(t) is the ``dense_rates`` precompute uploaded once
     (deduplicated by shared rate array), plan/cost scalars are gathered
     per lane from the packed ``_PlanTable`` parameter tables, per-lane
     branches become ``lax.while_loop``/masked ``where`` updates, and lag
     history comes back in chunked device→host readbacks instead of
     per-tick row writes.

Each engine is authoritative one level down: the scalar oracle defines
WHAT a tick does, the NumPy engine defines the floating-point ORDER of
the batched tick, and the device engine must reproduce that order
bit-exactly (``tests/test_device_campaign.py`` asserts
``assert_array_equal`` parity across plans, crash kinds, degradation
kinds, and mid-run plan switches).  Use the scalar for semantics work,
the NumPy engine for moderate grids and as the parity reference, and the
device engine for mega-campaigns (10^5+ lanes) and exhaustive plan
sweeps (``optimize_plan(..., exhaustive=True, engine="device")``).

``DeviceCampaign`` subclasses ``BatchedCampaign``: construction, lane
actuation (``lane_set_ci``/``lane_set_plan``), compaction, handles, and
every result surface reuse the host-side code; only ``run`` is replaced
by a device-chunk advance that syncs the full lane state host<->device at
chunk boundaries.  Between chunks the host state is exactly what the
NumPy engine would hold, so ``drive_campaign`` controllers actuate lanes
mid-run without knowing which engine is underneath.

Bit-exactness on CPU requires one backend flag.  XLA:CPU keeps f64
multiply-adds as separate HLO ops, but LLVM contracts them into FMAs on
FMA-capable ISAs (AVX2+), producing 1-ULP divergences from NumPy in
chains like ``0.9*s + 0.1*lag`` (neither ``optimization_barrier`` nor
``--xla_cpu_enable_fast_math=false`` prevents the contraction).
``--xla_cpu_max_isa=AVX`` pins codegen to a pre-FMA ISA and restores
bit-exact parity; ``ensure_bitexact_cpu()`` appends it to ``XLA_FLAGS``
(it must run before the first backend initialization — importing this
module is enough when nothing has touched jax yet), and
``fma_contraction_active()`` probes whether contraction is still on so
benchmarks can report parity honestly.
"""
from __future__ import annotations

import os
from functools import lru_cache
from typing import Optional, Sequence

import numpy as np

from repro.sim.batched import (_DEG_ID, _DIR_ID, BatchedCampaign, LaneSpec)
from repro.sim.costmodel import SimCostModel

_ISA_FLAG = "--xla_cpu_max_isa=AVX"


def ensure_bitexact_cpu() -> None:
    """Append ``--xla_cpu_max_isa=AVX`` to ``XLA_FLAGS`` if absent.

    Only effective before the first XLA backend initialization (the env
    var is read lazily at first computation); call it as early as the
    process allows — tests do it in conftest, benchmarks at driver start.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_cpu_max_isa" not in flags:
        os.environ["XLA_FLAGS"] = (flags + " " + _ISA_FLAG).strip()


ensure_bitexact_cpu()

import jax                                                          # noqa: E402
import jax.numpy as jnp                                             # noqa: E402
from jax import lax                                                 # noqa: E402
from jax.experimental import enable_x64                             # noqa: E402


def fma_contraction_active() -> bool:
    """True when jitted f64 mul-add chains still diverge from NumPy (the
    ISA pin did not take, e.g. a backend was initialized first)."""
    rng = np.random.default_rng(0)
    a = rng.random(256)
    b = rng.random(256)
    with enable_x64():
        jv = np.asarray(jax.jit(lambda x, y: 0.9 * x + 0.1 * y)(
            jnp.asarray(a), jnp.asarray(b)))
    return not np.array_equal(0.9 * a + 0.1 * b, jv)


#: per-lane read-only inputs (may change between chunks via actuation)
_LANE_CONST = ("interval", "plan_id", "_period", "_mu_ck", "lane_ticks")
_FAIL_CONST = ("fail_t", "fail_kind")
_DEG_CONST = ("deg_t", "deg_kind", "deg_dur", "deg_sev", "deg_jit",
              "deg_dir")


def _carry_partition(any_deg: bool, has_fail: bool, track_af: bool
                     ) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """Split the per-lane state into (carried, read-only-const) names for
    one chunk configuration.  Every array the loop body passes through
    unchanged costs XLA:CPU a per-tick buffer copy, so state a given
    configuration cannot mutate rides as a constant input instead (or is
    dropped entirely when it cannot even be read)."""
    carried = ["t", "lag", "produced", "consumed", "processed_total",
               "pol_last", "off_lvl", "last_off", "ck_active", "ck_end",
               "ck_off", "ck_lvls", "ckpt_count", "save_count", "down",
               "steady_lag"]
    consts: list[str] = []
    if has_fail:
        carried += ["down_until", "pending_ro", "fptr", "_next_fail",
                    "af_t0", "af_kind", "af_ci", "af_level"]
    else:
        consts += ["down_until", "pending_ro"]
        if track_af:
            consts += ["af_t0", "af_kind", "af_ci", "af_level"]
    if track_af:
        carried += ["af_active", "_rec_t_start", "_rec_kind", "_rec_ci",
                    "_rec_level", "_rec_t_end", "_rec_count"]
    if any_deg:
        carried += ["dptr", "_next_deg", "dg_cap_scale", "dg_cap_until",
                    "dg_ck_delay", "dg_ck_jitter", "dg_ck_t0",
                    "dg_ck_until", "dg_lat_delay", "dg_lat_jitter",
                    "dg_lat_t0", "dg_lat_until", "dg_bp_until",
                    "bp_suppressed"]
    return tuple(carried), tuple(consts)

_DEG_STRAGGLER = _DEG_ID["straggler"]
_DEG_NET = _DEG_ID["net_delay"]
_DEG_BP = _DEG_ID["backpressure"]
_DIR_STORE = _DIR_ID["to_ckpt_store"]
_DIR_SOURCE = _DIR_ID["to_source"]


@lru_cache(maxsize=32)
def _chunk_fn(hist_rows: int, any_deg: bool, has_fail: bool,
              lat_extra: bool, track_af: bool):
    """Compile one device-chunk program: ``hist_rows`` (static) rows of lag
    history per call (0 = no recording), tick count ``n`` traced.  The tick
    body mirrors ``BatchedCampaign._step`` statement-for-statement in the
    same floating-point order; every structural `if` below is a STATIC
    configuration switch, never per-lane control flow."""
    carried, ro_consts = _carry_partition(any_deg, has_fail, track_af)
    carried_set = frozenset(carried)

    def phase(t, t0):
        # ft.failures.jitter_phase, traced (np.where on tracers would fail)
        return jnp.where((t - t0) % 2.0 < 1.0, 1.0, -1.0)

    def begin_failure(s, c, mask, kind, ev_t):
        # BatchedCampaign._begin_failure (the early-return on an empty mask
        # is a no-op: every write below is masked by `act`)
        act = mask & ~s["down"]
        ck_active = s["ck_active"] & ~act
        pid = c["plan_id"]
        surv = c["surviving"][pid, kind]
        offs = jnp.where(surv, s["off_lvl"], -jnp.inf)
        best = offs.max(axis=1)
        has = surv.any(axis=1)
        lvl = jnp.argmax(offs == best[:, None], axis=1)
        restore = jnp.where(has, c["restore_dur"][pid, kind, lvl],
                            c["cold_restore"][pid])
        offset = jnp.where(has, best, 0.0)
        wipe = c["wipes"][pid, kind]
        return dict(
            s, ck_active=ck_active,
            off_lvl=jnp.where(act[:, None] & wipe, 0.0, s["off_lvl"]),
            down_until=jnp.where(
                act, ev_t + c["detect_s"] + c["restart_s"] + restore,
                s["down_until"]),
            pending_ro=jnp.where(act, offset, s["pending_ro"]),
            down=s["down"] | act,
            af_active=s["af_active"] | act,
            af_t0=jnp.where(act, ev_t, s["af_t0"]),
            af_kind=jnp.where(act, kind, s["af_kind"]),
            af_ci=jnp.where(act, c["interval"], s["af_ci"]),
            af_level=jnp.where(act, jnp.where(has, lvl, -1), s["af_level"]))

    def begin_degradation(s, c, mask, cur):
        # BatchedCampaign._begin_degradation (kind-specific masked writes)
        ar = jnp.arange(cur.shape[0])
        kind = c["deg_kind"][ar, cur]
        ev_t = c["deg_t"][ar, cur]
        until = ev_t + c["deg_dur"][ar, cur]
        sev = c["deg_sev"][ar, cur]
        jit = c["deg_jit"][ar, cur]
        dirn = c["deg_dir"][ar, cur]
        m = mask & (kind == _DEG_STRAGGLER)
        scale = 1.0 / (1.0 + c["sbf"] * (jnp.maximum(sev, 1.0) - 1.0))
        out = dict(s,
                   dg_cap_scale=jnp.where(m, scale, s["dg_cap_scale"]),
                   dg_cap_until=jnp.where(m, until, s["dg_cap_until"]))
        nd = mask & (kind == _DEG_NET)
        m = nd & (dirn == _DIR_STORE)
        out.update(dg_ck_delay=jnp.where(m, sev, s["dg_ck_delay"]),
                   dg_ck_jitter=jnp.where(m, jit, s["dg_ck_jitter"]),
                   dg_ck_t0=jnp.where(m, ev_t, s["dg_ck_t0"]),
                   dg_ck_until=jnp.where(m, until, s["dg_ck_until"]))
        m = nd & (dirn == _DIR_SOURCE)
        out.update(dg_lat_delay=jnp.where(m, sev, s["dg_lat_delay"]),
                   dg_lat_jitter=jnp.where(m, jit, s["dg_lat_jitter"]),
                   dg_lat_t0=jnp.where(m, ev_t, s["dg_lat_t0"]),
                   dg_lat_until=jnp.where(m, until, s["dg_lat_until"]))
        m = mask & (kind == _DEG_BP)
        out.update(dg_bp_until=jnp.where(m, until, s["dg_bp_until"]))
        return out

    def chunk(s, c, k0, n):
        rates_u, rate_col = c["rates_u"], c["rate_col"]
        lane_ticks = c["lane_ticks"]
        n_act = rate_col.shape[0]
        Kf = c["fail_t"].shape[1] if has_fail else 0
        Kd = c["deg_t"].shape[1] if any_deg else 0
        R = s["_rec_t_start"].shape[1] if track_af else 0
        ar = jnp.arange(n_act)

        def tick(i, carry):
            st, hist, lat = carry

            def get(name):
                # carried state from the loop carry, frozen state from the
                # constant inputs (static per configuration)
                return st[name] if name in carried_set else c[name]

            k = k0 + i
            t = st["t"]
            alive = k < lane_ticks
            lam = jnp.where(alive, rates_u[k][rate_col], 0.0)
            st = dict(st, produced=st["produced"] + lam)

            if has_fail:
                def f_cond(s2):
                    return jnp.any((s2["_next_fail"] <= t) & alive)

                def f_body(s2):
                    pend = (s2["_next_fail"] <= t) & alive
                    cur = jnp.minimum(s2["fptr"], Kf - 1)
                    s2 = begin_failure(s2, c, pend, c["fail_kind"][ar, cur],
                                       s2["_next_fail"])
                    fptr = jnp.where(pend, s2["fptr"] + 1, s2["fptr"])
                    nxt = jnp.minimum(fptr, Kf - 1)
                    nf = jnp.where(fptr < Kf, c["fail_t"][ar, nxt], jnp.inf)
                    return dict(s2, fptr=fptr, _next_fail=nf)

                st = lax.while_loop(f_cond, f_body, st)

            if any_deg:
                def d_cond(s2):
                    return jnp.any((s2["_next_deg"] <= t) & alive)

                def d_body(s2):
                    pend = (s2["_next_deg"] <= t) & alive
                    cur = jnp.minimum(s2["dptr"], Kd - 1)
                    s2 = begin_degradation(s2, c, pend, cur)
                    dptr = jnp.where(pend, s2["dptr"] + 1, s2["dptr"])
                    nxt = jnp.minimum(dptr, Kd - 1)
                    ndg = jnp.where(dptr < Kd, c["deg_t"][ar, nxt], jnp.inf)
                    return dict(s2, dptr=dptr, _next_deg=ndg)

                st = lax.while_loop(d_cond, d_body, st)

            # down lanes accumulate lag; restart rolls back to the offset
            down_pre = st["down"]
            lag = jnp.where(alive & down_pre, st["lag"] + lam, st["lag"])
            restart = alive & down_pre & (t >= get("down_until"))
            rb = restart & (get("pending_ro") < st["consumed"])
            lag = jnp.where(rb, lag + (st["consumed"] - get("pending_ro")),
                            lag)
            consumed = jnp.where(rb, get("pending_ro"), st["consumed"])
            down = down_pre & ~restart
            pol_last = jnp.where(restart, t, st["pol_last"])
            # a lane restarting this tick stays out of processing (the
            # NumPy `up` is taken before the restart clears `down`)
            up = alive & ~down_pre

            # checkpoint completion
            comp = up & st["ck_active"] & (t >= st["ck_end"])
            off = st["ck_off"]
            off_lvl = jnp.where(comp[:, None] & st["ck_lvls"], off[:, None],
                                st["off_lvl"])
            last_off = jnp.where(comp, jnp.maximum(st["last_off"], off),
                                 st["last_off"])
            ckpt_count = st["ckpt_count"] + comp
            ck_active = st["ck_active"] & ~comp

            # checkpoint start
            due = up & (t - pol_last >= c["interval"]) & ~ck_active
            if any_deg:
                bp = due & (t < st["dg_bp_until"])
                bp_suppressed = st["bp_suppressed"] + bp
                due = due & ~bp
            idx = st["save_count"] % c["_period"]
            save_count = st["save_count"] + due
            dur = c["trig_dur"][c["plan_id"], idx]
            if any_deg:
                ckd = t < st["dg_ck_until"]
                pen = c["store_f"] * st["dg_ck_delay"] \
                    + st["dg_ck_jitter"] * phase(t, st["dg_ck_t0"])
                dur = dur + jnp.where(ckd, pen, 0.0)
            ck_end = jnp.where(due, t + dur, st["ck_end"])
            ck_off = jnp.where(due, consumed, st["ck_off"])
            ck_lvls = jnp.where(due[:, None],
                                c["trig_lvls"][c["plan_id"], idx],
                                st["ck_lvls"])
            ck_active = ck_active | due
            pol_last = jnp.where(due, t, pol_last)

            # capacity + processing
            checkpointing = up & ck_active
            if any_deg:
                reset = up & (t >= st["dg_cap_until"])
                dg_cap_scale = jnp.where(reset, 1.0, st["dg_cap_scale"])
                mu = jnp.where(checkpointing, c["_mu_ck"], c["eps"]) \
                    * dg_cap_scale
            else:
                mu = jnp.where(checkpointing, c["_mu_ck"], c["eps"])
            inflow = lag + lam
            processed = jnp.where(up, jnp.minimum(inflow, mu), 0.0)
            lag = jnp.where(up, jnp.maximum(0.0, inflow - processed), lag)
            consumed = consumed + processed
            processed_total = st["processed_total"] + processed

            if hist_rows:
                # the NumPy step skips the row write entirely when no lane
                # is alive (leaving the zero initialization in place)
                any_alive = jnp.any(alive)
                hist = hist.at[i].set(jnp.where(any_alive, lag, 0.0))
                if lat_extra:
                    la = alive & (t < st["dg_lat_until"])
                    pen = jnp.where(
                        la, c["src_f"] * st["dg_lat_delay"]
                        + st["dg_lat_jitter"] * phase(t, st["dg_lat_t0"]),
                        0.0)
                    lat = lat.at[i].set(pen)

            # recovery bookkeeping (records scattered into bounded per-lane
            # slots; the host materializes dicts after the chunk)
            settled = alive & ~down          # post-restart down
            st = dict(
                st, t=jnp.where(alive, t + 1.0, t), lag=lag,
                consumed=consumed, processed_total=processed_total,
                pol_last=pol_last, down=down, off_lvl=off_lvl,
                last_off=last_off, ck_active=ck_active, ck_end=ck_end,
                ck_off=ck_off, ck_lvls=ck_lvls, ckpt_count=ckpt_count,
                save_count=save_count)
            if any_deg:
                st.update(bp_suppressed=bp_suppressed,
                          dg_cap_scale=dg_cap_scale)
            if track_af:
                env = lag <= jnp.maximum(2.0 * lam,
                                         1.05 * st["steady_lag"] + 1.0)
                af_active = st["af_active"]
                upd = settled & ~af_active
                near = af_active & settled & env
                j = jnp.minimum(st["_rec_count"], R - 1)
                # one-hot masked writes, NOT .at[].set: XLA:CPU lowers
                # scatter to a serial row loop (~90x slower than an
                # elementwise pass)
                slot = (jnp.arange(R)[None, :] == j[:, None]) & near[:, None]

                def rec_set(arr, val):
                    return jnp.where(slot, val[:, None], arr)

                st.update(
                    af_active=af_active & ~near,
                    _rec_t_start=rec_set(st["_rec_t_start"], get("af_t0")),
                    _rec_kind=rec_set(st["_rec_kind"], get("af_kind")),
                    _rec_ci=rec_set(st["_rec_ci"], get("af_ci")),
                    _rec_level=rec_set(st["_rec_level"], get("af_level")),
                    _rec_t_end=rec_set(st["_rec_t_end"], t),
                    _rec_count=st["_rec_count"] + near)
            else:
                upd = settled
            st["steady_lag"] = jnp.where(
                upd, 0.9 * st["steady_lag"] + 0.1 * lag, st["steady_lag"])
            return (st, hist, lat)

        hist0 = jnp.zeros((hist_rows, n_act))
        lat0 = jnp.zeros((hist_rows if lat_extra else 0, n_act))
        return lax.fori_loop(0, n, tick, (s, hist0, lat0))

    return jax.jit(chunk)


class DeviceCampaign(BatchedCampaign):
    """``BatchedCampaign`` advanced by the jitted device program.

    Construction, per-lane actuation, compaction, handles, and all result
    surfaces are inherited; ``run`` advances the lane state in device
    chunks that are bit-exact with the corresponding number of NumPy
    ``_step`` calls, syncing the full host state at every chunk boundary
    (so mid-run ``lane_set_ci``/``lane_set_plan`` between ``run`` calls
    behave identically to the NumPy engine).

    ``compact_every`` defaults to 0 here: compaction changes the active
    lane count, which forces an XLA retrace per new shape.  It remains
    fully supported (pass a nonzero value) for long mixed-horizon runs
    where the retrace amortizes.

    ``history_chunk_bytes`` bounds the device-side lag-history buffer; a
    recording campaign advances in ``history_chunk_bytes / (8 * n_lanes)``
    -tick chunks and copies each chunk's rows back to the host history
    matrix in one readback.
    """

    _PER_LANE = BatchedCampaign._PER_LANE + (
        "_rec_t_start", "_rec_kind", "_rec_ci", "_rec_level", "_rec_t_end",
        "_rec_count", "_rec_seen")

    def __init__(self, cost: SimCostModel, lanes: Sequence[LaneSpec],
                 record_history: bool = True, flink_semantics: bool = True,
                 early_exit: bool = False, compact_every: int = 0,
                 history_chunk_bytes: int = 64 << 20):
        super().__init__(cost, lanes, record_history=record_history,
                         flink_semantics=flink_semantics,
                         early_exit=early_exit, compact_every=compact_every)
        N = self.n_lanes
        R = max(1, self._n_fail)
        self._rec_t_start = np.zeros((N, R))
        self._rec_kind = np.zeros((N, R), dtype=np.int64)
        self._rec_ci = np.zeros((N, R))
        self._rec_level = np.full((N, R), -1, dtype=np.int64)
        self._rec_t_end = np.zeros((N, R))
        self._rec_count = np.zeros(N, dtype=np.int64)
        self._rec_seen = np.zeros(N, dtype=np.int64)
        # λ columns deduplicated by shared rate array: lanes built from one
        # recording all point at the same dense_rates precompute, so the
        # big (T, W) upload holds W unique columns, not N
        col_of: dict[int, int] = {}
        firsts: list[int] = []
        self._rate_col_all = np.zeros(N, dtype=np.int64)
        for i, l in enumerate(self.lanes):
            w = col_of.setdefault(id(l.rates), len(col_of))
            self._rate_col_all[i] = w
            if w == len(firsts):
                firsts.append(i)
        self._rates_u = np.ascontiguousarray(self._rates_tm[:, firsts])
        self._rates_dev = None
        if record_history:
            rows = int(history_chunk_bytes) // (8 * max(1, N))
            self._hist_rows = max(16, min(self.horizon, rows))
        else:
            self._hist_rows = 0

    # -- device advance -------------------------------------------------
    def run(self, n_ticks: Optional[int] = None) -> "DeviceCampaign":
        end = self.horizon if n_ticks is None \
            else min(self.horizon, self._step_idx + n_ticks)
        ce = self.compact_every
        while self._step_idx < end and self._active.size:
            stop = min(end, ((self._step_idx // ce) + 1) * ce) if ce else end
            left = stop - self._step_idx
            while left > 0:
                c = min(left, self._hist_rows) if self._hist_rows else left
                self._device_chunk(c)
                left -= c
            if ce and self._step_idx % ce == 0:
                self._maybe_compact()
        if self.done:
            self._finalize()
        return self

    def _device_chunk(self, n: int) -> None:
        has_fail = bool(np.isfinite(self._next_fail).any())
        lat_extra = self._lat_extra_tm is not None
        # recovery tracking is needed only while a failure can still fire
        # or a recovery is in flight — the common no-failure throughput
        # configuration then carries no af/rec state at all
        track_af = has_fail or bool(self.af_active.any())
        carried, ro_consts = _carry_partition(self._any_deg, has_fail,
                                              track_af)
        fn = _chunk_fn(self._hist_rows, self._any_deg, has_fail, lat_extra,
                       track_af)
        cost = self.cost
        with enable_x64():
            if self._rates_dev is None:
                self._rates_dev = jnp.asarray(self._rates_u)
            const_names = _LANE_CONST + ro_consts
            if has_fail:
                const_names += _FAIL_CONST
            if self._any_deg:
                const_names += _DEG_CONST
            c = {name: jnp.asarray(getattr(self, name))
                 for name in const_names}
            c.update(
                rates_u=self._rates_dev,
                rate_col=jnp.asarray(self._rate_col_all[self._active]),
                trig_dur=jnp.asarray(self.table.trig_dur),
                trig_lvls=jnp.asarray(self.table.trig_lvls),
                eps=jnp.float64(cost.capacity_eps),
                sbf=jnp.float64(cost.straggler_barrier_fraction),
                store_f=jnp.float64(cost.net_delay_store_factor),
                src_f=jnp.float64(cost.net_delay_source_factor))
            if has_fail:
                c.update(
                    restore_dur=jnp.asarray(self.table.restore_dur),
                    cold_restore=jnp.asarray(self.table.cold_restore),
                    surviving=jnp.asarray(self.table.surviving),
                    wipes=jnp.asarray(self.table.wipes),
                    detect_s=jnp.float64(cost.detect_s),
                    restart_s=jnp.float64(cost.restart_s))
            s = {name: jnp.asarray(getattr(self, name)) for name in carried}
            s, hist, lat = fn(s, c, self._step_idx, n)
            # np.array (not asarray): device buffers come back read-only,
            # and host-side actuation/compaction mutates these in place
            out = {name: np.array(s[name]) for name in carried}
        for name, arr in out.items():
            setattr(self, name, arr)
        if self._hist_rows:
            k0 = self._step_idx
            rows = np.asarray(hist)[:n]
            if self._final is None:
                self._lag_hist_tm[k0:k0 + n] = rows
            else:
                self._lag_hist_tm[k0:k0 + n, self._active] = rows
            if lat_extra:
                lrows = np.asarray(lat)[:n]
                if self._final is None:
                    self._lat_extra_tm[k0:k0 + n] = lrows
                else:
                    self._lat_extra_tm[k0:k0 + n, self._active] = lrows
        self._step_idx += n
        self._materialize_recoveries()

    def _materialize_recoveries(self) -> None:
        """Append recovery dicts for records the device scattered since the
        last chunk (same shape as the NumPy engine's in-loop appends; done
        before any compaction so retiring lanes never strand records)."""
        from repro.sim.batched import KINDS, LEVELS
        new = np.flatnonzero(self._rec_count > self._rec_seen)
        for i in new:
            oi = int(self._active[i])
            for j in range(int(self._rec_seen[i]), int(self._rec_count[i])):
                lvl = int(self._rec_level[i, j])
                t_end = float(self._rec_t_end[i, j])
                t_start = float(self._rec_t_start[i, j])
                self.recoveries[oi].append({
                    "t_start": t_start,
                    "kind": KINDS[int(self._rec_kind[i, j])],
                    "ci": float(self._rec_ci[i, j]),
                    "restore_level": LEVELS[lvl] if lvl >= 0 else None,
                    "plan": self.lane_plan_name[oi],
                    "t_end": t_end,
                    "recovery_s": float(t_end - t_start),
                })
            self._rec_seen[i] = self._rec_count[i]
