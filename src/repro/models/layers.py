"""Model-zoo primitives: norms, rotary embeddings, attention (full /
chunked-flash XLA / decode), GLU FFNs, scatter-based MoE, RG-LRU and RWKV-6
mixers, in pure JAX (params are nested dicts; apply fns are functional).

Conventions
-----------
* activations: (B, S, d) in ``cfg.dtype`` (bf16 by default)
* attention heads: q (B, S, H, hd); k/v (B, S, K, hd); G = H // K
* softmax / recurrences / norms accumulate in fp32
* every ``init_*`` returns a params dict; every ``apply`` is pure
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig

Params = Any


class NullAnnotator:
    """No-op activation-sharding annotator (single-device tests)."""
    dp_size: int = 1
    moe_groups: int = 1

    def constrain(self, x, kind: str):
        return x


NULL_ANN = NullAnnotator()


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _normal(key, shape, std, dtype):
    return (std * jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dim: Optional[int] = None) -> Params:
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), _pdtype(cfg))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), _pdtype(cfg))
    return p


def apply_norm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int, theta: float,
                mrope_sections: Optional[tuple[int, ...]] = None) -> jax.Array:
    """positions: (B, S) or (3, B, S) for M-RoPE -> angles (B, S, head_dim//2)."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) / half))
    inv_freq = jnp.asarray(inv_freq)
    if mrope_sections is None:
        pos = positions.astype(jnp.float32)
        return pos[..., None] * inv_freq[None, None, :]
    # M-RoPE: frequency slots are split into (t, h, w) sections, each taking
    # its position id from the corresponding plane of ``positions`` (3,B,S).
    assert positions.ndim == 3 and positions.shape[0] == len(mrope_sections)
    parts = []
    start = 0
    for sec_idx, sec in enumerate(mrope_sections):
        pos = positions[sec_idx].astype(jnp.float32)          # (B, S)
        parts.append(pos[..., None] * inv_freq[None, None, start:start + sec])
        start += sec
    assert start == half, "mrope sections must sum to head_dim//2"
    return jnp.concatenate(parts, axis=-1)


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (B, S, N, hd), angles: (B, S, hd//2) — half-split (llama) convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig) -> Params:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    std = 0.02
    p = {
        "wq": _normal(ks[0], (d, H, hd), std, _pdtype(cfg)),
        "wk": _normal(ks[1], (d, K, hd), std, _pdtype(cfg)),
        "wv": _normal(ks[2], (d, K, hd), std, _pdtype(cfg)),
        "wo": _normal(ks[3], (H, hd, d), std / math.sqrt(2 * cfg.num_layers), _pdtype(cfg)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), _pdtype(cfg))
        p["bk"] = jnp.zeros((K, hd), _pdtype(cfg))
        p["bv"] = jnp.zeros((K, hd), _pdtype(cfg))
    return p


def _qkv(p: Params, x: jax.Array, cfg: ModelConfig):
    dt = x.dtype
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def _out_proj(p: Params, o: jax.Array, dt) -> jax.Array:
    return jnp.einsum("bsnh,nhd->bsd", o, p["wo"].astype(dt))


def _mask_bias(q_pos, k_pos, causal: bool, window: int) -> jax.Array:
    """(..., Q, KV) additive fp32 bias: 0 allowed / -inf masked."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    allow = (kp <= qp) if causal else jnp.full(jnp.broadcast_shapes(qp.shape, kp.shape), True)
    if window > 0:
        allow = allow & (qp - kp < window)
    return jnp.where(allow, 0.0, -1e30).astype(jnp.float32)


def _softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


def full_attention(q, k, v, *, causal: bool, window: int = 0,
                   q_offset: int = 0, softcap: float = 0.0) -> jax.Array:
    """Reference full attention; q/k/v: (B, S, H, hd) (KV already repeated
    to H heads — see ``attention_sequence``)."""
    B, Sq, H, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqnh,bsnh->bnqs", q, k).astype(jnp.float32) * scale
    logits = _softcap(logits, softcap)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(k.shape[1])
    logits = logits + _mask_bias(q_pos, k_pos, causal, window)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bnqs,bsnh->bqnh", w, v)


def flash_attention_xla(q, k, v, *, causal: bool, window: int = 0,
                        chunk_q: int = 512, chunk_kv: int = 1024,
                        softcap: float = 0.0) -> jax.Array:
    """Memory-bounded chunked attention with running softmax (pure XLA).

    q/k/v: (B, S, H, hd), heads TP-shardable.  Outer scan over q chunks
    (each remat'd so the bwd never keeps softmax probabilities for more
    than one block pair), inner scan over kv chunks; fp32 accumulators.
    Working set per step is (Cq x Ckv) — never materializes (S x S).
    """
    B, S, H, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    Cq = min(chunk_q, S)
    Ckv = min(chunk_kv, k.shape[1])
    nq = S // Cq
    nkv = k.shape[1] // Ckv
    assert S % Cq == 0 and k.shape[1] % Ckv == 0, "seq not divisible by chunks"

    qg = q.reshape(B, nq, Cq, H, hd)
    kg = k.reshape(B, nkv, Ckv, H, hd)
    vg = v.reshape(B, nkv, Ckv, H, hd)

    def q_block(qi, qc, kg, vg):  # qc: (B, Cq, H, hd)
        q_pos = qi * Cq + jnp.arange(Cq)

        def kv_block(carry, inputs):
            m, l, acc = carry
            kj, kc, vc = inputs
            k_pos = kj * Ckv + jnp.arange(Ckv)
            s = jnp.einsum("bqnh,bsnh->bnqs", qc, kc).astype(jnp.float32) * scale
            s = _softcap(s, softcap)
            s = s + _mask_bias(q_pos, k_pos, causal, window)
            m_new = jnp.maximum(m, s.max(-1))
            corr = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bnqs,bsnh->bnqh", p.astype(qc.dtype), vc).astype(jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, Cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, Cq), jnp.float32)
        a0 = jnp.zeros((B, H, Cq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (jnp.arange(nkv), kg.swapaxes(0, 1), vg.swapaxes(0, 1)))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return o.astype(q.dtype)  # (B, H, Cq, hd)

    q_block = jax.checkpoint(q_block, static_argnums=())

    def scan_q(_, inputs):
        qi, qc = inputs
        return None, q_block(qi, qc, kg, vg)

    _, oq = jax.lax.scan(scan_q, None, (jnp.arange(nq), qg.swapaxes(0, 1)))
    # oq: (nq, B, H, Cq, hd) -> (B, S, H, hd)
    o = oq.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hd)
    return o


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     softcap: float = 0.0) -> jax.Array:
    """Single-token decode: q (B, 1, H, hd), caches (B, Smax, K, hd).

    ``pos`` (B,) is the index of the *current* token (its K/V already
    written); entries with k_pos > pos are masked.
    """
    B, _, H, hd = q.shape
    Smax, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, K, G, hd)
    assert k_cache.dtype != jnp.int8, "dequantize int8 KV before decode_attention"
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32) * scale
    s = _softcap(s, softcap)
    k_pos = jnp.arange(Smax)[None, :]
    allow = k_pos <= pos[:, None]
    if window > 0:
        allow = allow & (pos[:, None] - k_pos < window)
    s = s + jnp.where(allow, 0.0, -1e30)[:, None, None, :]
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgs,bskh->bkgh", w, v_cache)
    return o.reshape(B, 1, H, hd)


def attention_sequence(p: Params, x: jax.Array, cfg: ModelConfig, *,
                       positions: jax.Array, causal: bool = True,
                       window: int = 0, kv_override=None,
                       return_kv: bool = False, ann=NULL_ANN):
    """Attention over a full sequence (train / prefill).

    GQA KV is repeated up to H heads before the score einsum so the head
    dim shards cleanly over the TP axis even when num_kv_heads < tp (the
    repeat is a gather; FLOPs are identical to the grouped einsum).
    ``return_kv`` returns the *un-repeated* K/V for the KV cache.

    kv_override: (k, v, kv_angles) for cross-attention (whisper decoder;
    no RoPE applied on either side).
    """
    dt = x.dtype
    if kv_override is None:
        q, k, v = _qkv(p, x, cfg)
        angles = rope_angles(positions, cfg.resolved_head_dim, cfg.rope_theta,
                             tuple(cfg.mrope_sections) if cfg.mrope_sections else None)
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    else:
        q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(dt))
        if "bq" in p:
            q = q + p["bq"].astype(dt)
        k, v, _ = kv_override

    kv_out = (k, v)
    G = cfg.num_heads // k.shape[2]
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    q = ann.constrain(q, "heads")
    k = ann.constrain(k, "heads")
    v = ann.constrain(v, "heads")

    S = x.shape[1]
    use_flash = cfg.attn_impl in ("xla_chunked", "pallas") and S > cfg.attn_chunk_q \
        and S % cfg.attn_chunk_q == 0 and k.shape[1] % cfg.attn_chunk_kv == 0
    if use_flash:
        o = flash_attention_xla(q, k, v, causal=causal, window=window,
                                chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
                                softcap=cfg.attn_logit_softcap)
    else:
        o = full_attention(q, k, v, causal=causal, window=window,
                           softcap=cfg.attn_logit_softcap)
    out = _out_proj(p, o, dt)
    if return_kv:
        return out, kv_out
    return out


def attention_decode_step(p: Params, x: jax.Array, cfg: ModelConfig, *,
                          pos: jax.Array, k_cache, v_cache,
                          window: int = 0, cross_kv=None):
    """One-token decode. x: (B, 1, d); pos: (B,) current position.

    Returns (out, (k_cache, v_cache)) with the new K/V written at ``pos``
    (ring-buffer write when ``window`` > 0 and the cache holds only the
    window).
    """
    dt = x.dtype
    if cross_kv is not None:
        k, v = cross_kv
        q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(dt))
        if "bq" in p:
            q = q + p["bq"].astype(dt)
        B = x.shape[0]
        q_ang = rope_angles(pos[:, None], cfg.resolved_head_dim, cfg.rope_theta, None)
        q = apply_rope(q, q_ang)
        Smax = k.shape[1]
        s = jnp.einsum("bkgh,bskh->bkgs",
                       q.reshape(B, cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, -1),
                       k).astype(jnp.float32) / math.sqrt(cfg.resolved_head_dim)
        w = jax.nn.softmax(s, -1).astype(dt)
        o = jnp.einsum("bkgs,bskh->bkgh", w, v).reshape(B, 1, cfg.num_heads, -1)
        return _out_proj(p, o, dt), None

    q, k, v = _qkv(p, x, cfg)
    if cfg.mrope_sections:
        mpos = jnp.broadcast_to(pos[None, :, None], (3,) + pos.shape + (1,))
        angles = rope_angles(mpos, cfg.resolved_head_dim, cfg.rope_theta,
                             tuple(cfg.mrope_sections))
    else:
        angles = rope_angles(pos[:, None], cfg.resolved_head_dim, cfg.rope_theta, None)
    q = apply_rope(q, angles)
    k = apply_rope(k, angles)

    Smax = k_cache.shape[1]
    write_idx = (pos % Smax) if window > 0 else pos
    bidx = jnp.arange(x.shape[0])
    int8_kv = k_cache.dtype == jnp.int8
    if int8_kv:
        # static symmetric int8 KV quantization (beyond-paper decode lever:
        # halves cache HBM traffic; see EXPERIMENTS.md §Roofline decode note)
        qs = cfg.kv_quant_scale
        k_w = jnp.clip(jnp.round(k[:, 0].astype(jnp.float32) / qs), -127, 127)
        v_w = jnp.clip(jnp.round(v[:, 0].astype(jnp.float32) / qs), -127, 127)
        k_cache = k_cache.at[bidx, write_idx].set(k_w.astype(jnp.int8))
        v_cache = v_cache.at[bidx, write_idx].set(v_w.astype(jnp.int8))
        k_full = (k_cache.astype(dt) * jnp.asarray(qs, dt))
        v_full = (v_cache.astype(dt) * jnp.asarray(qs, dt))
    else:
        k_cache = k_cache.at[bidx, write_idx].set(k[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, write_idx].set(v[:, 0].astype(v_cache.dtype))
        k_full, v_full = k_cache.astype(dt), v_cache.astype(dt)
    if window > 0:
        # ring buffer: every live entry is within the window -> no pos mask
        o = decode_attention(q, k_full, v_full,
                             jnp.full_like(pos, Smax), window=0,
                             softcap=cfg.attn_logit_softcap)
    else:
        o = decode_attention(q, k_full, v_full, pos,
                             softcap=cfg.attn_logit_softcap)
    return _out_proj(p, o, dt), (k_cache, v_cache)


# ---------------------------------------------------------------------------
# FFN (dense)
# ---------------------------------------------------------------------------

def init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    std = 0.02
    gated = cfg.activation in ("swiglu", "geglu")
    p = {"w_up": _normal(ks[0], (d, f), std, _pdtype(cfg)),
         "w_down": _normal(ks[1], (f, d), std / math.sqrt(2 * cfg.num_layers), _pdtype(cfg))}
    if gated:
        p["w_gate"] = _normal(ks[2], (d, f), std, _pdtype(cfg))
    return p


def _act(name: str, g: jax.Array) -> jax.Array:
    if name in ("swiglu", "silu"):
        return jax.nn.silu(g)
    if name in ("geglu", "gelu"):
        return jax.nn.gelu(g)
    if name == "relu_sq":
        r = jax.nn.relu(g)
        return r * r
    raise ValueError(name)


def apply_ffn(p: Params, x: jax.Array, cfg: ModelConfig, ann=NULL_ANN) -> jax.Array:
    dt = x.dtype
    up = ann.constrain(x @ p["w_up"].astype(dt), "wide")
    if "w_gate" in p:
        gate = _act(cfg.activation, ann.constrain(x @ p["w_gate"].astype(dt), "wide"))
        h = gate * up
    else:
        h = _act(cfg.activation, up)
    return h @ p["w_down"].astype(dt)


# ---------------------------------------------------------------------------
# MoE (scatter-based top-k dispatch, GShard-style capacity)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig) -> Params:
    assert cfg.moe is not None
    d, E, f = cfg.d_model, cfg.moe.num_experts, cfg.moe.d_ff_expert
    ks = jax.random.split(key, 4)
    std = 0.02
    gated = cfg.activation in ("swiglu", "geglu")
    p = {
        "router": _normal(ks[0], (d, E), std, _pdtype(cfg)),
        "w_up": _normal(ks[1], (E, d, f), std, _pdtype(cfg)),
        "w_down": _normal(ks[2], (E, f, d), std / math.sqrt(2 * cfg.num_layers), _pdtype(cfg)),
    }
    if gated:
        p["w_gate"] = _normal(ks[3], (E, d, f), std, _pdtype(cfg))
    return p


def apply_moe(p: Params, x: jax.Array, cfg: ModelConfig, ann=NULL_ANN):
    """Top-k MoE, GShard-style grouped dispatch with scatter (no one-hot
    dispatch einsum).

    Tokens are split into G groups (G = the data-parallel degree so routing
    stays group-local and the dispatch scatter is fully local per shard);
    each group routes its tokens into a capacity-bounded (E, C, d) buffer,
    expert FFNs run as a batched einsum over E (GSPMD inserts the expert
    all-to-all from the sharding annotations), results gather back with the
    top-k gate-weighted combine.  Overflowed tokens drop (GShard
    semantics).  Returns (y, aux_loss).
    """
    assert cfg.moe is not None
    mo = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = mo.num_experts, mo.top_k
    dt = x.dtype
    G = max(1, min(ann.moe_groups, T))
    while T % G != 0:      # G always divides T in production (B % dp == 0)
        G -= 1
    Tg = T // G
    xt = x.reshape(G, Tg, d)

    logits = (xt @ p["router"].astype(dt)).astype(jnp.float32)   # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                     # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = int(max(4, math.ceil(k * Tg / E * mo.capacity_factor)))
    C = min(C, k * Tg)

    e_flat = idx.reshape(G, Tg * k)                              # (G, Tg*k)
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)          # (G, Tg*k, E)
    pos_all = jnp.cumsum(onehot, axis=1) - 1                     # position within expert
    pos = jnp.take_along_axis(pos_all, e_flat[..., None], axis=2)[..., 0]
    valid = pos < C
    pos_c = jnp.where(valid, pos, C)                             # overflow -> slot C

    # per-group scatter into (E, C+1, d); slot C is the trash slot.
    # one scatter per top-k rank keeps updates at (G, Tg, d) — never
    # materializes the (G, Tg*k, d) repeat.
    def scatter_group(xg, eg, pg):
        buf = jnp.zeros((E, C + 1, d), dt)
        for j in range(k):
            buf = buf.at[eg[:, j], pg[:, j]].add(xg)
        return buf

    e_tk = e_flat.reshape(G, Tg, k)
    p_tk = pos_c.reshape(G, Tg, k)
    buf = jax.vmap(scatter_group)(xt, e_tk, p_tk)                # (G, E, C+1, d)
    buf = ann.constrain(buf[:, :, :C], "moe_buf")                # (G, E, C, d)

    up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    if "w_gate" in p:
        g = _act(cfg.activation,
                 jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt)))
        h = g * up
    else:
        h = _act(cfg.activation, up)
    h = ann.constrain(h, "moe_hidden")
    out = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))  # (G, E, C, d)
    out = ann.constrain(out, "moe_buf")

    out = jnp.concatenate([out, jnp.zeros((G, E, 1, d), dt)], axis=2)

    def gather_group(og, eg, pg, wg):
        y = jnp.zeros((Tg, d), dt)
        for j in range(k):
            y = y + og[eg[:, j], pg[:, j]] * wg[:, j][:, None]
        return y

    w_tk = (gate_vals * valid.reshape(G, Tg, k)).astype(dt)
    y = jax.vmap(gather_group)(out, e_tk, p_tk, w_tk)            # (G, Tg, d)
    y = y.reshape(B, S, d)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    f_e = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    p_e = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e) * mo.aux_loss_weight
    return y, aux


# ---------------------------------------------------------------------------
# RG-LRU (recurrentgemma) recurrent block
# ---------------------------------------------------------------------------

def init_rglru_block(key, cfg: ModelConfig) -> Params:
    assert cfg.recurrent is not None
    d = cfg.d_model
    lru = cfg.recurrent.lru_width or d
    cw = cfg.recurrent.conv1d_width
    ks = jax.random.split(key, 7)
    std = 0.02
    # a_param init so that a = sigmoid(lambda)^c in [0.9, 0.999]
    a_init = jnp.log(jnp.expm1(-(1.0 / 8.0) * jnp.log(
        jnp.linspace(0.9, 0.999, lru, dtype=jnp.float32))) + 0.0)
    return {
        "w_x": _normal(ks[0], (d, lru), std, _pdtype(cfg)),
        "w_gate": _normal(ks[1], (d, lru), std, _pdtype(cfg)),
        "w_out": _normal(ks[2], (lru, d), std / math.sqrt(2 * cfg.num_layers), _pdtype(cfg)),
        "conv_w": _normal(ks[3], (cw, lru), std, _pdtype(cfg)),
        "conv_b": jnp.zeros((lru,), _pdtype(cfg)),
        # diagonal input/recurrence gates (block-diagonal in the paper;
        # diagonal here — noted simplification, same state dynamics)
        "gate_i_w": _normal(ks[4], (lru,), std, _pdtype(cfg)),
        "gate_i_b": jnp.zeros((lru,), _pdtype(cfg)),
        "gate_r_w": _normal(ks[5], (lru,), std, _pdtype(cfg)),
        "gate_r_b": jnp.zeros((lru,), _pdtype(cfg)),
        "a_param": a_init.astype(_pdtype(cfg)),
    }


def _rglru_gates(p, u):
    """u: (..., lru) branch input -> (a, gated_in) fp32."""
    uf = u.astype(jnp.float32)
    gi = jax.nn.sigmoid(uf * p["gate_i_w"].astype(jnp.float32) + p["gate_i_b"].astype(jnp.float32))
    gr = jax.nn.sigmoid(uf * p["gate_r_w"].astype(jnp.float32) + p["gate_r_b"].astype(jnp.float32))
    log_a = -8.0 * gr * jax.nn.softplus(p["a_param"].astype(jnp.float32))
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, mult * gi * uf


def rglru_sequence(p: Params, x: jax.Array, cfg: ModelConfig, *,
                   h0: Optional[jax.Array] = None, conv_state=None,
                   chunk: int = 256, ann=NULL_ANN):
    """RG-LRU block over a sequence. x: (B, S, d) -> (y, (h_last, conv_tail)).

    h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * (i_t * u_t), associative-scanned
    per chunk (remat between chunks keeps bwd memory linear in n_chunks).
    """
    B, S, d = x.shape
    dt = x.dtype
    u = ann.constrain(x @ p["w_x"].astype(dt), "wide")       # (B, S, lru)
    gate = ann.constrain(jax.nn.gelu(x @ p["w_gate"].astype(dt)), "wide")
    lru = u.shape[-1]
    cw = cfg.recurrent.conv1d_width

    if conv_state is None:
        conv_state = jnp.zeros((B, cw - 1, lru), dt)
    u_pad = jnp.concatenate([conv_state, u], axis=1)
    conv_w = p["conv_w"].astype(dt)
    uc = sum(u_pad[:, i:i + S] * conv_w[i] for i in range(cw)) + p["conv_b"].astype(dt)
    new_conv_state = u_pad[:, -(cw - 1):] if cw > 1 else conv_state

    a, b = _rglru_gates(p, uc)                  # fp32 (B, S, lru)
    if h0 is None:
        h0 = jnp.zeros((B, lru), jnp.float32)

    Ck = min(chunk, S)
    nchunks = max(1, S // Ck)
    assert S % Ck == 0 or nchunks == 1, "seq not divisible by rglru chunk"
    if S % Ck != 0:
        Ck, nchunks = S, 1
    a_c = a.reshape(B, nchunks, Ck, lru).swapaxes(0, 1)
    b_c = b.reshape(B, nchunks, Ck, lru).swapaxes(0, 1)

    def chunk_step(h, ab):
        ac, bc = ab                              # (B, Ck, lru) fp32

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        aa, bb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_seq = aa * h[:, None, :] + bb
        return h_seq[:, -1, :], h_seq

    chunk_step = jax.checkpoint(chunk_step)
    h_last, hs = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    h_seq = hs.swapaxes(0, 1).reshape(B, S, lru).astype(dt)
    y = (h_seq * gate) @ p["w_out"].astype(dt)
    return y, (h_last, new_conv_state)


def rglru_decode_step(p: Params, x: jax.Array, cfg: ModelConfig, *,
                      h: jax.Array, conv_state: jax.Array):
    """One-token RG-LRU step. x: (B, 1, d); h: (B, lru) fp32; conv_state (B, cw-1, lru)."""
    B = x.shape[0]
    dt = x.dtype
    u = (x[:, 0] @ p["w_x"].astype(dt))          # (B, lru)
    gate = jax.nn.gelu(x[:, 0] @ p["w_gate"].astype(dt))
    cw = cfg.recurrent.conv1d_width
    conv_w = p["conv_w"].astype(dt)
    hist = jnp.concatenate([conv_state, u[:, None]], axis=1)     # (B, cw, lru)
    uc = jnp.einsum("bcl,cl->bl", hist, conv_w) + p["conv_b"].astype(dt)
    new_conv_state = hist[:, 1:]
    a, b = _rglru_gates(p, uc)
    h_new = a * h + b
    y = ((h_new.astype(dt) * gate) @ p["w_out"].astype(dt))[:, None]
    return y, (h_new, new_conv_state)


# ---------------------------------------------------------------------------
# RWKV-6 (Finch) token mix + channel mix
# ---------------------------------------------------------------------------

def init_rwkv_block(key, cfg: ModelConfig) -> Params:
    assert cfg.rwkv is not None
    d = cfg.d_model
    hs = cfg.rwkv.head_size
    H = d // hs
    dl, gl = cfg.rwkv.decay_lora, cfg.rwkv.gate_lora
    ks = jax.random.split(key, 12)
    std = 0.02
    pd = _pdtype(cfg)
    return {
        "mu_r": jnp.full((d,), 0.5, pd), "mu_k": jnp.full((d,), 0.5, pd),
        "mu_v": jnp.full((d,), 0.5, pd), "mu_w": jnp.full((d,), 0.5, pd),
        "mu_g": jnp.full((d,), 0.5, pd),
        "w_r": _normal(ks[0], (d, d), std, pd),
        "w_k": _normal(ks[1], (d, d), std, pd),
        "w_v": _normal(ks[2], (d, d), std, pd),
        "w_g": _normal(ks[3], (d, d), std, pd),
        "w_o": _normal(ks[4], (d, d), std / math.sqrt(2 * cfg.num_layers), pd),
        # data-dependent decay LoRA (the Finch feature)
        "w0": jnp.full((d,), -6.0, pd),
        "wA": _normal(ks[5], (d, dl), std, pd),
        "wB": _normal(ks[6], (dl, d), std, pd),
        "u_bonus": _normal(ks[7], (H, hs), std, pd),
        "ln_scale": jnp.ones((d,), pd), "ln_bias": jnp.zeros((d,), pd),
        # channel mix
        "cm_mu_k": jnp.full((d,), 0.5, pd), "cm_mu_r": jnp.full((d,), 0.5, pd),
        "cm_wk": _normal(ks[8], (d, cfg.d_ff), std, pd),
        "cm_wv": _normal(ks[9], (cfg.d_ff, d), std / math.sqrt(2 * cfg.num_layers), pd),
        "cm_wr": _normal(ks[10], (d, d), std, pd),
    }


def _rwkv_wkv_scan(r, k, v, w, u, s0, chunk: int = 128):
    """WKV-6 recurrence.  r/k/v/w: (B, S, H, hs) fp32; u: (H, hs); s0: (B, H, hs, hs).

    y_t[j] = sum_i r_t[i] * (S_t[i,j] + u[i] k_t[i] v_t[j])
    S_{t+1}[i,j] = w_t[i] * S_t[i,j] + k_t[i] v_t[j]
    Chunked outer scan with remat'd inner scan (bwd memory ~ n_chunks states).
    """
    B, S, H, hs = r.shape
    Ck = min(chunk, S)
    if S % Ck != 0:
        Ck = S
    nc = S // Ck

    def to_chunks(x):
        return x.reshape(B, nc, Ck, H, hs).transpose(1, 2, 0, 3, 4)  # (nc, Ck, B, H, hs)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))

    def inner(s, rkvw):
        rt, kt, vt, wt = rkvw                    # (B, H, hs)
        kv = kt[..., :, None] * vt[..., None, :]  # (B, H, hs, hs)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s_new = wt[..., :, None] * s + kv
        return s_new, y

    def outer(s, ch):
        rc_, kc_, vc_, wc_ = ch

        def run(s):
            return jax.lax.scan(inner, s, (rc_, kc_, vc_, wc_))

        s_new, ys = jax.checkpoint(run)(s)
        return s_new, ys

    s_last, ys = jax.lax.scan(outer, s0, (rc, kc, vc, wc))
    # ys: (nc, Ck, B, H, hs) -> (B, S, H, hs)
    y = ys.reshape(nc * Ck, B, H, hs).transpose(1, 0, 2, 3)
    return y, s_last


def rwkv_time_mix(p: Params, x: jax.Array, cfg: ModelConfig, *,
                  x_prev: Optional[jax.Array] = None,
                  state: Optional[jax.Array] = None):
    """RWKV-6 time mix over a sequence. x: (B, S, d).

    Returns (y, (last_x, last_state)).
    """
    B, S, d = x.shape
    dt = x.dtype
    hs = cfg.rwkv.head_size
    H = d // hs
    if x_prev is None:
        x_prev = jnp.zeros((B, d), dt)
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)   # shifted
    dx = xs - x

    def mix(mu):
        return x + dx * mu.astype(dt)

    xr, xk, xv, xw, xg = (mix(p[m]) for m in ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g"))
    r = (xr @ p["w_r"].astype(dt)).reshape(B, S, H, hs).astype(jnp.float32)
    k = (xk @ p["w_k"].astype(dt)).reshape(B, S, H, hs).astype(jnp.float32)
    v = (xv @ p["w_v"].astype(dt)).reshape(B, S, H, hs).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["w_g"].astype(dt))
    dd = jnp.tanh(xw.astype(jnp.float32) @ p["wA"].astype(jnp.float32)) @ p["wB"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(p["w0"].astype(jnp.float32) + dd)).reshape(B, S, H, hs)
    if state is None:
        state = jnp.zeros((B, H, hs, hs), jnp.float32)
    y, s_last = _rwkv_wkv_scan(r, k, v, w, p["u_bonus"].astype(jnp.float32), state)
    # per-head groupnorm
    yf = y.reshape(B, S, H, hs)
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yn = ((yf - mu) * jax.lax.rsqrt(var + 64e-5)).reshape(B, S, d)
    yn = yn * p["ln_scale"].astype(jnp.float32) + p["ln_bias"].astype(jnp.float32)
    out = (yn.astype(dt) * g) @ p["w_o"].astype(dt)
    return out, (x[:, -1], s_last)


def rwkv_channel_mix(p: Params, x: jax.Array, cfg: ModelConfig, *,
                     x_prev: Optional[jax.Array] = None):
    B, S, d = x.shape
    dt = x.dtype
    if x_prev is None:
        x_prev = jnp.zeros((B, d), dt)
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    dx = xs - x
    xk = x + dx * p["cm_mu_k"].astype(dt)
    xr = x + dx * p["cm_mu_r"].astype(dt)
    kk = jax.nn.relu(xk @ p["cm_wk"].astype(dt))
    vv = (kk * kk) @ p["cm_wv"].astype(dt)
    rr = jax.nn.sigmoid(xr @ p["cm_wr"].astype(dt))
    return rr * vv, x[:, -1]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embeddings(key, cfg: ModelConfig) -> Params:
    V, d = cfg.padded_vocab, cfg.d_model
    ks = jax.random.split(key, 2)
    p = {"embed": _normal(ks[0], (V, d), 0.02, _pdtype(cfg))}
    if not cfg.tie_embeddings:
        p["unembed"] = _normal(ks[1], (d, V), 0.02, _pdtype(cfg))
    return p


def embed_tokens(p: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return p["embed"].astype(_dtype(cfg))[tokens]


def logits_from_hidden(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["embed"].astype(dt))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["unembed"].astype(dt))
    if cfg.padded_vocab != cfg.vocab_size:
        pad_bias = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab_size, 0.0, -1e30)
        logits = logits + pad_bias.astype(dt)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token CE in fp32. logits (B, S, V); labels (B, S) int32.

    The gold logit is extracted with a masked reduction along the vocab dim
    rather than ``take_along_axis`` — a gather along the TP-sharded vocab
    axis would force GSPMD to all-gather the full fp32 logits per device.
    The masked reduce partitions cleanly (vocab-sharded reduce + tiny
    all-reduce).
    """
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1))
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], lf, 0.0), axis=-1)
    return jnp.mean(lse - gold)
