from repro.models.zoo import (
    build_model,
    init_params,
    make_train_step,
    make_prefill_step,
    make_decode_step,
    input_specs,
)

__all__ = [
    "build_model",
    "init_params",
    "make_train_step",
    "make_prefill_step",
    "make_decode_step",
    "input_specs",
]
