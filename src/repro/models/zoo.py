"""Model zoo: family stacks (dense / moe / hybrid / ssm / vlm / audio),
train/prefill/decode step factories, and ShapeDtypeStruct input specs for
the dry-run.

All stacks scan over layers (``lax.scan`` with stacked params) so HLO size
is depth-independent — required to compile 48–64 layer models against a
512-way mesh.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, ShapeConfig, OptimizerConfig
from repro.models import layers as L
from repro.optim import Optimizer, clip_by_global_norm

Params = Any

VLM_VISION_TOKENS = 1024   # stub frontend: fixed number of precomputed patch embeddings


# ---------------------------------------------------------------------------
# Remat
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "minimal":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


def _stack_init(init_fn, key, n: int):
    """Initialize n layers with stacked (leading-axis n) params."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _index_tree(tree, i):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, rng: jax.Array) -> Params:
    keys = jax.random.split(rng, 8)
    p: dict = {"emb": L.init_embeddings(keys[0], cfg),
               "final_norm": L.init_norm(cfg)}

    if cfg.family in ("dense", "vlm"):
        def one(k):
            ks = jax.random.split(k, 2)
            return {"ln1": L.init_norm(cfg), "attn": L.init_attention(ks[0], cfg),
                    "ln2": L.init_norm(cfg), "ffn": L.init_ffn(ks[1], cfg)}
        p["layers"] = _stack_init(one, keys[1], cfg.num_layers)

    elif cfg.family == "moe":
        def one(k):
            ks = jax.random.split(k, 2)
            return {"ln1": L.init_norm(cfg), "attn": L.init_attention(ks[0], cfg),
                    "ln2": L.init_norm(cfg), "moe": L.init_moe(ks[1], cfg)}
        p["layers"] = _stack_init(one, keys[1], cfg.num_layers)

    elif cfg.family == "ssm":
        def one(k):
            return {"ln1": L.init_norm(cfg), "ln2": L.init_norm(cfg),
                    "mix": L.init_rwkv_block(k, cfg)}
        p["layers"] = _stack_init(one, keys[1], cfg.num_layers)

    elif cfg.family == "hybrid":
        pat = tuple(cfg.recurrent.block_pattern)
        period = len(pat)
        n_rec_per_group = sum(1 for b in pat if b == "recurrent")
        n_groups = cfg.num_layers // period
        n_tail = cfg.num_layers - n_groups * period
        assert all(b == "recurrent" for b in pat[:n_tail]), "tail must be recurrent-only"

        def rec_one(k):
            ks = jax.random.split(k, 2)
            return {"ln": L.init_norm(cfg), "mix": L.init_rglru_block(ks[0], cfg),
                    "ffn_ln": L.init_norm(cfg), "ffn": L.init_ffn(ks[1], cfg)}

        def attn_one(k):
            ks = jax.random.split(k, 2)
            return {"ln": L.init_norm(cfg), "attn": L.init_attention(ks[0], cfg),
                    "ffn_ln": L.init_norm(cfg), "ffn": L.init_ffn(ks[1], cfg)}

        def group_one(k):
            ks = jax.random.split(k, 2)
            return {"rec": _stack_init(rec_one, ks[0], n_rec_per_group),
                    "attn": attn_one(ks[1])}

        p["groups"] = _stack_init(group_one, keys[1], n_groups)
        if n_tail:
            p["tail"] = _stack_init(rec_one, keys[2], n_tail)

    elif cfg.family == "audio":
        def enc_one(k):
            ks = jax.random.split(k, 2)
            return {"ln1": L.init_norm(cfg), "attn": L.init_attention(ks[0], cfg),
                    "ln2": L.init_norm(cfg), "ffn": L.init_ffn(ks[1], cfg)}

        def dec_one(k):
            ks = jax.random.split(k, 3)
            return {"ln1": L.init_norm(cfg), "self_attn": L.init_attention(ks[0], cfg),
                    "ln2": L.init_norm(cfg), "cross_attn": L.init_attention(ks[1], cfg),
                    "ln3": L.init_norm(cfg), "ffn": L.init_ffn(ks[2], cfg)}

        p["enc_layers"] = _stack_init(enc_one, keys[1], cfg.num_layers)
        p["enc_norm"] = L.init_norm(cfg)
        p["dec_layers"] = _stack_init(dec_one, keys[2], cfg.num_decoder_layers)
    else:
        raise ValueError(cfg.family)
    return p


# ---------------------------------------------------------------------------
# Sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _run_uniform_stack(params, cfg: ModelConfig, x, positions, *,
                       collect_kv: bool, causal: bool = True, window: int = 0,
                       ann=L.NULL_ANN):
    """dense/moe/vlm/ssm stacks (uniform per-layer structure)."""

    def body(carry, lp):
        x, aux = carry
        x = ann.constrain(x, "hidden")
        ys = None
        if cfg.family == "ssm":
            h = L.apply_norm(lp["ln1"], x, cfg)
            y, (tm_x, tm_s) = L.rwkv_time_mix(lp["mix"], h, cfg)
            x = x + y
            h = L.apply_norm(lp["ln2"], x, cfg)
            y, cm_x = L.rwkv_channel_mix(lp["mix"], h, cfg)
            x = x + y
            if collect_kv:
                ys = {"tm_x": tm_x, "tm_s": tm_s, "cm_x": cm_x}
        else:
            h = L.apply_norm(lp["ln1"], x, cfg)
            if collect_kv:
                a, (k, v) = L.attention_sequence(
                    lp["attn"], h, cfg, positions=positions, causal=causal,
                    window=window, return_kv=True, ann=ann)
                ys = {"k": k, "v": v}
            else:
                a = L.attention_sequence(lp["attn"], h, cfg, positions=positions,
                                         causal=causal, window=window, ann=ann)
            x = ann.constrain(x + a, "hidden")
            h = L.apply_norm(lp["ln2"], x, cfg)
            if cfg.family == "moe":
                y, aux_l = L.apply_moe(lp["moe"], h, cfg, ann=ann)
                aux = aux + aux_l
            else:
                y = L.apply_ffn(lp["ffn"], h, cfg, ann=ann)
            x = x + y
        return (ann.constrain(x, "hidden"), aux), ys

    body = _remat(body, cfg)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    params["layers"])
    return x, aux, caches


def _hybrid_sublayer(lp, x, cfg, positions, kind: str, collect: bool,
                     ann=L.NULL_ANN):
    h = L.apply_norm(lp["ln"], x, cfg)
    st = None
    if kind == "recurrent":
        y, (h_last, conv_tail) = L.rglru_sequence(lp["mix"], h, cfg, ann=ann)
        if collect:
            st = {"h": h_last, "conv": conv_tail}
    else:
        W = cfg.recurrent.window_size
        if collect:
            y, (k, v) = L.attention_sequence(lp["attn"], h, cfg, positions=positions,
                                             causal=True, window=W, return_kv=True,
                                             ann=ann)
            S = k.shape[1]
            if S < W:
                pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            else:
                assert S % W == 0, "hybrid prefill needs seq % window == 0"
                k, v = k[:, -W:], v[:, -W:]
            st = {"k": k, "v": v}
        else:
            y = L.attention_sequence(lp["attn"], h, cfg, positions=positions,
                                     causal=True, window=W, ann=ann)
    x = ann.constrain(x + y, "hidden")
    h = L.apply_norm(lp["ffn_ln"], x, cfg)
    x = ann.constrain(x + L.apply_ffn(lp["ffn"], h, cfg, ann=ann), "hidden")
    return x, st


def _run_hybrid_stack(params, cfg: ModelConfig, x, positions, *, collect_kv: bool,
                      ann=L.NULL_ANN):
    pat = tuple(cfg.recurrent.block_pattern)
    n_rec = sum(1 for b in pat if b == "recurrent")

    def group_body(carry, gp):
        x, = carry
        x = ann.constrain(x, "hidden")
        recs = []
        for i in range(n_rec):
            x, st = _hybrid_sublayer(_index_tree(gp["rec"], i), x, cfg, positions,
                                     "recurrent", collect_kv, ann)
            recs.append(st)
        x, attn_st = _hybrid_sublayer(gp["attn"], x, cfg, positions,
                                      "attention", collect_kv, ann)
        ys = None
        if collect_kv:
            ys = {"rec": jax.tree_util.tree_map(lambda *a: jnp.stack(a), *recs),
                  "attn": attn_st}
        return (x,), ys

    group_body = _remat(group_body, cfg)
    (x,), group_caches = jax.lax.scan(group_body, (x,), params["groups"])

    tail_caches = []
    if "tail" in params:
        n_tail = jax.tree_util.tree_leaves(params["tail"])[0].shape[0]
        for i in range(n_tail):
            x, st = _hybrid_sublayer(_index_tree(params["tail"], i), x, cfg,
                                     positions, "recurrent", collect_kv, ann)
            tail_caches.append(st)
    caches = None
    if collect_kv:
        caches = {"groups": group_caches}
        if tail_caches:
            caches["tail"] = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *tail_caches)
    return x, caches


def _run_audio_stack(params, cfg: ModelConfig, frames, dec_x, dec_positions, *,
                     collect_kv: bool, ann=L.NULL_ANN):
    enc_positions = jnp.broadcast_to(jnp.arange(frames.shape[1])[None],
                                     frames.shape[:2])

    def enc_body(x, lp):
        x = ann.constrain(x, "hidden")
        h = L.apply_norm(lp["ln1"], x, cfg)
        x = x + L.attention_sequence(lp["attn"], h, cfg, positions=enc_positions,
                                     causal=False, ann=ann)
        h = L.apply_norm(lp["ln2"], x, cfg)
        x = x + L.apply_ffn(lp["ffn"], h, cfg, ann=ann)
        return ann.constrain(x, "hidden"), None

    enc_body = _remat(enc_body, cfg)
    enc_out, _ = jax.lax.scan(enc_body, frames, params["enc_layers"])
    enc_out = L.apply_norm(params["enc_norm"], enc_out, cfg)

    def dec_body(x, lp):
        dt = x.dtype
        x = ann.constrain(x, "hidden")
        h = L.apply_norm(lp["ln1"], x, cfg)
        ys = None
        if collect_kv:
            a, (k, v) = L.attention_sequence(lp["self_attn"], h, cfg,
                                             positions=dec_positions, causal=True,
                                             return_kv=True, ann=ann)
        else:
            a = L.attention_sequence(lp["self_attn"], h, cfg,
                                     positions=dec_positions, causal=True, ann=ann)
        x = x + a
        h = L.apply_norm(lp["ln2"], x, cfg)
        ck = jnp.einsum("bsd,dnh->bsnh", enc_out, lp["cross_attn"]["wk"].astype(dt))
        cv = jnp.einsum("bsd,dnh->bsnh", enc_out, lp["cross_attn"]["wv"].astype(dt))
        x = x + L.attention_sequence(lp["cross_attn"], h, cfg,
                                     positions=dec_positions, causal=False,
                                     kv_override=(ck, cv, None), ann=ann)
        h = L.apply_norm(lp["ln3"], x, cfg)
        x = ann.constrain(x + L.apply_ffn(lp["ffn"], h, cfg, ann=ann), "hidden")
        if collect_kv:
            ys = {"k": k, "v": v, "ck": ck, "cv": cv}
        return x, ys

    dec_body = _remat(dec_body, cfg)
    x, caches = jax.lax.scan(dec_body, dec_x, params["dec_layers"])
    return x, caches


def forward_logits(params, cfg: ModelConfig, batch: dict, *,
                   collect_kv: bool = False, last_token_only: bool = False,
                   ann=L.NULL_ANN):
    """Sequence forward for train/prefill. Returns (logits, aux, caches).

    ``last_token_only`` (prefill) computes logits for the final position
    only — avoids materializing the (B, S, V) logits for 32k prefills.
    """
    if cfg.family == "audio":
        frames = batch["frames"].astype(L._dtype(cfg))
        dec_tokens = batch["dec_tokens"]
        dec_x = L.embed_tokens(params["emb"], dec_tokens, cfg)
        B, Sd = dec_tokens.shape
        dec_pos = jnp.broadcast_to(jnp.arange(Sd)[None], (B, Sd))
        x, caches = _run_audio_stack(params, cfg, frames, dec_x, dec_pos,
                                     collect_kv=collect_kv, ann=ann)
        aux = jnp.zeros((), jnp.float32)
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = L.embed_tokens(params["emb"], tokens, cfg)
        if cfg.family == "vlm" and "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(x.dtype)
            nv = ve.shape[1]
            x = jnp.concatenate([x[:, :nv] + ve, x[:, nv:]], axis=1)
        x = ann.constrain(x, "hidden")
        if cfg.mrope_sections and "positions" in batch:
            positions = batch["positions"]
        else:
            positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
            if cfg.mrope_sections:
                positions = jnp.broadcast_to(positions[None], (3, B, S))
        if cfg.family == "hybrid":
            pos2d = positions if positions.ndim == 2 else positions[0]
            x, caches = _run_hybrid_stack(params, cfg, x, pos2d,
                                          collect_kv=collect_kv, ann=ann)
            aux = jnp.zeros((), jnp.float32)
        else:
            x, aux, caches = _run_uniform_stack(params, cfg, x, positions,
                                                collect_kv=collect_kv, ann=ann)
    if last_token_only:
        x = x[:, -1:]
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = ann.constrain(L.logits_from_hidden(params["emb"], x, cfg), "logits")
    return logits, aux, caches


# ---------------------------------------------------------------------------
# Decode forward
# ---------------------------------------------------------------------------

def forward_decode(params, cfg: ModelConfig, caches, tokens, pos,
                   ann=L.NULL_ANN):
    """One decode step. tokens (B, 1) int32; pos (B,) int32.

    Returns (logits (B, vocab_pad), new_caches).
    """
    x = L.embed_tokens(params["emb"], tokens, cfg)

    if cfg.family in ("dense", "vlm", "moe"):
        def body(carry, xs):
            x, aux = carry
            lp, kc, vc = xs
            h = L.apply_norm(lp["ln1"], x, cfg)
            a, (kc, vc) = L.attention_decode_step(lp["attn"], h, cfg, pos=pos,
                                                  k_cache=kc, v_cache=vc)
            x = x + a
            h = L.apply_norm(lp["ln2"], x, cfg)
            if cfg.family == "moe":
                y, aux_l = L.apply_moe(lp["moe"], h, cfg, ann=ann)
                aux = aux + aux_l
            else:
                y = L.apply_ffn(lp["ffn"], h, cfg)
            x = x + y
            return (x, aux), {"k": kc, "v": vc}

        (x, _), new_caches = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], caches["k"], caches["v"]))

    elif cfg.family == "ssm":
        def body(x, xs):
            lp, st = xs
            h = L.apply_norm(lp["ln1"], x, cfg)
            y, (tm_x, tm_s) = L.rwkv_time_mix(lp["mix"], h, cfg,
                                              x_prev=st["tm_x"], state=st["tm_s"])
            x = x + y
            h = L.apply_norm(lp["ln2"], x, cfg)
            y, cm_x = L.rwkv_channel_mix(lp["mix"], h, cfg, x_prev=st["cm_x"])
            x = x + y
            return x, {"tm_x": tm_x, "tm_s": tm_s, "cm_x": cm_x}

        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))

    elif cfg.family == "hybrid":
        n_rec = sum(1 for b in cfg.recurrent.block_pattern if b == "recurrent")
        W = cfg.recurrent.window_size

        def sub_rec(lp, x, st):
            h = L.apply_norm(lp["ln"], x, cfg)
            y, (hh, conv) = L.rglru_decode_step(lp["mix"], h, cfg,
                                                h=st["h"], conv_state=st["conv"])
            x = x + y
            h = L.apply_norm(lp["ffn_ln"], x, cfg)
            x = x + L.apply_ffn(lp["ffn"], h, cfg)
            return x, {"h": hh, "conv": conv}

        def sub_attn(lp, x, st):
            h = L.apply_norm(lp["ln"], x, cfg)
            a, (kc, vc) = L.attention_decode_step(lp["attn"], h, cfg, pos=pos,
                                                  k_cache=st["k"], v_cache=st["v"],
                                                  window=W)
            x = x + a
            h = L.apply_norm(lp["ffn_ln"], x, cfg)
            x = x + L.apply_ffn(lp["ffn"], h, cfg)
            return x, {"k": kc, "v": vc}

        def group_body(x, xs):
            gp, st = xs
            new_rec = []
            for i in range(n_rec):
                x, s = sub_rec(_index_tree(gp["rec"], i), x, _index_tree(st["rec"], i))
                new_rec.append(s)
            x, s_attn = sub_attn(gp["attn"], x, st["attn"])
            return x, {"rec": jax.tree_util.tree_map(lambda *a: jnp.stack(a), *new_rec),
                       "attn": s_attn}

        x, new_group = jax.lax.scan(group_body, x,
                                    (params["groups"], caches["groups"]))
        new_caches = {"groups": new_group}
        if "tail" in params:
            n_tail = jax.tree_util.tree_leaves(params["tail"])[0].shape[0]
            tails = []
            for i in range(n_tail):
                x, s = sub_rec(_index_tree(params["tail"], i), x,
                               _index_tree(caches["tail"], i))
                tails.append(s)
            new_caches["tail"] = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *tails)

    elif cfg.family == "audio":
        def body(x, xs):
            lp, st = xs
            h = L.apply_norm(lp["ln1"], x, cfg)
            a, (kc, vc) = L.attention_decode_step(lp["self_attn"], h, cfg, pos=pos,
                                                  k_cache=st["k"], v_cache=st["v"])
            x = x + a
            h = L.apply_norm(lp["ln2"], x, cfg)
            a, _ = L.attention_decode_step(lp["cross_attn"], h, cfg, pos=pos,
                                           k_cache=None, v_cache=None,
                                           cross_kv=(st["ck"], st["cv"]))
            x = x + a
            h = L.apply_norm(lp["ln3"], x, cfg)
            x = x + L.apply_ffn(lp["ffn"], h, cfg)
            return x, {"k": kc, "v": vc, "ck": st["ck"], "cv": st["cv"]}

        x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], caches))
    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.logits_from_hidden(params["emb"], x, cfg)[:, 0]
    return logits, new_caches


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: ModelConfig, ann=L.NULL_ANN):
    def loss_fn(params, batch):
        logits, aux, _ = forward_logits(params, cfg, batch, ann=ann)
        labels = batch["labels"]
        ce = L.cross_entropy(logits, labels)
        return ce + aux, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    opt_cfg: OptimizerConfig, accum: int = 1, ann=L.NULL_ANN,
                    accum_dtype: str = "float32"):
    loss_fn = make_loss_fn(cfg, ann=ann)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    adt = jnp.dtype(accum_dtype)

    def train_step(state, batch):
        params = state["params"]
        if accum <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                gsum, lsum = carry
                (l, _), g = grad_fn(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(adt), gsum, g)
                return (gsum, lsum + l), None

            z = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, adt), params)
            bsz = (batch.get("tokens", batch.get("frames"))).shape[0]

            def split_micro(x):
                """Split the global-batch dim into (accum, B/accum) —
                handles leading-batch leaves and (3, B, S) position ids."""
                if x.shape[0] == bsz:
                    return x.reshape((accum, bsz // accum) + x.shape[1:])
                if x.ndim >= 2 and x.shape[1] == bsz:
                    y = x.reshape(x.shape[:1] + (accum, bsz // accum) + x.shape[2:])
                    return jnp.moveaxis(y, 1, 0)
                raise ValueError(f"cannot microbatch leaf of shape {x.shape}")

            mbs = jax.tree_util.tree_map(split_micro, batch)
            (grads, loss), _ = jax.lax.scan(micro, (z, jnp.zeros((), jnp.float32)), mbs)
            grads = jax.tree_util.tree_map(lambda g: (g / accum).astype(jnp.float32), grads)
            loss = loss / accum
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        new_params, new_opt = optimizer.update(grads, state["opt"], params, state["step"])
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return new_state, out_metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, ann=L.NULL_ANN):
    def prefill(params, inputs):
        logits, _, caches = forward_logits(params, cfg, inputs, collect_kv=True,
                                           last_token_only=True, ann=ann)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, caches
    return prefill


def make_decode_step(cfg: ModelConfig, ann=L.NULL_ANN):
    def decode(params, caches, inputs):
        logits, new_caches = forward_decode(params, cfg, caches,
                                            inputs["tokens"], inputs["pos"],
                                            ann=ann)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token[:, None], new_caches
    return decode


# ---------------------------------------------------------------------------
# Input / cache specs (ShapeDtypeStruct, no allocation) — dry-run substrate
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this (arch, shape)."""
    B, S = shape.global_batch, shape.seq_len
    dt = cfg.dtype
    if shape.mode == "train":
        if cfg.family == "audio":
            Sd = S // cfg.dec_ratio
            return {"frames": _sds((B, S, cfg.d_model), dt),
                    "dec_tokens": _sds((B, Sd), "int32"),
                    "labels": _sds((B, Sd), "int32")}
        spec = {"tokens": _sds((B, S), "int32"), "labels": _sds((B, S), "int32")}
        if cfg.family == "vlm":
            spec["vision_embeds"] = _sds((B, VLM_VISION_TOKENS, cfg.d_model), dt)
            spec["positions"] = _sds((3, B, S), "int32")
        return spec
    if shape.mode == "prefill":
        if cfg.family == "audio":
            Sd = S // cfg.dec_ratio
            return {"frames": _sds((B, S, cfg.d_model), dt),
                    "dec_tokens": _sds((B, Sd), "int32")}
        spec = {"tokens": _sds((B, S), "int32")}
        if cfg.family == "vlm":
            spec["vision_embeds"] = _sds((B, VLM_VISION_TOKENS, cfg.d_model), dt)
            spec["positions"] = _sds((3, B, S), "int32")
        return spec
    # decode
    return {"tokens": _sds((B, 1), "int32"), "pos": _sds((B,), "int32")}


def cache_specs(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    """KV-cache / state ShapeDtypeStructs for decode shapes."""
    assert shape.mode == "decode"
    B, S = shape.global_batch, shape.seq_len
    K, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    kvdt = cfg.kv_cache_dtype
    if cfg.family in ("dense", "vlm", "moe"):
        LN = cfg.num_layers
        return {"k": _sds((LN, B, S, K, hd), kvdt),
                "v": _sds((LN, B, S, K, hd), kvdt)}
    if cfg.family == "ssm":
        H = cfg.d_model // cfg.rwkv.head_size
        hs = cfg.rwkv.head_size
        Lx = cfg.num_layers
        return {"tm_x": _sds((Lx, B, cfg.d_model), cfg.dtype),
                "tm_s": _sds((Lx, B, H, hs, hs), "float32"),
                "cm_x": _sds((Lx, B, cfg.d_model), cfg.dtype)}
    if cfg.family == "hybrid":
        pat = tuple(cfg.recurrent.block_pattern)
        n_rec = sum(1 for b in pat if b == "recurrent")
        G = cfg.num_layers // len(pat)
        n_tail = cfg.num_layers - G * len(pat)
        lru = cfg.recurrent.lru_width or cfg.d_model
        cw = cfg.recurrent.conv1d_width
        W = cfg.recurrent.window_size
        rec = {"h": _sds((G, n_rec, B, lru), "float32"),
               "conv": _sds((G, n_rec, B, cw - 1, lru), cfg.dtype)}
        attn = {"k": _sds((G, B, W, K, hd), kvdt),
                "v": _sds((G, B, W, K, hd), kvdt)}
        caches = {"groups": {"rec": rec, "attn": attn}}
        if n_tail:
            caches["tail"] = {"h": _sds((n_tail, B, lru), "float32"),
                              "conv": _sds((n_tail, B, cw - 1, lru), cfg.dtype)}
        return caches
    if cfg.family == "audio":
        Ld = cfg.num_decoder_layers
        Se = S // cfg.dec_ratio
        return {"k": _sds((Ld, B, S, K, hd), kvdt),
                "v": _sds((Ld, B, S, K, hd), kvdt),
                "ck": _sds((Ld, B, Se, K, hd), kvdt),
                "cv": _sds((Ld, B, Se, K, hd), kvdt)}
    raise ValueError(cfg.family)


def state_specs(cfg: ModelConfig, optimizer: Optimizer) -> dict:
    """TrainState ShapeDtypeStructs via eval_shape (no allocation)."""
    params = jax.eval_shape(partial(init_params, cfg), jax.random.PRNGKey(0))
    opt = jax.eval_shape(optimizer.init, params)
    return {"params": params, "opt": opt,
            "step": _sds((), "int32")}


def build_model(cfg: ModelConfig):
    """Convenience bundle for examples/tests."""
    return {
        "init": partial(init_params, cfg),
        "loss_fn": make_loss_fn(cfg),
        "prefill": make_prefill_step(cfg),
        "decode": make_decode_step(cfg),
        "input_specs": partial(input_specs, cfg),
        "cache_specs": partial(cache_specs, cfg),
    }
