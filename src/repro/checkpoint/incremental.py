"""Incremental (delta) checkpointing.

Between full checkpoints, only the compressed delta vs the last *full*
checkpoint is persisted — optimizer-adjacent tensors change slowly, so
deltas compress hard.  Two encodings:

  * ``lossless`` (default): delta = new - base (float32) plus an XOR
    residual between the predicted and true bytes — the subtraction makes
    slowly-drifting tensors compress hard, the residual makes restore
    BIT-exact even where float rounding perturbs the reconstruction.
    Non-float leaves store the XOR of raw bytes (zeros when unchanged).
  * ``int8``: per-group int8 quantized delta (the ``kernels/ckpt_delta``
    Pallas kernel implements the encode on-TPU; host fallback is its
    ref.py oracle).  Lossy — used as a cheap level-1 in multi-level
    schemes (paper-cited [21]); never for the level-2 full snapshots.

Compression: zstd when ``zstandard`` is installed, stdlib zlib otherwise.
The codec actually used is recorded in each delta manifest so restore picks
the matching decompressor even if the environment changed in between.

Two blob layouts coexist, selected by the source:

  * per-leaf (v2, and always the host path): one ``key@suffix.bin`` blob
    set per leaf, encoded/compressed/written concurrently on the io pool.
  * flat (v3, device placement): a ``pipeline.DeltaLeafSource`` hands over
    ONE already-encoded mega-buffer payload covering its packed f32
    subtree; it is frame-compressed (``store.compress_frames``) into
    ``flat@d.bin``/``flat@r.bin`` (lossless) or ``flat@q.bin``/
    ``flat@s.bin`` (int8) and described by the manifest's ``"flat"``
    section (size, group, per-leaf layout rows, per-array frame lengths).
    Leaves outside the packed subtree still get per-leaf blobs in the
    same delta, and ``apply_delta`` restores BOTH layouts — so v2 deltas
    written before the flat path existed keep restoring unchanged.

Chain layout: full_0, delta_1..delta_{k-1}, full_k, ...; restore loads the
newest full plus its newest delta (deltas are vs the base full, not
chained, so restore reads at most two objects).

The module-level ``write_delta``/``apply_delta``/``newest_delta_step``
functions are the reusable layer: ``IncrementalCheckpointer`` (legacy API)
and ``manager.CheckpointManager`` (unified plane) both compose them.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Optional

import numpy as np

import jax

from repro.checkpoint.store import (CheckpointStore, compress_frames,
                                    decompress_frames, fresh_tmp_dir,
                                    get_compressor, get_decompressor,
                                    publish_dir_atomic, write_json_atomic)
from repro.kernels.ckpt_delta.ref import GROUP
from repro.utils.trees import tree_flatten_with_names


def delta_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"delta_{step:010d}")


def _encode_leaf_blobs(key: str, leaf: np.ndarray, b: np.ndarray,
                       mode: str, compress) -> dict[str, bytes]:
    """blob-key -> compressed payload for one leaf (runs on an io worker)."""
    blobs: dict[str, bytes] = {}
    if mode == "lossless":
        if leaf.dtype == np.float32:
            # fused sub+XOR-residual scheme — host oracle of the
            # kernels/ckpt_delta lossless Pallas kernel; identical bytes
            from repro.kernels.ckpt_delta.ref import lossless_encode_ref
            delta, resid = lossless_encode_ref(leaf, b)
            blobs[key] = compress(delta.tobytes())
            blobs[key + "::r"] = compress(resid.tobytes())
        elif np.issubdtype(leaf.dtype, np.floating):
            delta = leaf.astype(np.float32) - b.astype(np.float32)
            pred = (b.astype(np.float32) + delta).astype(leaf.dtype)
            resid = np.frombuffer(leaf.tobytes(), np.uint8) \
                ^ np.frombuffer(pred.tobytes(), np.uint8)
            blobs[key] = compress(delta.tobytes())
            blobs[key + "::r"] = compress(resid.tobytes())
        else:
            xored = np.frombuffer(leaf.tobytes(), np.uint8) \
                ^ np.frombuffer(b.tobytes(), np.uint8)
            blobs[key] = compress(xored.tobytes())
        return blobs
    # int8 group-quantized delta (host-side oracle of kernels/ckpt_delta)
    from repro.kernels.ckpt_delta.ref import encode_ref
    delta = leaf.astype(np.float32) - b.astype(np.float32)
    q, scales = encode_ref(delta.reshape(-1))
    blobs[key + "::q"] = compress(q.tobytes())
    blobs[key + "::s"] = compress(scales.tobytes())
    return blobs


def write_delta(directory: str, step: int, state_np: Any, base: Any,
                base_step: int, timestamp: float = 0.0,
                extra: Optional[dict] = None, mode: str = "lossless",
                codec: str = "auto", level: int = 3
                ) -> tuple[str, int, float]:
    """Encode + atomically publish one delta checkpoint.

    Leaves are encoded/compressed/written concurrently on the shared
    ``pipeline.io_pool``; ``state_np`` and ``base`` may be pytrees or
    ``pipeline.LeafSource``s (a chunked snapshot still transferring from
    the device overlaps its D2H with the encode of already-landed leaves).
    A ``pipeline.DeltaLeafSource`` arrives FLAT-encoded (one fused device
    kernel ran in front of D2H): its packed mega-buffer payload is
    frame-compressed and written as ``flat@*.bin`` under the manifest's
    ``"flat"`` section, its fused per-leaf change counts become ``"zero"``
    markers, and only leaves outside the packed subtree fall back to the
    per-leaf host path against ``base``.  A host-path unchanged leaf (raw
    bytes equal to the base's) is likewise recorded as a ``"zero"`` marker
    instead of compressing and writing a full-size all-zeros blob.

    Returns (path, payload_bytes, encode_cpu_s) where ``encode_cpu_s``
    sums per-worker CPU seconds spent encoding+compressing — the quantity
    ``SimCostModel.delta_encode_s_per_byte`` is calibrated from (for a
    device source this is compress-only CPU; the device encode seconds are
    measured separately by ``bench_ckpt``).  The delta manifest records
    the codec, mode and encode placement so ``apply_delta`` is
    self-describing.
    """
    from repro.checkpoint.pipeline import as_leaf_source, io_pool

    codec_name, compress = get_compressor(codec, level)
    src = as_leaf_source(state_np)
    base_src = as_leaf_source(base)
    placement = getattr(src, "placement", "host")
    layout = getattr(src, "layout", None)
    if layout is not None:
        assert getattr(src, "codec", mode) == mode, \
            (f"flat-encoded source codec {src.codec!r} does not match the "
             f"requested delta mode {mode!r}")
    packed = frozenset(layout.names) if layout is not None else frozenset()
    path = delta_dir(directory, step)
    tmp = fresh_tmp_dir(path)

    def encode_leaf(name: str) -> tuple[str, int, float, bool]:
        key = name.replace("/", "::")
        t0 = time.thread_time()
        leaf = np.asarray(src.get(name))
        b = np.asarray(base_src.get(name))
        # skip-zero fast path: byte-level equality, compared through u8
        # views (reshape keeps 0-d leaves viewable) so no copies are made
        if leaf.dtype == b.dtype and leaf.shape == b.shape and \
                np.array_equal(leaf.reshape(-1).view(np.uint8),
                               b.reshape(-1).view(np.uint8)):
            return key, 0, time.thread_time() - t0, True
        blobs = _encode_leaf_blobs(key, leaf, b, mode, compress)
        cpu_s = time.thread_time() - t0
        nbytes = 0
        for k, blob in blobs.items():
            with open(os.path.join(tmp, k.replace("::", "@") + ".bin"),
                      "wb") as f:
                f.write(blob)
            nbytes += len(blob)
        return key, nbytes, cpu_s, False

    # per-leaf host encodes for everything the flat payload doesn't cover
    futures = [io_pool().submit(encode_leaf, n) for n in src.names
               if n not in packed]

    flat_meta = None
    flat_bytes = 0
    flat_cpu = 0.0
    zero_flat: list[str] = []
    if layout is not None:
        payload = src.flat_payload()            # blocks until chunks land
        zero_flat = [n.replace("/", "::") for n in src.zero_names]
        flat_meta = {"size": layout.total, "group": GROUP,
                     "layout": [[name.replace("/", "::"), off, size, shape]
                                for name, off, size, shape
                                in layout.to_manifest()],
                     "arrays": {}}
        for sfx in (("d", "r") if mode == "lossless" else ("q", "s")):
            arr = payload.get(sfx)
            if arr is None:             # every packed leaf unchanged
                continue
            if isinstance(arr, str):    # "zero": residual D2H was skipped
                flat_meta["arrays"][sfx] = "zero"
                continue
            frames, lens, cpu = compress_frames(arr, compress, io_pool())
            fname = f"flat@{sfx}.bin"
            with open(os.path.join(tmp, fname), "wb") as f:
                for frame in frames:
                    f.write(frame)
            flat_meta["arrays"][sfx] = {"file": fname,
                                        "dtype": str(arr.dtype),
                                        "frames": lens}
            flat_bytes += sum(lens)
            flat_cpu += cpu

    results = [f.result() for f in futures]
    nbytes = sum(n for _, n, _, _ in results) + flat_bytes
    encode_cpu_s = sum(c for _, _, c, _ in results) + flat_cpu
    meta = {"base_step": base_step, "step": step, "timestamp": timestamp,
            "mode": mode, "codec": codec_name, "scheme": "sub+xor",
            "placement": placement,
            "zero": [k for k, _, _, z in results if z] + zero_flat,
            "extra": extra or {}}
    if flat_meta is not None:
        meta["flat"] = flat_meta
    write_json_atomic(os.path.join(tmp, "delta_manifest.json"), meta)
    publish_dir_atomic(tmp, path)
    return path, nbytes, encode_cpu_s


def read_delta_manifest(directory: str, step: int) -> Optional[dict]:
    mpath = os.path.join(delta_dir(directory, step), "delta_manifest.json")
    if not os.path.exists(mpath):
        return None
    try:
        with open(mpath) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError):
        return None


def newest_delta_step(directory: str) -> Optional[int]:
    steps = []
    for name in os.listdir(directory):
        if name.startswith("delta_") and not name.endswith(".tmp"):
            step = int(name.split("_")[1])
            if read_delta_manifest(directory, step) is not None:
                steps.append(step)
    return max(steps) if steps else None


def _decode_leaf(ddir: str, name: str, leaf: np.ndarray, mode: str,
                 xor_ints: bool, zero: frozenset, decompress,
                 device: bool = False) -> np.ndarray:
    """Read + decompress + decode one leaf (runs on an io worker).

    ``device=True`` runs the f32 decode through the ``kernels/ckpt_delta``
    Pallas kernels instead of the ref.py host oracle — bit-identical
    output (the kernels are oracle-verified), so either placement restores
    blobs written by either encoder."""
    key = name.replace("/", "@")
    if name.replace("/", "::") in zero:     # unchanged leaf: base as-is
        return leaf
    if mode == "lossless":
        with open(os.path.join(ddir, key + ".bin"), "rb") as f:
            raw = decompress(f.read())
        if leaf.dtype == np.float32:
            delta = np.frombuffer(raw, np.float32)
            rpath = os.path.join(ddir, key + "@r.bin")
            if os.path.exists(rpath):        # bit-exactness correction
                with open(rpath, "rb") as f:
                    resid = np.frombuffer(decompress(f.read()), np.uint32)
                if device:
                    from repro.kernels.ckpt_delta.ops import (
                        default_interpret, lossless_decode)
                    out = np.asarray(lossless_decode(
                        leaf.reshape(-1), delta, resid,
                        interpret=default_interpret()))[:leaf.size]
                    return out.reshape(leaf.shape)
                from repro.kernels.ckpt_delta.ref import lossless_decode_ref
                return lossless_decode_ref(leaf, delta,
                                           resid).reshape(leaf.shape)
            return (leaf.reshape(-1) + delta).reshape(leaf.shape)
        if np.issubdtype(leaf.dtype, np.floating):
            delta = np.frombuffer(raw, np.float32).reshape(leaf.shape)
            pred = (leaf.astype(np.float32) + delta).astype(leaf.dtype)
            rpath = os.path.join(ddir, key + "@r.bin")
            if os.path.exists(rpath):        # bit-exactness correction
                with open(rpath, "rb") as f:
                    resid = np.frombuffer(decompress(f.read()), np.uint8)
                exact = np.frombuffer(pred.tobytes(), np.uint8) ^ resid
                pred = np.frombuffer(exact.tobytes(),
                                     leaf.dtype).reshape(leaf.shape)
            return pred
        if xor_ints:
            xored = np.frombuffer(raw, np.uint8)
            base_b = np.frombuffer(leaf.tobytes(), np.uint8)
            return np.frombuffer((xored ^ base_b).tobytes(),
                                 leaf.dtype).reshape(leaf.shape)
        # legacy scheme stored the raw leaf bytes
        return np.frombuffer(raw, leaf.dtype).reshape(leaf.shape)
    with open(os.path.join(ddir, key + "@q.bin"), "rb") as f:
        q = np.frombuffer(decompress(f.read()), np.int8)
    with open(os.path.join(ddir, key + "@s.bin"), "rb") as f:
        s = np.frombuffer(decompress(f.read()), np.float32)
    if device:
        from repro.kernels.ckpt_delta.ops import (default_interpret,
                                                  delta_decode)
        delta = np.asarray(delta_decode(
            q, s, interpret=default_interpret()))[:leaf.size]
        delta = delta.reshape(leaf.shape)
    else:
        from repro.kernels.ckpt_delta.ref import decode_ref
        delta = decode_ref(q, s)[:leaf.size].reshape(leaf.shape)
    return (leaf.astype(np.float32) + delta).astype(leaf.dtype)


def _decode_flat(ddir: str, flat: dict, mode: str, zero: frozenset,
                 base_leaves: dict, decompress, device: bool) -> dict:
    """Decode the flat mega-buffer payload back into per-leaf arrays.

    Rebuilds the packed base from the restored base leaves (host-side,
    matching ``FlatLayout``'s GROUP-aligned zero-padding), applies the
    flat delta — sub+XOR-residual or int8 dequant, through the Pallas
    kernels when ``device=True``, the ref.py oracles otherwise — in ONE
    vectorized pass, then slices each leaf back out by its manifest
    extent.  Leaves in ``zero`` take the base as-is.  Returns
    {name: decoded array} for every packed leaf."""
    entries = [(key.replace("::", "/"), int(off), int(size), tuple(shape))
               for key, off, size, shape in flat["layout"]]
    from repro.checkpoint.pipeline import io_pool
    arrays: dict[str, np.ndarray] = {}
    for sfx, spec in flat.get("arrays", {}).items():
        if spec == "zero":
            continue
        arrays[sfx] = decompress_frames(
            os.path.join(ddir, spec["file"]), spec["frames"],
            np.dtype(spec["dtype"]), decompress, io_pool())
    if not arrays:                  # every packed leaf was unchanged
        return {name: base_leaves[name] for name, _, _, _ in entries}
    total = int(flat["size"])
    base_flat = np.zeros(total, np.float32)
    for name, off, size, _ in entries:
        base_flat[off:off + size] = np.ascontiguousarray(
            base_leaves[name], np.float32).reshape(-1)
    if mode == "lossless":
        delta = arrays["d"]
        resid = arrays.get("r")
        if resid is None:           # skipped all-zero residual plane
            resid = np.zeros(total, np.uint32)
        if device:
            from repro.kernels.ckpt_delta.ops import (default_interpret,
                                                      lossless_decode)
            out_flat = np.asarray(lossless_decode(
                base_flat, delta, resid,
                interpret=default_interpret()))[:total]
        else:
            from repro.kernels.ckpt_delta.ref import lossless_decode_ref
            out_flat = lossless_decode_ref(base_flat, delta, resid)
    else:
        if device:
            from repro.kernels.ckpt_delta.ops import (default_interpret,
                                                      delta_decode)
            dflat = np.asarray(delta_decode(
                arrays["q"], arrays["s"],
                interpret=default_interpret()))[:total]
        else:
            from repro.kernels.ckpt_delta.ref import decode_ref
            dflat = decode_ref(arrays["q"], arrays["s"])[:total]
        out_flat = base_flat + dflat
    out: dict[str, np.ndarray] = {}
    for name, off, size, shape in entries:
        if name.replace("/", "::") in zero:
            out[name] = base_leaves[name]       # unchanged: base as-is
        else:
            out[name] = out_flat[off:off + size].reshape(shape)
    return out


def apply_delta(directory: str, step: int, base_state: Any,
                placement: str = "host") -> Any:
    """Apply the delta at ``step`` on top of ``base_state`` (the restored
    base full snapshot).  Codec and mode come from the delta manifest; the
    flat mega-buffer section (if present) decodes in one vectorized pass
    and the remaining per-leaf blobs decode concurrently (mirror of the
    pipelined write path) — so v3 flat deltas, v2 per-leaf deltas, and
    mixed deltas all restore through this one reader.

    ``placement`` selects where the DECODE runs ("host" via ref.py, or
    "device" via the Pallas kernels) and is independent of the placement
    the delta was encoded with — blobs are byte-compatible both ways, so
    a host-encoded checkpoint restores through the device path and vice
    versa."""
    assert placement in ("host", "device"), placement
    meta = read_delta_manifest(directory, step)
    if meta is None:
        raise FileNotFoundError(f"delta {step} is corrupt or missing")
    # pre-refactor manifests carry no codec/scheme/zero fields: they were
    # written with the then-unconditional zstd, float deltas had no XOR
    # residual (handled below by the missing @r.bin) and non-float leaves
    # stored raw bytes rather than an XOR vs the base
    decompress = get_decompressor(meta.get("codec", "zstd"))
    mode = meta.get("mode", "lossless")
    xor_ints = meta.get("scheme") == "sub+xor"
    zero = frozenset(meta.get("zero", ()))
    ddir = delta_dir(directory, step)
    names = [n for n, _ in tree_flatten_with_names(base_state)]
    leaves = [np.asarray(l) for l in jax.tree_util.tree_leaves(base_state)]
    flat_out: dict[str, np.ndarray] = {}
    flat = meta.get("flat")
    if flat:
        flat_out = _decode_flat(ddir, flat, mode, zero,
                                dict(zip(names, leaves)), decompress,
                                placement == "device")
    from repro.checkpoint.pipeline import io_pool
    futures = {name: io_pool().submit(_decode_leaf, ddir, name, leaf, mode,
                                      xor_ints, zero, decompress,
                                      placement == "device")
               for name, leaf in zip(names, leaves) if name not in flat_out}
    out = [flat_out[name] if name in flat_out else futures[name].result()
           for name in names]
    treedef = jax.tree_util.tree_structure(base_state)
    return jax.tree_util.tree_unflatten(treedef, out)


class IncrementalCheckpointer:
    def __init__(self, store: CheckpointStore, full_every: int = 8,
                 mode: str = "lossless", zstd_level: int = 3,
                 codec: str = "auto"):
        assert mode in ("lossless", "int8")
        self.store = store
        self.full_every = full_every
        self.mode = mode
        self.codec = codec
        self.zstd_level = zstd_level
        self._count = 0
        self._base: Optional[Any] = None
        self._base_step: Optional[int] = None
        self.bytes_written_full = 0
        self.bytes_written_delta = 0
        # pre-compression, post-encode bytes (this legacy checkpointer is
        # host-encode only, so every save moves the raw state D2H) — kept
        # separate from the post-compression bytes above so the BENCH
        # artifacts and the cost model don't conflate link and disk traffic
        self.bytes_on_link = 0

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, timestamp: float = 0.0,
             extra: Optional[dict] = None) -> str:
        state_np = jax.tree_util.tree_map(np.asarray, state)
        self.bytes_on_link += sum(l.nbytes for l in
                                  jax.tree_util.tree_leaves(state_np))
        if self._count % self.full_every == 0 or self._base is None:
            path = self.store.save(step, state_np, timestamp,
                                   {**(extra or {}), "kind": "full"})
            self._base = state_np
            self._base_step = step
            self.bytes_written_full += self.store.total_bytes(step)
        else:
            path, nbytes, _ = write_delta(
                self.store.directory, step, state_np, self._base,
                self._base_step, timestamp, extra or {}, self.mode,
                self.codec, self.zstd_level)
            self.bytes_written_delta += nbytes
        self._count += 1
        return path

    # ------------------------------------------------------------------
    def newest_delta(self) -> Optional[int]:
        return newest_delta_step(self.store.directory)

    def restore(self, treedef_like: Any) -> tuple[Any, int]:
        """Restore newest state (full + newest applicable delta).
        Returns (state, step)."""
        full_step = self.store.newest()
        if full_step is None:
            raise FileNotFoundError("no full checkpoint")
        state, _ = self.store.restore(treedef_like, full_step)
        dstep = self.newest_delta()
        if dstep is None or dstep <= full_step:
            return state, full_step
        meta = read_delta_manifest(self.store.directory, dstep)
        if meta is None or meta["base_step"] != full_step:
            return state, full_step   # delta belongs to an older chain
        return apply_delta(self.store.directory, dstep, state), dstep

    def stats(self) -> dict:
        return {"saves": self._count,
                "bytes_written_full": self.bytes_written_full,
                "bytes_written_delta": self.bytes_written_delta,
                "bytes_on_link": self.bytes_on_link}
