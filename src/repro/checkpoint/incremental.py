"""Incremental (delta) checkpointing.

Between full checkpoints, only the (zstd-compressed) delta vs the last
*full* checkpoint is persisted — optimizer-adjacent tensors change slowly,
so deltas compress hard.  Two modes:

  * ``lossless`` (default): delta = new - base, raw bytes zstd-compressed;
    restore is bit-exact.
  * ``int8``: per-group int8 quantized delta (the ``kernels/ckpt_delta``
    Pallas kernel implements the encode on-TPU; host fallback is its
    ref.py oracle).  Lossy — used as a cheap level-1 in multi-level
    schemes (paper-cited [21]); never for the level-2 full snapshots.

Chain layout: full_0, delta_1..delta_{k-1}, full_k, ...; restore loads the
newest full plus its newest delta (deltas are vs the base full, not
chained, so restore reads at most two objects).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import numpy as np
import zstandard as zstd

import jax

from repro.checkpoint.store import CheckpointStore
from repro.utils.trees import tree_flatten_with_names


class IncrementalCheckpointer:
    def __init__(self, store: CheckpointStore, full_every: int = 8,
                 mode: str = "lossless", zstd_level: int = 3):
        assert mode in ("lossless", "int8")
        self.store = store
        self.full_every = full_every
        self.mode = mode
        self.zstd_level = zstd_level
        self._count = 0
        self._base: Optional[Any] = None
        self._base_step: Optional[int] = None
        self.bytes_written_full = 0
        self.bytes_written_delta = 0

    # ------------------------------------------------------------------
    def _delta_dir(self, step: int) -> str:
        return os.path.join(self.store.directory, f"delta_{step:010d}")

    def save(self, step: int, state: Any, timestamp: float = 0.0,
             extra: Optional[dict] = None) -> str:
        state_np = jax.tree_util.tree_map(np.asarray, state)
        if self._count % self.full_every == 0 or self._base is None:
            path = self.store.save(step, state_np, timestamp,
                                   {**(extra or {}), "kind": "full"})
            self._base = state_np
            self._base_step = step
            self.bytes_written_full += self.store.total_bytes(step)
        else:
            path = self._save_delta(step, state_np, timestamp, extra or {})
        self._count += 1
        return path

    def _save_delta(self, step: int, state_np: Any, timestamp: float,
                    extra: dict) -> str:
        cctx = zstd.ZstdCompressor(level=self.zstd_level)
        blobs = {}
        meta = {"base_step": self._base_step, "step": step,
                "timestamp": timestamp, "mode": self.mode, "extra": extra}
        base_leaves = dict(tree_flatten_with_names(self._base))
        for name, leaf in tree_flatten_with_names(state_np):
            base = base_leaves[name]
            if self.mode == "lossless":
                delta = (leaf.astype(np.float32) - base.astype(np.float32)
                         if np.issubdtype(leaf.dtype, np.floating) else leaf)
                blobs[name.replace("/", "::")] = cctx.compress(delta.tobytes())
                continue
            # int8 group-quantized delta (host-side oracle of kernels/ckpt_delta)
            from repro.kernels.ckpt_delta.ref import encode_ref
            delta = leaf.astype(np.float32) - base.astype(np.float32)
            q, scales = encode_ref(delta.reshape(-1))
            blobs[name.replace("/", "::") + "::q"] = cctx.compress(q.tobytes())
            blobs[name.replace("/", "::") + "::s"] = cctx.compress(scales.tobytes())
        path = self._delta_dir(step)
        tmp = path + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        nbytes = 0
        for k, blob in blobs.items():
            fp = os.path.join(tmp, k.replace("::", "@") + ".bin")
            with open(fp, "wb") as f:
                f.write(blob)
            nbytes += len(blob)
        with open(os.path.join(tmp, "delta_manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(path):
            import shutil
            shutil.rmtree(path)
        os.rename(tmp, path)
        self.bytes_written_delta += nbytes
        return path

    # ------------------------------------------------------------------
    def newest_delta(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.store.directory):
            if name.startswith("delta_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.store.directory, name,
                                               "delta_manifest.json")):
                    steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, treedef_like: Any) -> tuple[Any, int]:
        """Restore newest state (full + newest applicable delta).
        Returns (state, step)."""
        full_step = self.store.newest()
        if full_step is None:
            raise FileNotFoundError("no full checkpoint")
        state, _ = self.store.restore(treedef_like, full_step)
        dstep = self.newest_delta()
        if dstep is None or dstep <= full_step:
            return state, full_step
        ddir = self._delta_dir(dstep)
        with open(os.path.join(ddir, "delta_manifest.json")) as f:
            meta = json.load(f)
        if meta["base_step"] != full_step:
            return state, full_step   # delta belongs to an older chain
        dctx = zstd.ZstdDecompressor()
        out = []
        names = [n for n, _ in tree_flatten_with_names(state)]
        leaves = jax.tree_util.tree_leaves(state)
        for name, leaf in zip(names, leaves):
            leaf = np.asarray(leaf)
            key = name.replace("/", "@")
            if self.mode == "lossless":
                fp = os.path.join(ddir, key + ".bin")
                raw = dctx.decompress(open(fp, "rb").read())
                if np.issubdtype(leaf.dtype, np.floating):
                    delta = np.frombuffer(raw, np.float32).reshape(leaf.shape)
                    out.append((leaf.astype(np.float32) + delta).astype(leaf.dtype))
                else:
                    out.append(np.frombuffer(raw, leaf.dtype).reshape(leaf.shape))
            else:
                from repro.kernels.ckpt_delta.ref import decode_ref
                q = np.frombuffer(dctx.decompress(
                    open(os.path.join(ddir, key + "@q.bin"), "rb").read()), np.int8)
                s = np.frombuffer(dctx.decompress(
                    open(os.path.join(ddir, key + "@s.bin"), "rb").read()), np.float32)
                delta = decode_ref(q, s)[:leaf.size].reshape(leaf.shape)
                out.append((leaf.astype(np.float32) + delta).astype(leaf.dtype))
        treedef = jax.tree_util.tree_structure(state)
        return jax.tree_util.tree_unflatten(treedef, out), dstep
