from repro.checkpoint.store import CheckpointStore, CheckpointMeta, HAVE_ZSTD
from repro.checkpoint.async_ckpt import AsyncCheckpointer, BackgroundCommitter
from repro.checkpoint.incremental import IncrementalCheckpointer
from repro.checkpoint.multilevel import MultiLevelCheckpointer
from repro.checkpoint.pipeline import (ChunkedHostSnapshot, DeltaLeafSource,
                                       DeviceDeltaBase, FlatLayout,
                                       LeafSource, PlainLeafSource,
                                       as_leaf_source)
from repro.checkpoint.policy import CheckpointPolicy
from repro.checkpoint.manager import (CheckpointManager, Checkpointer,
                                      RestoreReport, SaveReport)
from repro.checkpoint.replication import (PeerReplicatedStore, ReplicaStats,
                                          ReplicationError, retry_with_backoff,
                                          ring_peers)
from repro.config import CheckpointPlan

__all__ = [
    "CheckpointStore", "CheckpointMeta", "AsyncCheckpointer",
    "BackgroundCommitter", "IncrementalCheckpointer",
    "MultiLevelCheckpointer", "CheckpointPolicy", "CheckpointManager",
    "Checkpointer", "CheckpointPlan", "SaveReport", "RestoreReport",
    "HAVE_ZSTD", "ChunkedHostSnapshot", "DeltaLeafSource", "DeviceDeltaBase",
    "FlatLayout", "LeafSource", "PlainLeafSource", "as_leaf_source",
    "PeerReplicatedStore", "ReplicaStats", "ReplicationError",
    "retry_with_backoff", "ring_peers",
]
