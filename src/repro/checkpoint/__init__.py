from repro.checkpoint.store import CheckpointStore, CheckpointMeta
from repro.checkpoint.async_ckpt import AsyncCheckpointer
from repro.checkpoint.incremental import IncrementalCheckpointer
from repro.checkpoint.multilevel import MultiLevelCheckpointer
from repro.checkpoint.policy import CheckpointPolicy

__all__ = [
    "CheckpointStore", "CheckpointMeta", "AsyncCheckpointer",
    "IncrementalCheckpointer", "MultiLevelCheckpointer", "CheckpointPolicy",
]
