"""Sharded atomic checkpoint store.

Layout (one directory per checkpoint):

    <dir>/step_00001234/
        shard_00000.npz ... shard_000HH.npz    # per-host leaf groups
        manifest.json                          # written LAST = commit marker

Atomicity: shards are written first (concurrently, on the shared
``pipeline.io_pool`` — save accepts a still-transferring chunked snapshot
and each shard worker blocks only on the chunks holding its own leaves),
then the manifest (with per-shard CRC32 checksums and the full tree spec)
is written to a temp file and renamed into place.  A checkpoint without a valid manifest (or with a
checksum mismatch) is invisible to ``newest``/``restore`` — crash-during-
write simply falls back to the previous checkpoint.

Resharding: the manifest records the leaf->shard assignment, so restore
works with any host count — each restoring host reads the files holding
its leaves.  On a real multi-host cluster each shard holds that host's
*slices*; on this single-process substrate shards hold whole leaves
(bin-packed by bytes), which exercises the same manifest-driven reshard
logic (DESIGN.md §5).
"""
from __future__ import annotations

import json
import os
import shutil
import time
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Optional

import numpy as np

from repro.utils.trees import tree_flatten_with_names

import jax


# ---------------------------------------------------------------------------
# Compression codecs (zstd preferred, zlib always available)
# ---------------------------------------------------------------------------

try:
    import zstandard as _zstd
    HAVE_ZSTD = True
except ImportError:          # offline environments: stdlib fallback
    _zstd = None
    HAVE_ZSTD = False


def resolve_codec(name: str = "auto") -> str:
    """Map a requested codec name to an available one."""
    if name in ("auto", "zstd"):
        return "zstd" if HAVE_ZSTD else "zlib"
    if name != "zlib":
        raise ValueError(f"unknown codec {name!r}")
    return "zlib"


def get_compressor(name: str = "auto", level: int = 3
                   ) -> tuple[str, Callable[[bytes], bytes]]:
    """Returns (resolved_codec_name, compress_fn).  The resolved name must
    be recorded in the manifest so restore can pick the matching codec."""
    codec = resolve_codec(name)
    if codec == "zstd":
        # fresh context per call: the pipelined writers compress leaves
        # concurrently on the io pool and zstd contexts are not thread-safe
        return codec, lambda data: _zstd.ZstdCompressor(level=level).compress(data)
    return codec, lambda data: zlib.compress(data, level)


def get_decompressor(name: str) -> Callable[[bytes], bytes]:
    """Decompressor for a codec name read back from a manifest."""
    codec = resolve_codec(name)
    if codec == "zstd":
        if not HAVE_ZSTD:
            raise RuntimeError("checkpoint was written with zstd but "
                               "zstandard is not installed")
        return lambda data: _zstd.ZstdDecompressor().decompress(data)
    return zlib.decompress


# ---------------------------------------------------------------------------
# Framed compression for large single-array payloads (the flat delta
# mega-buffer): the per-leaf paths get compression parallelism for free
# (one leaf per io worker), a single big array would serialize it — so it
# is compressed as independent fixed-size frames whose compressed lengths
# are recorded in the manifest, letting the decoder split the file and
# decompress frames in parallel too.
# ---------------------------------------------------------------------------

FLAT_FRAME_BYTES = 8 << 20


def compress_frames(arr: np.ndarray, compress, pool,
                    frame_bytes: int = FLAT_FRAME_BYTES
                    ) -> tuple[list, list, float]:
    """Compress ``arr``'s bytes as independent frames, concurrently on
    ``pool``.  Returns (frames, frame_lens, cpu_s) — ``frame_lens`` goes in
    the manifest for ``decompress_frames``; ``cpu_s`` sums per-worker CPU
    seconds (the encode-cost quantity the calibration records)."""
    data = memoryview(np.ascontiguousarray(arr).reshape(-1)).cast("B")

    def one(a: int) -> tuple[bytes, float]:
        t0 = time.thread_time()
        blob = compress(bytes(data[a:a + frame_bytes]))
        return blob, time.thread_time() - t0

    futs = [pool.submit(one, a) for a in range(0, len(data), frame_bytes)]
    results = [f.result() for f in futs]
    frames = [blob for blob, _ in results]
    return frames, [len(b) for b in frames], sum(c for _, c in results)


def decompress_frames(path: str, frame_lens: list, dtype, decompress,
                      pool) -> np.ndarray:
    """Inverse of ``compress_frames``: read the file, split it on the
    recorded frame lengths, decompress frames in parallel, reassemble."""
    with open(path, "rb") as f:
        data = f.read()
    offs = [0]
    for n in frame_lens:
        offs.append(offs[-1] + int(n))
    futs = [pool.submit(decompress, data[offs[i]:offs[i + 1]])
            for i in range(len(frame_lens))]
    return np.frombuffer(b"".join(f.result() for f in futs), dtype)


# ---------------------------------------------------------------------------
# Atomic-publish helpers (shared by the full-snapshot store, the delta
# writer and anything else that commits a directory of files at once)
# ---------------------------------------------------------------------------

def write_json_atomic(path: str, obj: dict) -> None:
    """Write JSON via temp-file + rename; the rename is the commit point."""
    with open(path + ".part", "w") as f:
        json.dump(obj, f)
    os.rename(path + ".part", path)


def publish_dir_atomic(tmp: str, path: str) -> None:
    """Atomically publish a fully-written temp directory at ``path``.

    If ``path`` already exists (same step re-saved after a rollback) the old
    copy is superseded; a crash between the rmtree and the rename leaves no
    manifest at ``path`` so older checkpoints still win.
    """
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def fresh_tmp_dir(path: str) -> str:
    """Create (or recreate) the scratch dir a checkpoint is staged in."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    return tmp


@dataclass
class CheckpointMeta:
    step: int
    timestamp: float
    num_shards: int
    extra: dict

    @property
    def name(self) -> str:
        return f"step_{self.step:010d}"


def _assign_shards(sizes_by_name: list[tuple[str, int]], num_shards: int):
    """Greedy balanced bin-packing of leaves into shards by bytes."""
    sizes = sorted(((nb, name) for name, nb in sizes_by_name), reverse=True)
    loads = [0] * num_shards
    assign: dict[str, int] = {}
    for nbytes, name in sizes:
        j = int(np.argmin(loads))
        loads[j] += nbytes
        assign[name] = j
    return assign


class CheckpointStore:
    def __init__(self, directory: str, num_shards: int = 4, keep: int = 3,
                 num_hosts: Optional[int] = None,
                 fault_hook: Optional[Callable[[str], None]] = None,
                 write_attempts: int = 4, write_backoff_s: float = 0.01):
        self.directory = directory
        self.num_shards = num_shards
        # shard j lives on simulated host ``j % num_hosts`` — the manifest
        # records this placement so failure injection can kill exactly one
        # host's files (on this substrate hosts == shards by default)
        self.num_hosts = num_hosts if num_hosts is not None else num_shards
        self.keep = keep
        # transient-IO injection point for tests: called with the target
        # path before every file write attempt; raising OSError from it
        # exercises the bounded-retry path below
        self.fault_hook = fault_hook
        self.write_attempts = write_attempts
        self.write_backoff_s = write_backoff_s
        self.saves = 0
        self.bytes_written = 0
        self.write_retries = 0
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, timestamp: float = 0.0,
             extra: Optional[dict] = None) -> str:
        """Write one checkpoint.  ``state`` is a pytree or a
        ``pipeline.LeafSource`` (e.g. a chunked snapshot still transferring
        from the device): shards are planned from leaf specs alone, then
        written concurrently on the io pool — each shard worker starts as
        soon as the chunks holding its leaves have landed, overlapping D2H
        with serialization.  The manifest is written only after every shard
        has, so the commit-marker invariant is untouched."""
        from repro.checkpoint.pipeline import as_leaf_source, io_pool

        src = as_leaf_source(state)
        assign = _assign_shards([(n, src.nbytes(n)) for n in src.names],
                                self.num_shards)
        name = f"step_{step:010d}"
        path = os.path.join(self.directory, name)
        tmp = fresh_tmp_dir(path)

        def write_shard(j: int) -> tuple[str, int]:
            from repro.checkpoint.replication import retry_with_backoff

            shard = {n.replace("/", "::"): np.asarray(src.get(n))
                     for n in src.names if assign[n] == j}
            fpath = os.path.join(tmp, f"shard_{j:05d}.npz")

            # transient IO errors (flaky disk / NFS hiccup on the remote
            # level) get bounded retries with jittered backoff instead of
            # failing the whole save; a persistent error still propagates
            # and the un-manifested .tmp dir stays invisible to restore
            def attempt() -> int:
                if self.fault_hook is not None:
                    self.fault_hook(fpath)
                np.savez(fpath, **shard)
                with open(fpath, "rb") as f:
                    return zlib.crc32(f.read())

            def note_retry(i: int, e: BaseException) -> None:
                self.write_retries += 1

            crc = retry_with_backoff(attempt, attempts=self.write_attempts,
                                     base_s=self.write_backoff_s,
                                     on_retry=note_retry)
            return f"shard_{j:05d}.npz", crc

        futures = [io_pool().submit(write_shard, j)
                   for j in range(self.num_shards)]
        checksums = dict(f.result() for f in futures)
        # the replica-push phase (PeerReplicatedStore) runs BETWEEN the
        # primary shard writes and the manifest commit: a failed quorum
        # raises before anything becomes visible
        replicas = self._push_replicas(tmp, checksums)

        specs = {n: src.spec(n) for n in src.names}
        manifest = {
            "step": step,
            "timestamp": timestamp,
            "num_shards": self.num_shards,
            "assign": assign,
            "checksums": checksums,
            "placement": {
                "num_hosts": self.num_hosts,
                "owners": {f: self._file_host(f) for f in checksums},
            },
            "dtypes": {n: str(dt) for n, (_, dt) in specs.items()},
            "shapes": {n: list(shape) for n, (shape, _) in specs.items()},
            "extra": extra or {},
        }
        if replicas:
            manifest["replicas"] = replicas
        write_json_atomic(os.path.join(tmp, "manifest.json"), manifest)
        publish_dir_atomic(tmp, path)
        self.saves += 1
        self.bytes_written += self.total_bytes(step)
        self._gc()
        return path

    def _push_replicas(self, tmp: str, checksums: dict) -> Optional[dict]:
        """Replication hook between shard writes and the manifest commit.
        The plain store replicates nothing (level-3 durability comes from
        the remote medium itself); ``replication.PeerReplicatedStore``
        overrides this with the ring push + quorum rule."""
        return None

    def stats(self) -> dict:
        return {"saves": self.saves, "bytes_written": self.bytes_written,
                "write_retries": self.write_retries}

    # -- host placement -------------------------------------------------------
    def _file_host(self, fname: str) -> Optional[int]:
        """Which simulated host's disk a checkpoint file lives on (None
        for files not owned by any single host, e.g. the manifest)."""
        if fname.startswith("shard_") and fname.endswith(".npz"):
            return int(fname[6:11]) % self.num_hosts
        return None

    def kill_host(self, host: int) -> list[str]:
        """Failure injection: host ``host``'s node-local disk dies, taking
        every checkpoint file placed on it (across all steps) with it.
        On the un-replicated store this leaves affected steps without a
        valid copy of the dead host's shards — exactly the degradation
        the replicated subclass exists to survive."""
        removed = []
        for name in sorted(os.listdir(self.directory)):
            d = os.path.join(self.directory, name)
            if not name.startswith("step_") or not os.path.isdir(d):
                continue
            for fname in sorted(os.listdir(d)):
                if self._file_host(fname) == host:
                    os.remove(os.path.join(d, fname))
                    removed.append(os.path.join(name, fname))
        return removed

    # -- introspection --------------------------------------------------------
    def _manifest(self, name: str) -> Optional[dict]:
        """Load a step's manifest without checksum validation."""
        mpath = os.path.join(self.directory, name, "manifest.json")
        if not os.path.exists(mpath):
            return None
        try:
            with open(mpath) as f:
                return json.load(f)
        except (json.JSONDecodeError, OSError):
            return None

    def _file_ok(self, name: str, fname: str, crc: int) -> bool:
        fpath = os.path.join(self.directory, name, fname)
        if not os.path.exists(fpath):
            return False
        with open(fpath, "rb") as f:
            return zlib.crc32(f.read()) == crc

    def _valid(self, name: str) -> Optional[dict]:
        manifest = self._manifest(name)
        if manifest is None:
            return None
        for fname, crc in manifest["checksums"].items():
            if not self._file_ok(name, fname, crc):
                return None
        return manifest

    def list_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.directory)):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if self._valid(name) is not None:
                    out.append(int(name.split("_")[1]))
        return out

    def newest(self) -> Optional[int]:
        steps = self.list_steps()
        return steps[-1] if steps else None

    # -- restore ---------------------------------------------------------------
    def restore(self, treedef_like: Any, step: Optional[int] = None) -> tuple[Any, dict]:
        """Restore into the structure of ``treedef_like`` (a pytree of arrays
        or ShapeDtypeStructs).  Returns (state, extra)."""
        step = step if step is not None else self.newest()
        if step is None:
            raise FileNotFoundError("no valid checkpoint found")
        name = f"step_{step:010d}"
        manifest = self._valid(name)
        if manifest is None:
            raise FileNotFoundError(f"checkpoint {name} is corrupt or missing")
        from repro.checkpoint.pipeline import io_pool

        def load_shard(j: int) -> dict[str, np.ndarray]:
            fpath = os.path.join(self.directory, name, f"shard_{j:05d}.npz")
            with np.load(fpath) as z:
                return {k.replace("::", "/"): z[k] for k in z.files}

        data: dict[str, np.ndarray] = {}
        for fut in [io_pool().submit(load_shard, j)
                    for j in range(manifest["num_shards"])]:
            data.update(fut.result())
        names = [n for n, _ in tree_flatten_with_names(treedef_like)]
        missing = [n for n in names if n not in data]
        if missing:
            raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
        leaves_struct = jax.tree_util.tree_leaves(treedef_like)
        treedef = jax.tree_util.tree_structure(treedef_like)
        restored = [data[n] for n in names]
        restored = [np.asarray(v, dtype=s.dtype) if hasattr(s, "dtype") else v
                    for v, s in zip(restored, leaves_struct)]
        return jax.tree_util.tree_unflatten(treedef, restored), manifest["extra"]

    def read_leaves(self, step: int, names: list) -> dict[str, np.ndarray]:
        """Load only the shards holding ``names`` — the per-shard remote
        fallback of a degraded partial restore reads exactly the failed
        host's leaves, never the whole checkpoint.  Leaf names are
        layout-independent, so a remote store with a different shard
        assignment serves a local store's missing shard correctly."""
        name = f"step_{step:010d}"
        manifest = self._valid(name)
        if manifest is None:
            raise FileNotFoundError(f"checkpoint {name} is corrupt or missing")
        assign = manifest["assign"]
        missing = [n for n in names if n not in assign]
        if missing:
            raise KeyError(f"checkpoint missing leaves: {missing[:5]}...")
        wanted = set(names)
        from repro.checkpoint.pipeline import io_pool

        def load_shard(j: int) -> dict[str, np.ndarray]:
            fpath = os.path.join(self.directory, name, f"shard_{j:05d}.npz")
            with np.load(fpath) as z:
                return {k.replace("::", "/"): z[k] for k in z.files
                        if k.replace("::", "/") in wanted}

        data: dict[str, np.ndarray] = {}
        for fut in [io_pool().submit(load_shard, j)
                    for j in sorted({assign[n] for n in names})]:
            data.update(fut.result())
        return data

    def total_bytes(self, step: int) -> int:
        name = f"step_{step:010d}"
        p = os.path.join(self.directory, name)
        return sum(os.path.getsize(os.path.join(p, f)) for f in os.listdir(p))

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:010d}"),
                          ignore_errors=True)
