"""Unified checkpoint plane: one ``Checkpointer`` protocol, one manager.

Before this module existed the repo had four checkpointer implementations
with incompatible interfaces (store/incremental/multilevel/async) and the
trainer, simulator and controller each only knew the plain full-snapshot
path.  ``CheckpointManager`` composes those pieces as *layers* behind a
single protocol, configured by ``config.CheckpointPlan``:

        trigger            CheckpointPolicy.due(t)   (the Khaos CI knob)
           |
        snapshot           chunked D2H transfer (pipeline.ChunkedHost-
           |               Snapshot): mutable host leaves copy eagerly,
           |               device chunks stream on the transfer pool —
           |               only the first chunk's device sync blocks
           |
        encode             full snapshot, or delta vs the last full
           |                 (lossless sub+XOR-residual or int8, both with
           |                  a kernels/ckpt_delta Pallas codec and its
           |                  ref.py host oracle), leaf-parallel on the
           |                  io pool, overlapped with the D2H stream;
           |                  unchanged leaves short-circuit to a "zero"
           |                  manifest marker.
           |               plan.encode_placement == "device" swaps the
           |                 order of the two stages above: ONE fused
           |                 Pallas kernel encodes the packed f32 subtree
           |                 against the device-resident flat base
           |                 (pipeline.DeltaLeafSource) and only the
           |                 encoded payload crosses the link — bytes_on_-
           |                 link drops to ~0.26x state bytes for int8
           |
        compress           zstd when installed, zlib otherwise; the codec
           |                 used is recorded in the delta manifest
           |
        level routing      memory  — in-RAM snapshot, every trigger
           |               local   — node-local store, every local_every-th
           |               remote  — durable store, every remote_every-th
           |                 (remote only ever receives FULL snapshots;
           |                  deltas stay with their base full's level)
           |
        commit             sync (blocks the step stream) or async via a
                           BackgroundCommitter (double-buffered, at most
                           one write in flight, skip/block busy policy);
                           shards write concurrently on the io pool either
                           way

    restore(treedef, failure_kind) walks the levels that survive the
    failure kind (multilevel.LEVEL_COVERAGE) newest-step-first, applies
    the newest matching delta on top of its base full, and reports which
    (level, kind) served the recovery — the controller prices exactly this
    path when it optimizes over plans.

Every save/restore returns a report carrying bytes + durations so the
trainer's metrics, the simulator's cost model and ``bench_ckpt`` all
account the same quantities.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import numpy as np

from repro.checkpoint.async_ckpt import BackgroundCommitter
from repro.checkpoint.incremental import (apply_delta, newest_delta_step,
                                          read_delta_manifest, write_delta)
from repro.checkpoint.multilevel import allowed_levels
from repro.checkpoint.pipeline import (ChunkedHostSnapshot, DeltaLeafSource,
                                       DeviceDeltaBase, PlainLeafSource)
from repro.checkpoint.policy import CheckpointPolicy
from repro.checkpoint.replication import PeerReplicatedStore
from repro.checkpoint.store import CheckpointStore
from repro.config import CheckpointPlan


@dataclass
class SaveReport:
    """What one save() actually did (all byte/duration accounting flows
    from here into metrics, the simulator calibration and benchmarks)."""
    step: int
    kind: str                       # full | delta | skipped
    levels: tuple = ()              # levels written this trigger
    bytes_written: int = 0          # post-compression bytes on disk
    bytes_on_link: int = 0          # pre-compression post-encode bytes the
                                    # trigger moved device->host — raw state
                                    # for host-encode paths, encoded payload
                                    # for device-encode deltas; the quantity
                                    # bench_ckpt/2 and the cost model price,
                                    # NOT the same thing as bytes_written
    duration_s: float = 0.0         # total write work (wall)
    blocking_s: float = 0.0         # portion that blocked the caller
    encode_s: float = 0.0           # delta encode+compress CPU seconds
    paths: tuple = ()
    synchronous: bool = True

    def __bool__(self) -> bool:     # truthy iff something was persisted
        return self.kind != "skipped"


@dataclass
class RestoreReport:
    state: Any
    step: int
    level: str                      # memory | local | remote
    kind: str                       # memory | full | full+delta
    duration_s: float
    extra: dict = field(default_factory=dict)
    degraded: bool = False          # a degraded partial restore: some shard
                                    # was rebuilt from peer replicas (or the
                                    # per-shard remote fallback)
    restored_bytes: int = 0         # bytes PULLED to rebuild dead shards —
                                    # the recovery-drill gate compares this
                                    # against the full checkpoint size


@runtime_checkable
class Checkpointer(Protocol):
    """The one interface all three planes (trainer, simulator cost
    accounting, controller) talk to."""

    def save(self, step: int, state: Any, timestamp: float = 0.0,
             extra: Optional[dict] = None) -> SaveReport: ...

    def restore(self, treedef_like: Any,
                failure_kind: str = "task") -> RestoreReport: ...

    def stats(self) -> dict: ...


class CheckpointManager:
    """Executes a ``CheckpointPlan``; the single checkpoint entry point."""

    def __init__(self, directory: str, plan: CheckpointPlan,
                 policy: Optional[CheckpointPolicy] = None):
        self.directory = directory
        self.plan = plan
        self.policy = policy or CheckpointPolicy(plan.interval_s)
        os.makedirs(directory, exist_ok=True)
        self.stores: dict[str, CheckpointStore] = {}
        for level in plan.disk_levels:
            if level == "local" and plan.effective_replication >= 1:
                # the replicated level-2 store: each host pushes its shard
                # to k ring peers, so a node loss is survivable HERE — the
                # survival rule the cost model derives from the same k
                self.stores[level] = PeerReplicatedStore(
                    os.path.join(directory, level),
                    num_shards=plan.num_shards, keep=plan.keep,
                    replication_factor=plan.effective_replication)
            else:
                self.stores[level] = CheckpointStore(
                    os.path.join(directory, level),
                    num_shards=plan.num_shards, keep=plan.keep)
        # first disk level is the primary: it anchors the delta chain
        self.primary_level: Optional[str] = (plan.disk_levels[0]
                                             if plan.disk_levels else None)
        self._memory: Optional[tuple[int, Any, dict]] = None   # newest only
        self._base: Optional[Any] = None       # last full snapshot (host)
        self._base_step: Optional[int] = None
        # device-resident twin of the host base (plan.encode_placement ==
        # "device"): immutable references to the last full's device leaves,
        # refreshed on every full trigger/savepoint so delta triggers can
        # encode on device without a host round trip
        self._device_base: Optional[DeviceDeltaBase] = None
        self._count = 0
        self._committer = (None if plan.sync
                           else BackgroundCommitter(plan.busy_policy))
        # accounting
        self.link_bytes = 0           # pre-compression post-encode (D2H)
        self.bytes_by_kind = {"full": 0, "delta": 0}
        self.saves_by_level = {l: 0 for l in ("memory", "local", "remote")}
        self.skips = 0
        self.savepoints = 0
        self.late_saves = 0           # triggers landing past their cadence
        self.late_by_s = 0.0          # slot, and by how much in total — a
                                      # backpressured trigger widens the
                                      # lost-work window the controller's
                                      # CI assumption prices, so the slip
                                      # is measured rather than silent
        self.restores: list[tuple[int, str, str]] = []

    def _mark_trigger(self, timestamp: float) -> None:
        """Advance the cadence clock, accounting how late the trigger ran
        relative to the slot that made it due (regular triggers only —
        ``savepoint`` is cadence-exempt and marks directly)."""
        slot = self.policy.next_due(timestamp)
        slip = timestamp - slot
        # polling quantization lands every trigger a little past its slot;
        # only a slip a controller could care about (5% of the interval)
        # counts as late — backpressure windows exceed this by design
        if slip > 0.05 * self.policy.interval_s:
            self.late_saves += 1
            self.late_by_s += slip
        self.policy.mark(timestamp)

    # -- save ---------------------------------------------------------------
    def _kind(self) -> str:
        if self._base is None:     # no live base: the chain must restart
            return "full"
        return "full" if self.plan.is_full_trigger(self._count) else "delta"

    def save(self, step: int, state: Any, timestamp: float = 0.0,
             extra: Optional[dict] = None) -> SaveReport:
        extra = extra or {}
        if self._committer is not None and self._committer.busy:
            if self.plan.busy_policy == "skip":
                self.skips += 1
                self._count += 1          # the trigger happened; cadence moves on
                self._mark_trigger(timestamp)
                return SaveReport(step, "skipped", synchronous=False)
            self._committer.wait()

        t0 = time.monotonic()
        kind = self._kind()
        levels = [l for l, _ in self.plan.levels_due(self._count)
                  if l == "memory" or l in self.stores]
        # a real copy when the snapshot outlives this call (async write in
        # flight, or parked at the memory level / as the delta base) —
        # aliasing host arrays the caller may mutate would corrupt it.
        # ChunkedHostSnapshot copies only the mutable host leaves up front;
        # immutable device chunks stream to the io workers in background,
        # so blocking_s is the first chunk's device sync, not the full copy.
        # plan.eager_snapshot disables the deferral (donated-buffer states:
        # the "immutable" device arrays are re-used by the next step)
        need_copy = (self._committer is not None or "memory" in levels
                     or self.plan.mode == "incremental")
        device_delta = (kind == "delta"
                        and self.plan.encode_placement == "device"
                        and self._device_base is not None)
        if device_delta:
            # encode in front of D2H: only the encoded payload crosses the
            # link; raw leaves stay lazily reachable (memory-level parking,
            # delta-upgraded-to-full self-heal) through immutable refs
            snap = DeltaLeafSource(state, self._device_base,
                                   codec=self.plan.delta_codec,
                                   chunk_bytes=self.plan.chunk_bytes)
        else:
            snap = (ChunkedHostSnapshot(
                        state, self.plan.chunk_bytes,
                        defer_device=not self.plan.eager_snapshot)
                    if need_copy else PlainLeafSource(state))
        if "memory" in levels:
            # the memory level always holds the decoded newest state (as a
            # possibly-still-transferring snapshot source) — a task restart
            # restores from RAM without touching the codec path
            self._memory = (step, snap, dict(extra))
            self.saves_by_level["memory"] += 1
        if kind == "full":
            self._base, self._base_step = snap, step
            if self.plan.encode_placement == "device":
                self._device_base = DeviceDeltaBase(state)
        base, base_step = self._base, self._base_step
        self._count += 1

        disk = [l for l in levels if l in self.stores]
        report = SaveReport(step, kind, tuple(levels), synchronous=self._committer is None)

        def commit() -> None:
            nbytes, paths, encode_s = 0, [], 0.0
            for level in disk:
                store = self.stores[level]
                # remote only ever receives fulls; a delta whose base full
                # is missing at a level would be unrestorable there
                write_full = (kind == "full" or level == "remote"
                              or store.newest() != base_step)
                if write_full:
                    paths.append(store.save(step, snap, timestamp,
                                            {**extra, "kind": "full"}))
                    n = store.total_bytes(step)
                    nbytes += n
                    self.bytes_by_kind["full"] += n
                else:
                    p, n, enc = write_delta(store.directory, step, snap,
                                            base, base_step, timestamp,
                                            extra,
                                            self.plan.delta_codec,
                                            self.plan.codec)
                    paths.append(p)
                    nbytes += n
                    encode_s += enc
                    self.bytes_by_kind["delta"] += n
                    if isinstance(store, PeerReplicatedStore):
                        # deltas aren't physically replicated (the post-
                        # failure chain restarts from a full) but their
                        # mirror traffic is priced — keep the measured
                        # replica_bytes twin honest
                        store.account_delta_mirror(n)
                self.saves_by_level[level] += 1
            report.bytes_written = nbytes
            report.bytes_on_link = snap.bytes_on_link()
            self.link_bytes += report.bytes_on_link
            report.encode_s = encode_s
            report.paths = tuple(paths)
            report.duration_s = time.monotonic() - t0

        if self._committer is None:
            commit()
            report.blocking_s = report.duration_s
        else:
            self._committer.submit(commit)
            report.blocking_s = time.monotonic() - t0   # snapshot only
        self._mark_trigger(timestamp)
        return report

    # -- savepoint (cadence-exempt checkpoint-now) ---------------------------
    def savepoint(self, step: int, state: Any, timestamp: float = 0.0,
                  extra: Optional[dict] = None) -> SaveReport:
        """Durable checkpoint-now: drain any in-flight commit, then write a
        FULL snapshot synchronously to EVERY configured level — ignoring
        the every-Nth level cadences, which gate regular triggers only.
        This is the drain barrier under a controlled reconfiguration:
        after it returns, nothing the job has processed can be lost, even
        if the next action discards this manager (a plan switch rebuild).
        Does not advance the trigger count (cadence patterns are
        unaffected); does anchor a fresh delta chain at ``step``."""
        extra = extra or {}
        self.wait()
        t0 = time.monotonic()
        snap = ChunkedHostSnapshot(state, self.plan.chunk_bytes,
                                   defer_device=not self.plan.eager_snapshot)
        levels = []
        if "memory" in self.plan.levels:
            self._memory = (step, snap, dict(extra))
            self.saves_by_level["memory"] += 1
            levels.append("memory")
        self._base, self._base_step = snap, step
        if self.plan.encode_placement == "device":
            # the savepoint anchors a fresh delta chain; refresh the
            # device-resident base so post-drain deltas encode against it
            self._device_base = DeviceDeltaBase(state)
        nbytes, paths = 0, []
        for level, store in self.stores.items():
            paths.append(store.save(step, snap, timestamp,
                                    {**extra, "kind": "full"}))
            n = store.total_bytes(step)
            nbytes += n
            self.bytes_by_kind["full"] += n
            self.saves_by_level[level] += 1
            levels.append(level)
        self.savepoints += 1
        self.policy.mark(timestamp)
        dur = time.monotonic() - t0
        self.link_bytes += snap.bytes_on_link()
        return SaveReport(step, "full", tuple(levels), nbytes,
                          bytes_on_link=snap.bytes_on_link(),
                          duration_s=dur, blocking_s=dur,
                          paths=tuple(paths), synchronous=True)

    # -- restore ------------------------------------------------------------
    def _remote_steps(self) -> tuple[int, ...]:
        remote = self.stores.get("remote")
        return tuple(remote.list_steps()) if remote is not None else ()

    def _disk_candidate(self, level: str) -> Optional[tuple[int, int]]:
        """(restore_step, base_full_step) for a disk level, or None."""
        store = self.stores.get(level)
        if store is None:
            return None
        if isinstance(store, PeerReplicatedStore):
            # a degraded step (some shards only on replicas, or coverable
            # per-shard by the remote store AT THE SAME STEP) still counts
            full = store.newest_restorable(self._remote_steps())
        else:
            full = store.newest()
        if full is None:
            return None
        dstep = newest_delta_step(store.directory)
        if dstep is not None and dstep > full:
            meta = read_delta_manifest(store.directory, dstep)
            if meta is not None and meta["base_step"] == full:
                return dstep, full
        return full, full

    def restore(self, treedef_like: Any,
                failure_kind: str = "task") -> RestoreReport:
        self.wait()
        t0 = time.monotonic()
        allowed = allowed_levels(failure_kind,
                                 self.plan.effective_replication)
        candidates: list[tuple[int, int, str]] = []   # (step, speed, level)
        speed = {"memory": 2, "local": 1, "remote": 0}
        if "memory" in allowed and self._memory is not None:
            candidates.append((self._memory[0], speed["memory"], "memory"))
        for level in ("local", "remote"):
            if level in allowed:
                cand = self._disk_candidate(level)
                if cand is not None:
                    candidates.append((cand[0], speed[level], level))
        if not candidates:
            raise FileNotFoundError(
                f"no checkpoint survives a {failure_kind} failure")
        step, _, level = max(candidates)
        if level == "memory":
            mstep, snap, extra = self._memory
            # deep copy so the caller can't corrupt the parked snapshot
            state = jax.tree_util.tree_map(lambda x: np.array(x, copy=True),
                                           snap.as_pytree())
            report = RestoreReport(state, mstep, "memory", "memory",
                                   time.monotonic() - t0, dict(extra))
        else:
            store = self.stores[level]
            restore_step, full_step = self._disk_candidate(level)
            degraded, restored_bytes = False, 0
            if isinstance(store, PeerReplicatedStore):
                # degraded partial restore: dead shards come from peer
                # replicas, and a shard with NO local copy falls back
                # per-shard to the remote store at the same step
                remote = self.stores.get("remote")
                fallback = remote.read_leaves if remote is not None else None
                state, extra = store.restore(treedef_like, full_step,
                                             shard_fallback=fallback)
                degraded = store.last_restore.get("degraded", False)
                restored_bytes = store.last_restore.get("restored_bytes", 0)
            else:
                state, extra = store.restore(treedef_like, full_step)
            kind = "full"
            if restore_step > full_step:
                meta = read_delta_manifest(store.directory, restore_step)
                # decode where this plan encodes; blobs are byte-compatible
                # across placements, so a host-written delta restores here
                # and a device-written one restores under a host plan
                state = apply_delta(store.directory, restore_step, state,
                                    placement=self.plan.encode_placement)
                extra = meta.get("extra", extra)
                kind = "full+delta"
            report = RestoreReport(state, restore_step, level, kind,
                                   time.monotonic() - t0, extra,
                                   degraded=degraded,
                                   restored_bytes=restored_bytes)
        self.restores.append((report.step, report.level, report.kind))
        return report

    # -- lifecycle / failure hooks -----------------------------------------
    def adopt_runtime_state(self, old: "CheckpointManager") -> None:
        """Carry the in-RAM snapshot and delta base over from a manager
        this one replaces (the plan-switch rebuild): the predecessor's
        drain savepoint is the newest state, so task restarts keep their
        RAM path and incremental plans delta against the drained full —
        the invariant lives here, next to the fields it protects.  The
        device-resident delta base rides along, so a plan switch onto (or
        between) device-encode plans deltas against the drained full
        without re-uploading it."""
        self._memory = old._memory
        self._base, self._base_step = old._base, old._base_step
        self._device_base = old._device_base

    def wait(self) -> None:
        """Drain any in-flight async commit."""
        if self._committer is not None:
            self._committer.wait()

    def on_failure(self, failure_kind: str,
                   host: Optional[int] = None) -> None:
        """Apply a failure's destruction to the levels it wipes out.
        A host-targeted node failure (``host`` given) additionally kills
        that host's node-local disk — its primary shards and the replicas
        it held for peers — which is what makes the subsequent restore a
        DEGRADED partial restore instead of a free local read.  With no
        ``host`` the node failure models a process loss whose disk
        survives (the pre-replication semantics, kept for back-compat)."""
        if failure_kind in ("node", "cluster"):
            self._memory = None
            self._base = None     # host RAM gone: next save must be a full
            self._base_step = None
            self._device_base = None   # the device died with the job too
        if failure_kind == "node" and host is not None \
                and "local" in self.stores:
            self.stores["local"].kill_host(host)
        if failure_kind == "cluster" and "local" in self.stores:
            # the sim's cluster failure loses node-local disks too; real
            # deployments re-point the store at an empty scratch dir
            import shutil
            shutil.rmtree(self.stores["local"].directory, ignore_errors=True)
            os.makedirs(self.stores["local"].directory, exist_ok=True)

    def newest_step(self) -> Optional[int]:
        try:
            return self.restore_candidates()[0][0]
        except IndexError:
            return None

    def restore_candidates(self) -> list[tuple[int, str]]:
        """(step, level) restore options, best first (newest, then fastest)."""
        out = []
        if self._memory is not None:
            out.append((self._memory[0], 2, "memory"))
        for level in self.stores:
            cand = self._disk_candidate(level)
            if cand is not None:
                out.append((cand[0], {"local": 1, "remote": 0}[level], level))
        return [(s, l) for s, _, l in sorted(out, reverse=True)]

    def stats(self) -> dict:
        errors = (list(self._committer.errors)
                  if self._committer is not None else [])
        return {
            "saves": self._count,
            "skips": self.skips,
            "savepoints": self.savepoints,
            "late_saves": self.late_saves,
            "late_by_s": self.late_by_s,
            "bytes_by_kind": dict(self.bytes_by_kind),
            "bytes_written": sum(self.bytes_by_kind.values()),
            "bytes_on_link": self.link_bytes,
            "saves_by_level": dict(self.saves_by_level),
            "restores": list(self.restores),
            "async_errors": errors,
            "plan": self.plan.name,
        }
