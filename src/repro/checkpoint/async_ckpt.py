"""Asynchronous checkpointing: the blocking part of a save shrinks to the
chunked snapshot's first-chunk device sync (plus eager copies of any
mutable host leaves — see ``pipeline.ChunkedHostSnapshot``); the remaining
device->host chunks transfer in the background and the disk write runs on
a background thread consuming them, so the training step stream is not
blocked — double-buffered: at most one write in flight; a new snapshot
while busy either blocks ('block') or is dropped ('skip').

Crash-consistency: the underlying store only publishes a manifest after
all shards land, so a failure mid-write leaves the previous checkpoint as
the newest valid one.

``BackgroundCommitter`` is the reusable piece (one in-flight commit thunk
+ busy policy + error capture); ``AsyncCheckpointer`` is the legacy
store-bound wrapper and ``manager.CheckpointManager`` drives the committer
with composed (delta/multilevel) commit thunks.
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint.pipeline import ChunkedHostSnapshot
from repro.checkpoint.store import CheckpointStore


def snapshot_to_host(state: Any) -> Any:
    """Monolithic device -> host copy (the pre-pipeline blocking cost; kept
    as the reference point ``bench_ckpt`` compares the chunked snapshot
    against).  np.array(copy=True): np.asarray would ALIAS host-resident
    arrays and let later in-place mutation corrupt the in-flight
    snapshot."""
    return jax.tree_util.tree_map(lambda x: np.array(x, copy=True), state)


class BackgroundCommitter:
    """At most one commit thunk in flight on a daemon thread."""

    def __init__(self, busy_policy: str = "skip"):
        assert busy_policy in ("skip", "block")
        self.busy_policy = busy_policy
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self.commits = 0
        self.skips = 0
        self.errors: list = []

    def submit(self, thunk: Callable[[], None]) -> bool:
        """Run ``thunk`` in the background. Returns False if skipped."""
        if self._thread is not None and self._thread.is_alive():
            if self.busy_policy == "skip":
                self.skips += 1
                return False
            self._thread.join()

        def work():
            try:
                thunk()
                with self._lock:
                    self.commits += 1
            except Exception as e:   # noqa: BLE001
                with self._lock:
                    self.errors.append(repr(e))

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()

    @property
    def busy(self) -> bool:
        return self._thread is not None and self._thread.is_alive()


class AsyncCheckpointer:
    def __init__(self, store: CheckpointStore, busy_policy: str = "skip"):
        self.store = store
        self._committer = BackgroundCommitter(busy_policy)

    @property
    def busy_policy(self) -> str:
        return self._committer.busy_policy

    def _snapshot(self, state: Any) -> Any:
        # chunked: mutable host leaves copy now, device chunks stream to
        # the background write through the transfer pool
        return ChunkedHostSnapshot(state)

    def save(self, step: int, state: Any, timestamp: float = 0.0,
             extra: Optional[dict] = None) -> bool:
        """Snapshot now, write in background. Returns False if skipped."""
        if self._committer.busy and self._committer.busy_policy == "skip":
            self._committer.skips += 1
            return False
        snap = self._snapshot(state)
        return self._committer.submit(
            lambda: self.store.save(step, snap, timestamp, extra))

    def wait(self) -> None:
        self._committer.wait()

    @property
    def busy(self) -> bool:
        return self._committer.busy

    @property
    def writes(self) -> int:
        return self._committer.commits

    @property
    def skips(self) -> int:
        return self._committer.skips

    @property
    def errors(self) -> list:
        return self._committer.errors

    def stats(self) -> dict:
        return {"writes": self.writes, "skips": self.skips,
                "errors": len(self.errors)}
