"""Asynchronous checkpointing: the device->host snapshot is taken
synchronously (cheap), the disk write runs on a background thread so the
training step stream is not blocked — double-buffered: at most one write
in flight; a new snapshot while busy either blocks ('block') or is
dropped ('skip').

Crash-consistency: the underlying store only publishes a manifest after
all shards land, so a failure mid-write leaves the previous checkpoint as
the newest valid one.
"""
from __future__ import annotations

import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore


class AsyncCheckpointer:
    def __init__(self, store: CheckpointStore, busy_policy: str = "skip"):
        assert busy_policy in ("skip", "block")
        self.store = store
        self.busy_policy = busy_policy
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self.writes = 0
        self.skips = 0
        self.errors: list = []

    def _snapshot(self, state: Any) -> Any:
        # device -> host copy; on TPU this is the only step-blocking part.
        # np.array(copy=True): np.asarray would ALIAS host-resident arrays and
        # let later in-place mutation corrupt the in-flight snapshot.
        return jax.tree_util.tree_map(lambda x: np.array(x, copy=True), state)

    def save(self, step: int, state: Any, timestamp: float = 0.0,
             extra: Optional[dict] = None) -> bool:
        """Snapshot now, write in background. Returns False if skipped."""
        if self._thread is not None and self._thread.is_alive():
            if self.busy_policy == "skip":
                self.skips += 1
                return False
            self._thread.join()
        snap = self._snapshot(state)

        def work():
            try:
                self.store.save(step, snap, timestamp, extra)
                with self._lock:
                    self.writes += 1
            except Exception as e:   # noqa: BLE001
                with self._lock:
                    self.errors.append(repr(e))

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()

    @property
    def busy(self) -> bool:
        return self._thread is not None and self._thread.is_alive()
