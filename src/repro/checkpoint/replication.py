"""Peer replication — the mechanism that EARNS level-2 node survival.

Before this module, ``multilevel.LEVEL_COVERAGE["node"] -> "local"`` was a
modeling assumption: the simulator priced node failures as recoverable
from node-local disk, but no peer ever held a copy — a real node loss
would have silently degraded to a remote restore the optimizer never
priced.  This is exactly the modeled-vs-actual recovery-path gap the
fault-recovery benchmarking literature measures across real frameworks
(Vogel et al., arXiv:2404.06203 / 2405.07917).

``PeerReplicatedStore`` closes it on this single-process substrate:

* each simulated host owns the shards ``_assign_shards`` places on it
  (owner of shard j = ``j % num_hosts``, recorded in the manifest's
  ``placement`` section);
* after the primary shards land, each host pushes its shard to its k
  ring-neighbor peers (``ring_peers``) through the shared transfer pool,
  each push wrapped in bounded retry with jittered backoff;
* the save COMMITS (manifest written, directory published) only if every
  shard collected >= k replica acks — the quorum rule.  A failed quorum
  raises ``ReplicationError`` and leaves no manifest, so the previous
  checkpoint still wins;
* ``kill_host(h)`` simulates losing host h's node-local disk: its owned
  primary shards AND every replica it held for others vanish;
* restore is a DEGRADED PARTIAL restore: surviving primary shards load
  locally, only the failed host's shards are pulled from peer replicas
  (``replica_stats.restored_bytes`` counts exactly those pulled bytes),
  and a shard with zero surviving copies falls back per-shard to the
  remote store via ``shard_fallback`` — never a full remote restore when
  any local copy survives.

Scope note: incremental deltas are not physically replicated — the cost
model prices their mirror traffic via ``account_delta_mirror`` and a
post-failure delta chain restarts from a full (the manager already resets
the base on node loss), so correctness never depends on replicated
deltas.
"""
from __future__ import annotations

import os
import random
import shutil
import time
from dataclasses import dataclass
from typing import Any, Callable, Optional

import numpy as np

from repro.checkpoint.store import CheckpointStore


class ReplicationError(RuntimeError):
    """A level-2 save failed its replication quorum and was not committed."""


def ring_peers(host: int, num_hosts: int, k: int) -> tuple[int, ...]:
    """The k ring-neighbor peers host ``host`` replicates to:
    ``(host+1, ..., host+k) mod num_hosts``, never including itself.
    A ring of H hosts has at most H-1 distinct peers."""
    if num_hosts <= 1 or k <= 0:
        return ()
    peers = []
    for i in range(1, min(k, num_hosts - 1) + 1):
        p = (host + i) % num_hosts
        if p != host and p not in peers:
            peers.append(p)
    return tuple(peers)


def retry_with_backoff(fn: Callable[[], Any], attempts: int = 4,
                       base_s: float = 0.01, factor: float = 2.0,
                       jitter: float = 0.5,
                       rng: Optional[random.Random] = None,
                       sleep: Optional[Callable[[float], None]] = None,
                       on_retry: Optional[Callable[[int, BaseException],
                                                   None]] = None) -> Any:
    """Run ``fn`` with bounded retries and jittered exponential backoff.

    Retries only ``OSError`` (the transient-IO class: flaky disk, NFS
    hiccup, interrupted copy); anything else propagates immediately.
    Attempt i sleeps ``base_s * factor**i * (1 + jitter*U[0,1))`` before
    retrying — the jitter decorrelates concurrent pushers hammering the
    same recovering disk.  After ``attempts`` failures the last error
    propagates (bounded, never infinite).  ``sleep``/``rng`` are
    injectable so tests run instantly and deterministically.
    """
    if attempts < 1:
        raise ValueError("attempts must be >= 1")
    rng = rng if rng is not None else random.Random()
    sleep = sleep if sleep is not None else time.sleep
    for i in range(attempts):
        try:
            return fn()
        except OSError as e:
            if i == attempts - 1:
                raise
            if on_retry is not None:
                on_retry(i, e)
            sleep(base_s * (factor ** i) * (1.0 + jitter * rng.random()))


@dataclass
class ReplicaStats:
    """Byte/attempt accounting for the replica plane — the measured twin
    of ``SimCostModel.avg_replica_bytes`` / the degraded-restore price."""
    pushes: int = 0             # replica copies attempted (incl. retries' firsts)
    push_retries: int = 0       # backoff retries taken
    push_failures: int = 0      # pushes dead after bounded retry
    acks: int = 0               # replica copies that landed + checksummed
    replica_bytes: int = 0      # bytes of replica traffic (incl. delta mirror)
    degraded_restores: int = 0  # restores that had to touch replicas/remote
    shards_from_primary: int = 0
    shards_from_peer: int = 0   # shards rebuilt from a peer replica
    shards_from_remote: int = 0  # shards with no local copy, pulled remote
    restored_bytes: int = 0     # bytes PULLED during degraded restores
                                # (replica reads + remote fallback), i.e. the
                                # partial-restore traffic — local primary
                                # reads are free and not counted

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class PeerReplicatedStore(CheckpointStore):
    """A ``CheckpointStore`` whose saves are durable against a single
    node loss: see the module docstring for the protocol."""

    def __init__(self, directory: str, num_shards: int = 4, keep: int = 3,
                 num_hosts: Optional[int] = None,
                 replication_factor: int = 1,
                 fault_hook: Optional[Callable[[str], None]] = None,
                 push_attempts: int = 4, push_backoff_s: float = 0.01,
                 seed: int = 0,
                 sleep: Optional[Callable[[float], None]] = None):
        super().__init__(directory, num_shards=num_shards, keep=keep,
                         num_hosts=num_hosts, fault_hook=fault_hook)
        self.replication_factor = max(0, min(replication_factor,
                                             self.num_hosts - 1))
        self.push_attempts = push_attempts
        self.push_backoff_s = push_backoff_s
        self.replica_stats = ReplicaStats()
        self.last_restore: dict = {}
        self._rng = random.Random(seed)
        self._sleep = sleep

    # -- replica push (runs inside save(), between shards and manifest) ----
    def _push_replicas(self, tmp: str, checksums: dict) -> Optional[dict]:
        """Push every shard to its owner's ring peers on the transfer
        pool.  Returns the manifest ``replicas`` section, or raises
        ``ReplicationError`` if any shard misses quorum (>= k acks) —
        in that case save() never writes the manifest, so the half-
        replicated checkpoint is invisible."""
        from repro.checkpoint.pipeline import transfer_pool

        k = self.replication_factor
        if k == 0:
            return None
        stats = self.replica_stats
        jobs = []   # (shard_fname, crc, peer, replica_fname, future)
        for fname, crc in checksums.items():
            owner = self._file_host(fname)
            for peer in ring_peers(owner, self.num_hosts, k):
                rname = f"replica_h{peer:03d}_{fname}"
                jobs.append((fname, crc, peer, rname,
                             transfer_pool().submit(
                                 self._push_one, tmp, fname, rname)))
        replicas: dict[str, dict] = {}
        acked = {fname: 0 for fname in checksums}
        errors = []
        for fname, crc, peer, rname, fut in jobs:
            try:
                fut.result()
            except OSError as e:
                stats.push_failures += 1
                errors.append(f"{rname}: {e}")
                continue
            stats.acks += 1
            stats.replica_bytes += os.path.getsize(os.path.join(tmp, rname))
            acked[fname] += 1
            replicas[rname] = {"shard": fname, "crc": crc, "host": peer}
        short = sorted(f for f, n in acked.items() if n < k)
        if short:
            raise ReplicationError(
                f"replication quorum failed (need {k} acks/shard): shards "
                f"{short} under-replicated after bounded retry "
                f"[{'; '.join(errors) or 'no push errors recorded'}]")
        return replicas

    def _push_one(self, tmp: str, fname: str, rname: str) -> None:
        """One shard->peer push: a retried copy through the node-
        interconnect stand-in (same-dir file copy on this substrate)."""
        stats = self.replica_stats
        stats.pushes += 1
        src = os.path.join(tmp, fname)
        dst = os.path.join(tmp, rname)

        def attempt() -> None:
            if self.fault_hook is not None:
                self.fault_hook(dst)
            shutil.copyfile(src, dst)

        def note_retry(i: int, e: BaseException) -> None:
            stats.push_retries += 1

        retry_with_backoff(attempt, attempts=self.push_attempts,
                           base_s=self.push_backoff_s, rng=self._rng,
                           sleep=self._sleep, on_retry=note_retry)

    def account_delta_mirror(self, nbytes: int) -> None:
        """Price the replica traffic of a delta write (k mirrors of the
        delta payload).  Deltas are not physically replicated (module
        docstring: the post-failure chain restarts from a full), but
        their mirror bytes must still show up in measured replica
        traffic so the cost model's ``avg_replica_bytes`` has a
        measured twin under incremental plans."""
        self.replica_stats.replica_bytes += nbytes * self.replication_factor

    # -- failure injection --------------------------------------------------
    # ``kill_host`` is inherited: the base deletes every file whose
    # ``_file_host`` is the dead host, and the override below makes the
    # replicas a host holds for its peers count as living on it too.
    def _file_host(self, fname: str) -> Optional[int]:
        if fname.startswith("replica_h"):
            return int(fname[9:12])
        return super()._file_host(fname)

    # -- validity: a shard is covered if ANY copy of it is intact ----------
    def _valid(self, name: str) -> Optional[dict]:
        manifest = self._manifest(name)
        if manifest is None:
            return None
        replicas = manifest.get("replicas") or {}
        for fname, crc in manifest["checksums"].items():
            if self._file_ok(name, fname, crc):
                continue
            covered = any(
                info["shard"] == fname
                and self._file_ok(name, rname, info["crc"])
                for rname, info in replicas.items())
            if not covered:
                return None
        return manifest

    def restorable_steps(self, remote_steps: Any = ()) -> list[int]:
        """Steps restorable at this level, counting per-shard remote
        fallback: a step whose manifest loads but whose shards lost every
        local copy is still restorable iff the remote store holds the
        SAME step (mixed-step shards would not be bit-exact)."""
        remote_steps = set(remote_steps)
        out = []
        for name in sorted(os.listdir(self.directory)):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            if self._valid(name) is not None:
                out.append(int(name.split("_")[1]))
            elif self._manifest(name) is not None \
                    and int(name.split("_")[1]) in remote_steps:
                out.append(int(name.split("_")[1]))
        return out

    def newest_restorable(self, remote_steps: Any = ()) -> Optional[int]:
        steps = self.restorable_steps(remote_steps)
        return steps[-1] if steps else None

    # -- degraded partial restore ------------------------------------------
    def restore(self, treedef_like: Any, step: Optional[int] = None,
                shard_fallback: Optional[Callable[[int, list],
                                                  dict]] = None
                ) -> tuple[Any, dict]:
        """Restore, pulling ONLY what the failure destroyed: intact
        primary shards load locally for free; a dead primary loads from
        a surviving peer replica; a shard with no local copy at all is
        fetched per-shard from ``shard_fallback(step, leaf_names)`` (the
        manager wires this to the remote store's ``read_leaves`` at the
        SAME step).  ``last_restore``/``replica_stats`` record the
        degraded-pull bytes the recovery actually moved."""
        from repro.checkpoint.pipeline import io_pool

        from repro.utils.trees import tree_flatten_with_names
        import jax

        step = step if step is not None else self.newest()
        if step is None:
            raise FileNotFoundError("no valid checkpoint found")
        name = f"step_{step:010d}"
        manifest = self._manifest(name)
        if manifest is None:
            raise FileNotFoundError(f"checkpoint {name} is corrupt or missing")
        replicas = manifest.get("replicas") or {}
        stats = self.replica_stats
        pulled_bytes = 0
        from_peer = from_remote = from_primary = 0
        plan: list[tuple[str, str]] = []     # (load_path kind, fname)
        missing: list[str] = []              # shard fnames with no local copy
        for fname, crc in manifest["checksums"].items():
            if self._file_ok(name, fname, crc):
                plan.append(("primary", fname))
                continue
            rep = next((rname for rname, info in replicas.items()
                        if info["shard"] == fname
                        and self._file_ok(name, rname, info["crc"])), None)
            if rep is not None:
                plan.append(("peer", rep))
            else:
                missing.append(fname)

        def load_npz(fname: str) -> dict[str, np.ndarray]:
            fpath = os.path.join(self.directory, name, fname)
            with np.load(fpath) as z:
                return {k.replace("::", "/"): z[k] for k in z.files}

        data: dict[str, np.ndarray] = {}
        futs = [(src, fname, io_pool().submit(load_npz, fname))
                for src, fname in plan]
        for src, fname, fut in futs:
            data.update(fut.result())
            if src == "primary":
                from_primary += 1
            else:
                from_peer += 1
                pulled_bytes += os.path.getsize(
                    os.path.join(self.directory, name, fname))
        assign = manifest["assign"]
        for fname in missing:
            j = int(fname[6:11])
            leaf_names = sorted(n for n, s in assign.items() if s == j)
            if shard_fallback is None:
                raise FileNotFoundError(
                    f"{name}: shard {fname} has no surviving local copy "
                    "and no remote fallback was provided")
            fetched = shard_fallback(step, leaf_names)
            still = [n for n in leaf_names if n not in fetched]
            if still:
                raise FileNotFoundError(
                    f"{name}: remote fallback missing leaves {still[:5]}")
            data.update({n: fetched[n] for n in leaf_names})
            from_remote += 1
            pulled_bytes += sum(int(np.asarray(fetched[n]).nbytes)
                                for n in leaf_names)
        degraded = bool(from_peer or from_remote)
        if degraded:
            stats.degraded_restores += 1
        stats.shards_from_primary += from_primary
        stats.shards_from_peer += from_peer
        stats.shards_from_remote += from_remote
        stats.restored_bytes += pulled_bytes
        self.last_restore = {"step": step, "degraded": degraded,
                             "restored_bytes": pulled_bytes,
                             "shards_from_primary": from_primary,
                             "shards_from_peer": from_peer,
                             "shards_from_remote": from_remote}

        names = [n for n, _ in tree_flatten_with_names(treedef_like)]
        absent = [n for n in names if n not in data]
        if absent:
            raise KeyError(f"checkpoint missing leaves: {absent[:5]}...")
        leaves_struct = jax.tree_util.tree_leaves(treedef_like)
        treedef = jax.tree_util.tree_structure(treedef_like)
        restored = [data[n] for n in names]
        restored = [np.asarray(v, dtype=s.dtype) if hasattr(s, "dtype") else v
                    for v, s in zip(restored, leaves_struct)]
        return (jax.tree_util.tree_unflatten(treedef, restored),
                manifest["extra"])

    def stats(self) -> dict:
        out = super().stats()
        out["replication_factor"] = self.replication_factor
        out["replica"] = self.replica_stats.as_dict()
        return out
