"""Pipelined checkpoint hot path: chunked device->host transfer feeding a
parallel compression/write worker pool, with the delta encode placeable on
EITHER side of the link.

The pre-pipeline save path was serial end-to-end: a monolithic
``snapshot_to_host`` deep copy of the whole state blocked the step stream,
then every leaf was encoded and compressed one after another on the commit
thread.  This module breaks that into overlapping stages.  Host placement
(``CheckpointPlan.encode_placement="host"``, the default) ships the raw
state and encodes behind the link:

    trigger -> chunked D2H transfer  ||  encode  ||  compress  ||  write

Device placement runs the ``kernels/ckpt_delta`` codec in front of D2H
(``DeltaLeafSource``), so only the encoded payload crosses the link —
delta + sparse residual (lossless) or int8 q + scales (~4x fewer bytes):

    trigger -> device encode -> chunked D2H of encoded payload
                                          ||  compress  ||  write

  * ``ChunkedHostSnapshot`` partitions the state's leaves into byte-bounded
    chunks.  Mutable host leaves (``np.ndarray``) are deep-copied eagerly —
    the caller may mutate them in place the moment ``save()`` returns, so
    their copy IS the blocking cost (this is the aliasing hazard the
    pipeline must preserve; see the race test in test_checkpoint_plane).
    Immutable ``jax.Array`` leaves only need their references grabbed: the
    first chunk is materialized synchronously (the device sync), the rest
    transfer on a background pool while downstream encode/compress/write
    workers consume whatever chunks have landed.  The caveat: deferred
    transfer relies on JAX immutability, so states updated with donated
    buffers (``donate_argnums``) must materialize every leaf before the
    donating step runs — set ``CheckpointPlan.eager_snapshot=True`` (the
    manager then constructs this snapshot with ``defer_device=False``,
    trading the pipelined blocking win for donation safety).  The in-repo
    trainer does not donate, so the knob defaults off.

  * ``LeafSource`` is the uniform interface the parallel writers consume:
    leaf names/specs are known immediately (shard planning needs no bytes),
    ``get(name)`` blocks until that leaf's bytes are host-resident.  A
    plain pytree wraps into ``PlainLeafSource`` so every existing call
    site keeps working.

  * Two pools, deliberately: transfer tasks (D2H) run on ``transfer_pool``
    and compression/write tasks on ``io_pool``.  IO tasks wait on transfer
    futures, never the reverse, so sharing one pool could not deadlock —
    but separating them keeps a slow zlib encode from starving the
    device->host stream that feeds it.
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.trees import tree_flatten_with_names

DEFAULT_CHUNK_BYTES = 4 << 20     # D2H granularity: first chunk = blocking

_pool_lock = threading.Lock()
_transfer_pool: Optional[ThreadPoolExecutor] = None
_io_pool: Optional[ThreadPoolExecutor] = None


def transfer_pool() -> ThreadPoolExecutor:
    """Background device->host chunk transfers (small: D2H is one link)."""
    global _transfer_pool
    with _pool_lock:
        if _transfer_pool is None:
            _transfer_pool = ThreadPoolExecutor(
                max_workers=2, thread_name_prefix="ckpt-d2h")
        return _transfer_pool


def io_pool() -> ThreadPoolExecutor:
    """Shared encode/compress/write workers for all checkpoint stores."""
    global _io_pool
    with _pool_lock:
        if _io_pool is None:
            _io_pool = ThreadPoolExecutor(
                max_workers=min(8, max(2, (os.cpu_count() or 2))),
                thread_name_prefix="ckpt-io")
        return _io_pool


class LeafSource:
    """Leaf-level access to a checkpoint state for the pipelined writers.

    ``names``/``spec`` are available immediately so shard assignment and
    manifests never wait on bytes; ``get(name)`` blocks until that leaf is
    host-resident.
    """

    names: list
    treedef: Any

    def spec(self, name: str) -> tuple[tuple, np.dtype]:
        raise NotImplementedError

    def nbytes(self, name: str) -> int:
        shape, dtype = self.spec(name)
        return int(np.prod(shape, dtype=np.int64)) * dtype.itemsize if shape \
            else dtype.itemsize

    def get(self, name: str) -> np.ndarray:
        raise NotImplementedError

    def bytes_on_link(self) -> int:
        """Bytes this snapshot moves across the device->host link
        (pre-compression, post-encode).  Raw sources move every leaf's raw
        bytes; ``DeltaLeafSource`` overrides with the encoded-payload
        accounting — the quantity ``SaveReport.bytes_on_link`` reports and
        the cost model prices, distinct from the post-compression bytes
        that hit the disk."""
        return sum(self.nbytes(n) for n in self.names)

    def wait(self) -> None:
        """Block until every leaf is host-resident."""

    def as_pytree(self) -> Any:
        self.wait()
        return jax.tree_util.tree_unflatten(
            self.treedef, [self.get(n) for n in self.names])


class PlainLeafSource(LeafSource):
    """A fully host-resident pytree (no copy — leaves may alias the
    caller's arrays; use ``ChunkedHostSnapshot`` when the snapshot must
    survive in-place mutation)."""

    def __init__(self, state: Any):
        named = tree_flatten_with_names(state)
        self.treedef = jax.tree_util.tree_structure(state)
        self.names = [n for n, _ in named]
        self._leaves = {n: np.asarray(l) for n, l in named}

    def spec(self, name: str) -> tuple[tuple, np.dtype]:
        leaf = self._leaves[name]
        return tuple(leaf.shape), leaf.dtype

    def get(self, name: str) -> np.ndarray:
        return self._leaves[name]


class ChunkedHostSnapshot(LeafSource):
    """Point-in-time host snapshot with chunked, overlapped D2H transfer.

    Blocking work (done in ``__init__``): deep-copy of every mutable host
    leaf + synchronous materialization of the first device chunk (the
    device sync).  Everything else lands on ``transfer_pool`` and is pulled
    by ``get``/``wait``.
    """

    def __init__(self, state: Any, chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 defer_device: bool = True):
        named = tree_flatten_with_names(state)
        self.treedef = jax.tree_util.tree_structure(state)
        self.names = [n for n, _ in named]
        self._spec: dict[str, tuple[tuple, np.dtype]] = {}
        self._leaves: dict[str, np.ndarray] = {}
        self._future_of: dict[str, Future] = {}

        deferred: list[tuple[str, Any]] = []
        for name, leaf in named:
            if defer_device and isinstance(leaf, jax.Array):
                # immutable: a reference is as good as a copy until the
                # transfer worker reads it
                self._spec[name] = (tuple(leaf.shape), np.dtype(leaf.dtype))
                deferred.append((name, leaf))
            else:
                # mutable host memory (or cheap scalar): copy NOW — the
                # caller may mutate it the moment save() returns
                arr = np.array(leaf, copy=True)
                self._spec[name] = (tuple(arr.shape), arr.dtype)
                self._leaves[name] = arr

        # byte-bounded chunks over the deferred device leaves
        chunks: list[list[tuple[str, Any]]] = []
        cur, cur_bytes = [], 0
        for name, leaf in deferred:
            cur.append((name, leaf))
            cur_bytes += self.nbytes(name)
            if cur_bytes >= chunk_bytes:
                chunks.append(cur)
                cur, cur_bytes = [], 0
        if cur:
            chunks.append(cur)

        if chunks:      # first chunk synchronously: the device sync point
            self._leaves.update(self._materialize(chunks[0]))
        pool = transfer_pool()
        for chunk in chunks[1:]:
            fut = pool.submit(self._materialize, chunk)
            for name, _ in chunk:
                self._future_of[name] = fut

    @staticmethod
    def _materialize(chunk: list) -> dict[str, np.ndarray]:
        # np.asarray on a jax.Array is the D2H copy (on the CPU backend it
        # may alias the immutable buffer, which is equally safe)
        return {name: np.asarray(leaf) for name, leaf in chunk}

    def spec(self, name: str) -> tuple[tuple, np.dtype]:
        return self._spec[name]

    def get(self, name: str) -> np.ndarray:
        fut = self._future_of.get(name)
        if fut is not None:
            return fut.result()[name]
        return self._leaves[name]

    def wait(self) -> None:
        for fut in self._future_of.values():
            fut.result()


class DeviceDeltaBase:
    """The delta base held device-resident across triggers.

    Because ``jax.Array``s are immutable, holding references to the last
    full snapshot's device leaves is free — no extra HBM beyond delaying
    the old buffers' release — and gives the on-device encoder a base to
    diff against without any host round trip.  Mutable host leaves are
    deep-copied eagerly (the same aliasing contract as
    ``ChunkedHostSnapshot``).  ``CheckpointManager`` refreshes this on
    every full trigger/savepoint and carries it across plan-switch
    rebuilds (``adopt_runtime_state``).
    """

    def __init__(self, state: Any):
        self.leaves: dict[str, Any] = {}
        for name, leaf in tree_flatten_with_names(state):
            if isinstance(leaf, jax.Array):
                self.leaves[name] = leaf          # immutable: ref == copy
            else:
                self.leaves[name] = np.array(leaf, copy=True)


class DeltaLeafSource(LeafSource):
    """Delta-encode on device, then stream only the ENCODED chunks D2H.

    The ``kernels/ckpt_delta`` encoders are dispatched per f32 device leaf
    in ``__init__`` (async on real accelerators), against the
    device-resident base.  The encoded outputs are then pulled host-side
    with the same first-chunk-sync contract as ``ChunkedHostSnapshot``:
    the first payload chunk materializes synchronously (that device sync
    is the caller-blocking cost), the rest on ``transfer_pool``.

    Consumed two ways:

      * ``encoded(name)`` — the pre-encoded payload for the delta writer
        (``incremental.write_delta``): a dict of blob-suffix -> array
        whose bytes are identical to the host encoder's blobs, the
        ``"zero"`` marker for an unchanged leaf, or None for a leaf this
        source could not device-encode (non-f32, host-resident, or
        base-shape mismatch — the writer falls back to host encode).
      * ``get(name)`` — the raw leaf, materialized lazily (memory-level
        parking and the rare delta-upgraded-to-full self-heal write);
        device refs are immutable so the late D2H is safe.

    Lossless payloads are delta (f32, full size) + XOR residual (u32) —
    but the residual is all-zero for any element within 2x of its base
    (Sterbenz), so its D2H is skipped when the on-device nonzero count is
    0 and the host writes a reconstructed zero blob: link traffic drops to
    ~1.0x state bytes + the change flags, and the host CPU encode
    disappears.  int8 payloads are q (1 B/elem) + per-1024 scales —
    ~0.25x state bytes on the link.
    """

    placement = "device"

    def __init__(self, state: Any, base: DeviceDeltaBase,
                 codec: str = "lossless",
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 interpret: Optional[bool] = None):
        assert codec in ("lossless", "int8"), codec
        from repro.kernels.ckpt_delta.ops import (default_interpret,
                                                  int8_encode_leaf,
                                                  lossless_encode_leaf)
        self.codec = codec
        self.interpret = default_interpret() if interpret is None \
            else interpret
        named = tree_flatten_with_names(state)
        self.treedef = jax.tree_util.tree_structure(state)
        self.names = [n for n, _ in named]
        self._spec: dict[str, tuple[tuple, np.dtype]] = {}
        self._raw: dict[str, Any] = {}
        self._enc: dict[str, Any] = {}           # first-chunk payloads
        self._future_of: dict[str, Future] = {}
        self._link_lock = threading.Lock()
        self._link_bytes = 0

        pending: list[tuple[str, tuple]] = []    # (name, device outputs)
        for name, leaf in named:
            if isinstance(leaf, jax.Array):
                self._spec[name] = (tuple(leaf.shape), np.dtype(leaf.dtype))
                self._raw[name] = leaf
                b = base.leaves.get(name)
                if (np.dtype(leaf.dtype) == np.float32 and b is not None
                        and tuple(getattr(b, "shape", ())) == tuple(leaf.shape)
                        and np.dtype(b.dtype) == np.float32):
                    bj = b if isinstance(b, jax.Array) else jax.numpy.asarray(b)
                    fn = (lossless_encode_leaf if codec == "lossless"
                          else int8_encode_leaf)
                    pending.append((name, fn(leaf, bj,
                                             interpret=self.interpret)))
                    continue
                # non-f32 device leaf: host-encode fallback, raw D2H lazily
                self._account(self.nbytes(name))
            else:
                arr = np.array(leaf, copy=True)   # mutable host leaf
                self._spec[name] = (tuple(arr.shape), arr.dtype)
                self._raw[name] = arr
                self._account(arr.nbytes)

        # byte-bounded chunks over the encoded payloads (worst-case size)
        chunks: list[list[tuple[str, tuple]]] = []
        cur: list[tuple[str, tuple]] = []
        cur_bytes = 0
        for name, outs in pending:
            cur.append((name, outs))
            cur_bytes += sum(int(np.prod(o.shape, dtype=np.int64))
                             * np.dtype(o.dtype).itemsize for o in outs)
            if cur_bytes >= chunk_bytes:
                chunks.append(cur)
                cur, cur_bytes = [], 0
        if cur:
            chunks.append(cur)

        if chunks:      # first chunk synchronously: the device sync point
            self._enc.update(self._materialize(chunks[0]))
        pool = transfer_pool()
        for chunk in chunks[1:]:
            fut = pool.submit(self._materialize, chunk)
            for name, _ in chunk:
                self._future_of[name] = fut

    def _account(self, nbytes: int) -> None:
        with self._link_lock:
            self._link_bytes += int(nbytes)

    def _materialize(self, chunk: list) -> dict[str, Any]:
        return {name: self._pull(name, outs) for name, outs in chunk}

    def _pull(self, name: str, outs: tuple) -> Any:
        """D2H one leaf's encoded payload (or detect it unchanged)."""
        shape, _ = self._spec[name]
        n = int(np.prod(shape, dtype=np.int64)) if shape else 1
        if self.codec == "lossless":
            delta, resid, changed, nnz = outs
            if not bool(np.asarray(changed)):
                return "zero"
            payload = {"": np.asarray(delta)[:n]}
            self._account(n * 4)
            if int(np.asarray(nnz)):
                payload["::r"] = np.asarray(resid)[:n]
                self._account(n * 4)
            else:       # residual known all-zero: reconstruct host-side —
                        # the on-disk blob stays byte-identical, the link
                        # transfer is skipped
                payload["::r"] = np.zeros(n, np.uint32)
            return payload
        q, scales, changed = outs
        if not bool(np.asarray(changed)):
            return "zero"
        q_np, s_np = np.asarray(q), np.asarray(scales)
        self._account(q_np.nbytes + s_np.nbytes)
        return {"::q": q_np, "::s": s_np}

    # -- LeafSource interface -------------------------------------------
    def spec(self, name: str) -> tuple[tuple, np.dtype]:
        return self._spec[name]

    def get(self, name: str) -> np.ndarray:
        leaf = self._raw[name]
        if isinstance(leaf, np.ndarray):
            return leaf
        arr = np.asarray(leaf)
        with self._link_lock:
            cur = self._raw[name]
            if isinstance(cur, np.ndarray):     # another worker won the race
                return cur
            self._raw[name] = arr
            # a raw pull IS link traffic (remote/self-heal full writes and
            # memory-level restores pull raw leaves from a delta source) —
            # count it so bytes_on_link never under-reports a delta trigger
            # that also performed a full write
            self._link_bytes += arr.nbytes
        return arr

    def encoded(self, name: str) -> Any:
        """Pre-encoded payload dict, ``"zero"``, or None (host fallback).
        Blocks until the leaf's encoded chunk has landed."""
        fut = self._future_of.get(name)
        if fut is not None:
            return fut.result()[name]
        return self._enc.get(name)

    def wait(self) -> None:
        for fut in self._future_of.values():
            fut.result()

    def bytes_on_link(self) -> int:
        self.wait()
        with self._link_lock:
            return self._link_bytes


def as_leaf_source(state: Any) -> LeafSource:
    """Adapt ``state`` (pytree or LeafSource) for the pipelined writers."""
    if isinstance(state, LeafSource):
        return state
    return PlainLeafSource(state)
