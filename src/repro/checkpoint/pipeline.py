"""Pipelined checkpoint hot path: chunked device->host transfer feeding a
parallel compression/write worker pool.

The pre-pipeline save path was serial end-to-end: a monolithic
``snapshot_to_host`` deep copy of the whole state blocked the step stream,
then every leaf was encoded and compressed one after another on the commit
thread.  This module breaks that into overlapping stages:

    trigger -> chunked D2H transfer  ||  encode  ||  compress  ||  write

  * ``ChunkedHostSnapshot`` partitions the state's leaves into byte-bounded
    chunks.  Mutable host leaves (``np.ndarray``) are deep-copied eagerly —
    the caller may mutate them in place the moment ``save()`` returns, so
    their copy IS the blocking cost (this is the aliasing hazard the
    pipeline must preserve; see the race test in test_checkpoint_plane).
    Immutable ``jax.Array`` leaves only need their references grabbed: the
    first chunk is materialized synchronously (the device sync), the rest
    transfer on a background pool while downstream encode/compress/write
    workers consume whatever chunks have landed.  The caveat: deferred
    transfer relies on JAX immutability, so states updated with donated
    buffers (``donate_argnums``) must materialize every leaf before the
    donating step runs — set ``CheckpointPlan.eager_snapshot=True`` (the
    manager then constructs this snapshot with ``defer_device=False``,
    trading the pipelined blocking win for donation safety).  The in-repo
    trainer does not donate, so the knob defaults off.

  * ``LeafSource`` is the uniform interface the parallel writers consume:
    leaf names/specs are known immediately (shard planning needs no bytes),
    ``get(name)`` blocks until that leaf's bytes are host-resident.  A
    plain pytree wraps into ``PlainLeafSource`` so every existing call
    site keeps working.

  * Two pools, deliberately: transfer tasks (D2H) run on ``transfer_pool``
    and compression/write tasks on ``io_pool``.  IO tasks wait on transfer
    futures, never the reverse, so sharing one pool could not deadlock —
    but separating them keeps a slow zlib encode from starving the
    device->host stream that feeds it.
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.trees import tree_flatten_with_names

DEFAULT_CHUNK_BYTES = 4 << 20     # D2H granularity: first chunk = blocking

_pool_lock = threading.Lock()
_transfer_pool: Optional[ThreadPoolExecutor] = None
_io_pool: Optional[ThreadPoolExecutor] = None


def transfer_pool() -> ThreadPoolExecutor:
    """Background device->host chunk transfers (small: D2H is one link)."""
    global _transfer_pool
    with _pool_lock:
        if _transfer_pool is None:
            _transfer_pool = ThreadPoolExecutor(
                max_workers=2, thread_name_prefix="ckpt-d2h")
        return _transfer_pool


def io_pool() -> ThreadPoolExecutor:
    """Shared encode/compress/write workers for all checkpoint stores."""
    global _io_pool
    with _pool_lock:
        if _io_pool is None:
            _io_pool = ThreadPoolExecutor(
                max_workers=min(8, max(2, (os.cpu_count() or 2))),
                thread_name_prefix="ckpt-io")
        return _io_pool


class LeafSource:
    """Leaf-level access to a checkpoint state for the pipelined writers.

    ``names``/``spec`` are available immediately so shard assignment and
    manifests never wait on bytes; ``get(name)`` blocks until that leaf is
    host-resident.
    """

    names: list
    treedef: Any

    def spec(self, name: str) -> tuple[tuple, np.dtype]:
        raise NotImplementedError

    def nbytes(self, name: str) -> int:
        shape, dtype = self.spec(name)
        return int(np.prod(shape, dtype=np.int64)) * dtype.itemsize if shape \
            else dtype.itemsize

    def get(self, name: str) -> np.ndarray:
        raise NotImplementedError

    def wait(self) -> None:
        """Block until every leaf is host-resident."""

    def as_pytree(self) -> Any:
        self.wait()
        return jax.tree_util.tree_unflatten(
            self.treedef, [self.get(n) for n in self.names])


class PlainLeafSource(LeafSource):
    """A fully host-resident pytree (no copy — leaves may alias the
    caller's arrays; use ``ChunkedHostSnapshot`` when the snapshot must
    survive in-place mutation)."""

    def __init__(self, state: Any):
        named = tree_flatten_with_names(state)
        self.treedef = jax.tree_util.tree_structure(state)
        self.names = [n for n, _ in named]
        self._leaves = {n: np.asarray(l) for n, l in named}

    def spec(self, name: str) -> tuple[tuple, np.dtype]:
        leaf = self._leaves[name]
        return tuple(leaf.shape), leaf.dtype

    def get(self, name: str) -> np.ndarray:
        return self._leaves[name]


class ChunkedHostSnapshot(LeafSource):
    """Point-in-time host snapshot with chunked, overlapped D2H transfer.

    Blocking work (done in ``__init__``): deep-copy of every mutable host
    leaf + synchronous materialization of the first device chunk (the
    device sync).  Everything else lands on ``transfer_pool`` and is pulled
    by ``get``/``wait``.
    """

    def __init__(self, state: Any, chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 defer_device: bool = True):
        named = tree_flatten_with_names(state)
        self.treedef = jax.tree_util.tree_structure(state)
        self.names = [n for n, _ in named]
        self._spec: dict[str, tuple[tuple, np.dtype]] = {}
        self._leaves: dict[str, np.ndarray] = {}
        self._future_of: dict[str, Future] = {}

        deferred: list[tuple[str, Any]] = []
        for name, leaf in named:
            if defer_device and isinstance(leaf, jax.Array):
                # immutable: a reference is as good as a copy until the
                # transfer worker reads it
                self._spec[name] = (tuple(leaf.shape), np.dtype(leaf.dtype))
                deferred.append((name, leaf))
            else:
                # mutable host memory (or cheap scalar): copy NOW — the
                # caller may mutate it the moment save() returns
                arr = np.array(leaf, copy=True)
                self._spec[name] = (tuple(arr.shape), arr.dtype)
                self._leaves[name] = arr

        # byte-bounded chunks over the deferred device leaves
        chunks: list[list[tuple[str, Any]]] = []
        cur, cur_bytes = [], 0
        for name, leaf in deferred:
            cur.append((name, leaf))
            cur_bytes += self.nbytes(name)
            if cur_bytes >= chunk_bytes:
                chunks.append(cur)
                cur, cur_bytes = [], 0
        if cur:
            chunks.append(cur)

        if chunks:      # first chunk synchronously: the device sync point
            self._leaves.update(self._materialize(chunks[0]))
        pool = transfer_pool()
        for chunk in chunks[1:]:
            fut = pool.submit(self._materialize, chunk)
            for name, _ in chunk:
                self._future_of[name] = fut

    @staticmethod
    def _materialize(chunk: list) -> dict[str, np.ndarray]:
        # np.asarray on a jax.Array is the D2H copy (on the CPU backend it
        # may alias the immutable buffer, which is equally safe)
        return {name: np.asarray(leaf) for name, leaf in chunk}

    def spec(self, name: str) -> tuple[tuple, np.dtype]:
        return self._spec[name]

    def get(self, name: str) -> np.ndarray:
        fut = self._future_of.get(name)
        if fut is not None:
            return fut.result()[name]
        return self._leaves[name]

    def wait(self) -> None:
        for fut in self._future_of.values():
            fut.result()


def as_leaf_source(state: Any) -> LeafSource:
    """Adapt ``state`` (pytree or LeafSource) for the pipelined writers."""
    if isinstance(state, LeafSource):
        return state
    return PlainLeafSource(state)
