"""Pipelined checkpoint hot path: chunked device->host transfer feeding a
parallel compression/write worker pool, with the delta encode placeable on
EITHER side of the link.

The pre-pipeline save path was serial end-to-end: a monolithic
``snapshot_to_host`` deep copy of the whole state blocked the step stream,
then every leaf was encoded and compressed one after another on the commit
thread.  This module breaks that into overlapping stages.  Host placement
(``CheckpointPlan.encode_placement="host"``, the default) ships the raw
state and encodes behind the link:

    trigger -> chunked D2H transfer  ||  encode  ||  compress  ||  write

Device placement runs the ``kernels/ckpt_delta`` codec in front of D2H
(``DeltaLeafSource``), so only the encoded payload crosses the link —
delta + sparse residual (lossless) or int8 q + scales (~4x fewer bytes):

    trigger -> pack -> ONE fused encode -> chunked D2H of encoded payload
                                                 ||  compress  ||  write

The device encode is FLAT: the f32 subtree of the state is packed into
one contiguous GROUP-aligned mega-buffer (``FlatLayout`` — each leaf
zero-padded to a whole number of 1024-element groups, so per-group change
statistics map exactly onto leaves), diffed against the equally-packed
``DeviceDeltaBase.flat`` by a single ``flat_lossless_encode``/
``flat_int8_encode`` dispatch, and the encoded payload streams off-device
in byte-bounded chunks.  One pack dispatch + one encode dispatch + one
chunked transfer replace the N per-leaf kernel launches + N small D2H
copies the pre-flat plane paid (which priced device placement out of the
optimizer on seconds while winning on bytes).

Flat-layout manifest (the ``"flat"`` section ``incremental.write_delta``
records, decoded by ``incremental.apply_delta``):

    {"size": <padded elems>, "group": 1024,
     "layout": [[name, offset, size, shape], ...],   # GROUP-aligned offsets
     "arrays": {"d": {file, dtype, frames}, "r": "zero" | {...}}}

plus per-leaf skip-zero markers in the manifest's ``zero`` list (from the
kernel's fused per-leaf change counts) and a ``"zero"`` marker for an
all-zero residual plane whose D2H was skipped entirely.  Leaves outside
the packed subtree (non-f32, host-resident, shape-drifted) fall back to
the per-leaf host encode path and per-leaf blobs — a v3 (flat) delta can
carry both, and per-leaf-only v2 deltas keep restoring through the same
reader.

Pack/refresh lifecycle: ``DeviceDeltaBase`` packs its flat buffer ONCE
per full trigger/savepoint (``CheckpointManager`` refreshes it there and
carries it across ``set_plan`` rebuilds via ``adopt_runtime_state``);
every delta trigger then packs only the NEW state (one cached-jit
dispatch) and encodes against the resident base, so the steady-state
trigger never re-uploads or re-packs the base.

  * ``ChunkedHostSnapshot`` partitions the state's leaves into byte-bounded
    chunks.  Mutable host leaves (``np.ndarray``) are deep-copied eagerly —
    the caller may mutate them in place the moment ``save()`` returns, so
    their copy IS the blocking cost (this is the aliasing hazard the
    pipeline must preserve; see the race test in test_checkpoint_plane).
    Immutable ``jax.Array`` leaves only need their references grabbed: the
    first chunk is materialized synchronously (the device sync), the rest
    transfer on a background pool while downstream encode/compress/write
    workers consume whatever chunks have landed.  The caveat: deferred
    transfer relies on JAX immutability, so states updated with donated
    buffers (``donate_argnums``) must materialize every leaf before the
    donating step runs — set ``CheckpointPlan.eager_snapshot=True`` (the
    manager then constructs this snapshot with ``defer_device=False``,
    trading the pipelined blocking win for donation safety).  The in-repo
    trainer does not donate, so the knob defaults off.

  * ``LeafSource`` is the uniform interface the parallel writers consume:
    leaf names/specs are known immediately (shard planning needs no bytes),
    ``get(name)`` blocks until that leaf's bytes are host-resident.  A
    plain pytree wraps into ``PlainLeafSource`` so every existing call
    site keeps working.

  * Two pools, deliberately: transfer tasks (D2H) run on ``transfer_pool``
    and compression/write tasks on ``io_pool``.  IO tasks wait on transfer
    futures, never the reverse, so sharing one pool could not deadlock —
    but separating them keeps a slow zlib encode from starving the
    device->host stream that feeds it.
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Any, Optional

import jax
import numpy as np

# GROUP comes from the numpy-only oracle module so importing the pipeline
# never pays for a pallas import (the jit'd ops load lazily, per call site)
from repro.kernels.ckpt_delta.ref import GROUP
from repro.utils.trees import tree_flatten_with_names

DEFAULT_CHUNK_BYTES = 4 << 20     # D2H granularity: first chunk = blocking

_pool_lock = threading.Lock()
_transfer_pool: Optional[ThreadPoolExecutor] = None
_io_pool: Optional[ThreadPoolExecutor] = None


def transfer_pool() -> ThreadPoolExecutor:
    """Background device->host chunk transfers (small: D2H is one link)."""
    global _transfer_pool
    with _pool_lock:
        if _transfer_pool is None:
            _transfer_pool = ThreadPoolExecutor(
                max_workers=2, thread_name_prefix="ckpt-d2h")
        return _transfer_pool


def io_pool() -> ThreadPoolExecutor:
    """Shared encode/compress/write workers for all checkpoint stores."""
    global _io_pool
    with _pool_lock:
        if _io_pool is None:
            _io_pool = ThreadPoolExecutor(
                max_workers=min(8, max(2, (os.cpu_count() or 2))),
                thread_name_prefix="ckpt-io")
        return _io_pool


class LeafSource:
    """Leaf-level access to a checkpoint state for the pipelined writers.

    ``names``/``spec`` are available immediately so shard assignment and
    manifests never wait on bytes; ``get(name)`` blocks until that leaf is
    host-resident.
    """

    names: list
    treedef: Any

    def spec(self, name: str) -> tuple[tuple, np.dtype]:
        raise NotImplementedError

    def nbytes(self, name: str) -> int:
        shape, dtype = self.spec(name)
        return int(np.prod(shape, dtype=np.int64)) * dtype.itemsize if shape \
            else dtype.itemsize

    def get(self, name: str) -> np.ndarray:
        raise NotImplementedError

    def bytes_on_link(self) -> int:
        """Bytes this snapshot moves across the device->host link
        (pre-compression, post-encode).  Raw sources move every leaf's raw
        bytes; ``DeltaLeafSource`` overrides with the encoded-payload
        accounting — the quantity ``SaveReport.bytes_on_link`` reports and
        the cost model prices, distinct from the post-compression bytes
        that hit the disk."""
        return sum(self.nbytes(n) for n in self.names)

    def wait(self) -> None:
        """Block until every leaf is host-resident."""

    def as_pytree(self) -> Any:
        self.wait()
        return jax.tree_util.tree_unflatten(
            self.treedef, [self.get(n) for n in self.names])


class PlainLeafSource(LeafSource):
    """A fully host-resident pytree (no copy — leaves may alias the
    caller's arrays; use ``ChunkedHostSnapshot`` when the snapshot must
    survive in-place mutation)."""

    def __init__(self, state: Any):
        named = tree_flatten_with_names(state)
        self.treedef = jax.tree_util.tree_structure(state)
        self.names = [n for n, _ in named]
        self._leaves = {n: np.asarray(l) for n, l in named}

    def spec(self, name: str) -> tuple[tuple, np.dtype]:
        leaf = self._leaves[name]
        return tuple(leaf.shape), leaf.dtype

    def get(self, name: str) -> np.ndarray:
        return self._leaves[name]


class ChunkedHostSnapshot(LeafSource):
    """Point-in-time host snapshot with chunked, overlapped D2H transfer.

    Blocking work (done in ``__init__``): deep-copy of every mutable host
    leaf + synchronous materialization of the first device chunk (the
    device sync).  Everything else lands on ``transfer_pool`` and is pulled
    by ``get``/``wait``.
    """

    def __init__(self, state: Any, chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 defer_device: bool = True):
        named = tree_flatten_with_names(state)
        self.treedef = jax.tree_util.tree_structure(state)
        self.names = [n for n, _ in named]
        self._spec: dict[str, tuple[tuple, np.dtype]] = {}
        self._leaves: dict[str, np.ndarray] = {}
        self._future_of: dict[str, Future] = {}

        deferred: list[tuple[str, Any]] = []
        for name, leaf in named:
            if defer_device and isinstance(leaf, jax.Array):
                # immutable: a reference is as good as a copy until the
                # transfer worker reads it
                self._spec[name] = (tuple(leaf.shape), np.dtype(leaf.dtype))
                deferred.append((name, leaf))
            else:
                # mutable host memory (or cheap scalar): copy NOW — the
                # caller may mutate it the moment save() returns
                arr = np.array(leaf, copy=True)
                self._spec[name] = (tuple(arr.shape), arr.dtype)
                self._leaves[name] = arr

        # byte-bounded chunks over the deferred device leaves
        chunks: list[list[tuple[str, Any]]] = []
        cur, cur_bytes = [], 0
        for name, leaf in deferred:
            cur.append((name, leaf))
            cur_bytes += self.nbytes(name)
            if cur_bytes >= chunk_bytes:
                chunks.append(cur)
                cur, cur_bytes = [], 0
        if cur:
            chunks.append(cur)

        if chunks:      # first chunk synchronously: the device sync point
            self._leaves.update(self._materialize(chunks[0]))
        pool = transfer_pool()
        for chunk in chunks[1:]:
            fut = pool.submit(self._materialize, chunk)
            for name, _ in chunk:
                self._future_of[name] = fut

    @staticmethod
    def _materialize(chunk: list) -> dict[str, np.ndarray]:
        # np.asarray on a jax.Array is the D2H copy (on the CPU backend it
        # may alias the immutable buffer, which is equally safe)
        return {name: np.asarray(leaf) for name, leaf in chunk}

    def spec(self, name: str) -> tuple[tuple, np.dtype]:
        return self._spec[name]

    def get(self, name: str) -> np.ndarray:
        fut = self._future_of.get(name)
        if fut is not None:
            return fut.result()[name]
        return self._leaves[name]

    def wait(self) -> None:
        for fut in self._future_of.values():
            fut.result()


@dataclass(frozen=True)
class FlatEntry:
    """One leaf's extent inside the packed mega-buffer (element units)."""

    name: str
    offset: int          # GROUP-aligned start
    size: int            # true (unpadded) element count
    shape: tuple

    @property
    def padded(self) -> int:
        return -(-self.size // GROUP) * GROUP


class FlatLayout:
    """Where each f32 leaf lives inside the packed mega-buffer.

    Every leaf is zero-padded to a whole number of GROUP(=1024)-element
    groups, so (a) offsets are GROUP-aligned and every group belongs to
    exactly ONE leaf — the kernel's per-group change statistics reduce
    exactly to per-leaf counts via ``group_leaf``, (b) int8 scale groups
    never straddle leaves, making any flat payload extent bit-identical
    to the per-leaf encoder's output, and (c) the decoder can slice any
    leaf back out by ``(offset, size, shape)``.  ``to_manifest()`` is the
    serialized form the delta manifest's ``"flat"`` section records.
    """

    def __init__(self, named_shapes: list):
        self.entries: list[FlatEntry] = []
        off = 0
        for name, shape in named_shapes:
            size = int(np.prod(shape, dtype=np.int64)) if shape else 1
            entry = FlatEntry(name, off, size, tuple(shape))
            self.entries.append(entry)
            off += entry.padded
        self.total = off
        self.by_name = {e.name: e for e in self.entries}
        self.names = [e.name for e in self.entries]
        group_leaf = np.zeros(self.total // GROUP, np.int32)
        for i, entry in enumerate(self.entries):
            group_leaf[entry.offset // GROUP:
                       (entry.offset + entry.padded) // GROUP] = i
        self.group_leaf = group_leaf
        self._group_leaf_dev: Optional[jax.Array] = None

    def group_leaf_device(self) -> jax.Array:
        """The group->leaf index map, uploaded once and cached (the fused
        encoders scatter-add per-group stats over it)."""
        if self._group_leaf_dev is None:
            self._group_leaf_dev = jax.numpy.asarray(self.group_leaf)
        return self._group_leaf_dev

    def to_manifest(self) -> list:
        return [[e.name, e.offset, e.size, list(e.shape)]
                for e in self.entries]


class DeviceDeltaBase:
    """The delta base held device-resident across triggers — per-leaf refs
    (for the fallback path and shape checks) plus the PACKED flat
    mega-buffer the fused encoder diffs against.

    Because ``jax.Array``s are immutable, holding references to the last
    full snapshot's device leaves is free — no extra HBM beyond delaying
    the old buffers' release — and gives the on-device encoder a base to
    diff against without any host round trip.  Mutable host leaves are
    deep-copied eagerly (the same aliasing contract as
    ``ChunkedHostSnapshot``).  The f32 subtree is additionally packed into
    ``flat`` under ``layout`` by one ``pack_flat`` dispatch — paid once
    per refresh and amortized over every delta trigger until the next.
    ``CheckpointManager`` refreshes this on every full trigger/savepoint
    and carries it across plan-switch rebuilds (``adopt_runtime_state``).
    """

    def __init__(self, state: Any):
        self.leaves: dict[str, Any] = {}
        packable: list[tuple[str, Any]] = []
        for name, leaf in tree_flatten_with_names(state):
            if isinstance(leaf, jax.Array):
                self.leaves[name] = leaf          # immutable: ref == copy
                if np.dtype(leaf.dtype) == np.float32 and leaf.size > 0:
                    packable.append((name, leaf))
            else:
                self.leaves[name] = np.array(leaf, copy=True)
        self.layout: Optional[FlatLayout] = None
        self.flat: Optional[jax.Array] = None
        if packable:
            from repro.kernels.ckpt_delta.ops import pack_flat
            self.layout = FlatLayout(
                [(name, tuple(leaf.shape)) for name, leaf in packable])
            self.flat = pack_flat([leaf for _, leaf in packable])

    def flat_subset(self, names: list) -> tuple[FlatLayout, jax.Array]:
        """The packed base restricted to ``names`` (in that order).  The
        common case — the new state's packable subtree matches the base's
        exactly — returns the resident buffer as-is; after a drift
        (leaves removed or reordered) the surviving GROUP-aligned extents
        are sliced out and re-concatenated in one dispatch."""
        assert self.layout is not None and self.flat is not None
        if names == self.layout.names:
            return self.layout, self.flat
        sub = FlatLayout([(n, self.layout.by_name[n].shape) for n in names])
        parts = [self.flat[e.offset:e.offset + e.padded]
                 for e in (self.layout.by_name[n] for n in names)]
        return sub, jax.numpy.concatenate(parts)


class DeltaLeafSource(LeafSource):
    """Delta-encode on device with ONE fused kernel over the packed flat
    buffer, then stream only the ENCODED payload D2H in chunks.

    ``__init__`` does the whole blocking dance: pack the new state's f32
    subtree (one ``pack_flat`` dispatch), run one fused
    ``flat_lossless_encode``/``flat_int8_encode`` against the resident
    ``DeviceDeltaBase.flat``, pull the per-LEAF change statistics (that
    tiny stats read is the device sync — the encode is complete), then
    materialize the FIRST payload chunk synchronously — the same
    first-chunk-sync ``blocking_s`` contract as ``ChunkedHostSnapshot`` —
    and queue the remaining byte-bounded chunks on ``transfer_pool``.

    Consumed two ways:

      * ``layout`` + ``flat_payload()`` + ``zero_names`` — the flat
        protocol ``incremental.write_delta`` detects (via
        ``getattr(src, "layout", None)``): the packed extents' manifest
        rows, the host-resident payload arrays ("d"/"r" lossless,
        "q"/"s" int8; ``"zero"`` marks a residual plane whose D2H was
        skipped), and the leaves whose fused change count was 0 (the
        skip-zero manifest markers).  Leaves OUTSIDE the packed subtree
        (non-f32, host-resident, zero-size, or base-shape drift) are
        absent from ``layout`` and take the per-leaf host-encode path.
      * ``get(name)`` — the raw leaf, materialized lazily (memory-level
        parking and the rare delta-upgraded-to-full self-heal write);
        device refs are immutable so the late D2H is safe.

    Lossless payloads are delta (f32) + XOR residual (u32) over the whole
    flat buffer — the residual is all-zero for any element within 2x of
    its base (Sterbenz), so when the fused per-leaf nonzero counts sum to
    0 the residual plane's D2H is skipped entirely and the decoder
    reconstructs zeros.  int8 payloads are q (1 B/elem) + per-1024 f32
    scales — ~0.26x state bytes on the link.  When EVERY packed leaf is
    unchanged nothing crosses the link at all.
    """

    placement = "device"

    def __init__(self, state: Any, base: DeviceDeltaBase,
                 codec: str = "lossless",
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 interpret: Optional[bool] = None):
        assert codec in ("lossless", "int8"), codec
        from repro.kernels.ckpt_delta.ops import (default_interpret,
                                                  flat_int8_encode,
                                                  flat_lossless_encode,
                                                  pack_flat)
        self.codec = codec
        self.interpret = default_interpret() if interpret is None \
            else interpret
        named = tree_flatten_with_names(state)
        self.treedef = jax.tree_util.tree_structure(state)
        self.names = [n for n, _ in named]
        self._spec: dict[str, tuple[tuple, np.dtype]] = {}
        self._raw: dict[str, Any] = {}
        self._payload: dict[str, Any] = {}       # suffix -> host np / "zero"
        self._chunk_futs: list[Future] = []
        self._link_lock = threading.Lock()
        self._link_bytes = 0
        self.layout: Optional[FlatLayout] = None
        self.zero_names: tuple = ()

        packed: list[tuple[str, Any]] = []
        for name, leaf in named:
            if isinstance(leaf, jax.Array):
                self._spec[name] = (tuple(leaf.shape), np.dtype(leaf.dtype))
                self._raw[name] = leaf
                entry = None if base.layout is None \
                    else base.layout.by_name.get(name)
                if (entry is not None
                        and np.dtype(leaf.dtype) == np.float32
                        and entry.shape == tuple(leaf.shape)):
                    packed.append((name, leaf))
                # else: fallback leaf — per-leaf host encode; its raw D2H
                # is accounted when write_delta actually pulls it in get()
            else:
                arr = np.array(leaf, copy=True)   # mutable host leaf
                self._spec[name] = (tuple(arr.shape), arr.dtype)
                self._raw[name] = arr
                self._account(arr.nbytes)

        if not packed:
            return

        layout, base_flat = base.flat_subset([n for n, _ in packed])
        self.layout = layout
        new_flat = pack_flat([leaf for _, leaf in packed])
        group_leaf = layout.group_leaf_device()
        if codec == "lossless":
            d, r, leaf_changed, leaf_rnnz = flat_lossless_encode(
                new_flat, base_flat, group_leaf, num_leaves=len(packed),
                interpret=self.interpret)
            changed = np.asarray(leaf_changed)    # stats pull = device sync
            arrays: list[tuple[str, Any]] = []
            if changed.any():
                arrays.append(("d", d))
                if int(np.asarray(leaf_rnnz).sum()):
                    arrays.append(("r", r))
                else:           # residual known all-zero: skip its D2H —
                    self._payload["r"] = "zero"   # decoder reconstructs
        else:
            q, s, leaf_changed = flat_int8_encode(
                new_flat, base_flat, group_leaf, num_leaves=len(packed),
                interpret=self.interpret)
            changed = np.asarray(leaf_changed)    # stats pull = device sync
            arrays = [("q", q), ("s", s)] if changed.any() else []
        self.zero_names = tuple(
            entry.name for entry, c in zip(layout.entries, changed) if not c)
        self._start_transfers(arrays, chunk_bytes)

    def _start_transfers(self, arrays: list, chunk_bytes: int) -> None:
        """Chunk the encoded payload arrays and stream them D2H: first
        chunk synchronously (the blocking cost), the rest on the pool."""
        tasks: list[tuple] = []
        for sfx, dev in arrays:
            host = np.empty(int(dev.shape[0]), np.dtype(dev.dtype))
            self._payload[sfx] = host
            per = max(GROUP, chunk_bytes // host.itemsize)
            for a in range(0, host.size, per):
                tasks.append((host, dev, a, min(host.size, a + per)))
        if not tasks:
            return
        self._pull_chunk(*tasks[0])
        pool = transfer_pool()
        self._chunk_futs = [pool.submit(self._pull_chunk, *task)
                            for task in tasks[1:]]

    def _pull_chunk(self, host: np.ndarray, dev: Any, a: int, b: int) -> None:
        host[a:b] = np.asarray(dev[a:b])
        self._account((b - a) * host.itemsize)

    def _account(self, nbytes: int) -> None:
        with self._link_lock:
            self._link_bytes += int(nbytes)

    # -- flat protocol for incremental.write_delta ----------------------
    def flat_payload(self) -> dict:
        """suffix -> host payload array ("d"/"r" lossless, "q"/"s" int8)
        or the ``"zero"`` marker for a skipped all-zero residual plane;
        empty when every packed leaf was unchanged.  Blocks until every
        chunk has landed."""
        self.wait()
        return dict(self._payload)

    # -- LeafSource interface -------------------------------------------
    def spec(self, name: str) -> tuple[tuple, np.dtype]:
        return self._spec[name]

    def get(self, name: str) -> np.ndarray:
        leaf = self._raw[name]
        if isinstance(leaf, np.ndarray):
            return leaf
        arr = np.asarray(leaf)
        with self._link_lock:
            cur = self._raw[name]
            if isinstance(cur, np.ndarray):     # another worker won the race
                return cur
            self._raw[name] = arr
            # a raw pull IS link traffic (remote/self-heal full writes,
            # memory-level restores, and per-leaf fallback encodes pull raw
            # leaves from a delta source) — count it so bytes_on_link never
            # under-reports
            self._link_bytes += arr.nbytes
        return arr

    def wait(self) -> None:
        for fut in self._chunk_futs:
            fut.result()

    def bytes_on_link(self) -> int:
        self.wait()
        with self._link_lock:
            return self._link_bytes


def as_leaf_source(state: Any) -> LeafSource:
    """Adapt ``state`` (pytree or LeafSource) for the pipelined writers."""
    if isinstance(state, LeafSource):
        return state
    return PlainLeafSource(state)
