"""Checkpoint cadence policy — the knob Khaos turns at runtime.

The interval is in SECONDS (the paper's CI); ``due`` converts against the
job clock.  ``set_interval`` is hot-swappable: the controller's
reconfiguration lands here without a job restart (DESIGN.md §7.1), or via
the simulator's flink-semantics restart path for faithful E1/E2 runs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass
class CheckpointPolicy:
    interval_s: float
    _last_ckpt_t: float = 0.0
    history: list = field(default_factory=list)   # (t, new_interval)

    def set_interval(self, interval_s: float, t: float = 0.0) -> None:
        self.interval_s = float(interval_s)
        self.history.append((t, float(interval_s)))

    def due(self, t: float) -> bool:
        return t - self._last_ckpt_t >= self.interval_s

    def next_due(self, t: float) -> float:
        return self._last_ckpt_t + self.interval_s

    def mark(self, t: float) -> None:
        self._last_ckpt_t = t

    def reset(self, t: float) -> None:
        self._last_ckpt_t = t
