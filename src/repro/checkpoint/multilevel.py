"""Multi-level checkpointing (paper-cited related work [12–17], [21]).

Level 1  memory  — in-process snapshot; survives task restarts within the
                   same process/host (transient failures), lost on node loss
Level 2  local   — node-local disk (fast, lost with the node in the sim's
                   failure model unless peers hold replicas)
Level 3  remote  — durable remote store (slowest, survives everything)

Schedule: level-1 on every trigger, level-2 every ``local_every``-th,
level-3 every ``remote_every``-th.  Restore walks levels newest-first,
constrained by the failure type's coverage.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore

#: failure kind -> minimum level that survives it, at the DEFAULT
#: replication factor k=1.  Since PR 7 this is no longer an assumption:
#: level-2 survival of a node loss is earned by
#: ``checkpoint.replication.PeerReplicatedStore`` — each host pushes its
#: shard to k ring-neighbor peers, a save commits only once every shard
#: holds >= k replica acks, and restore after ``kill_host`` rebuilds the
#: failed host's shards from the surviving peer copies.  The general rule
#: is ``level_survives``/``derived_coverage`` below: with k=0 (replication
#: disabled) plain un-replicated node-local disk degrades node failures to
#: "remote".  ``sim.costmodel.SimCostModel`` asserts this table equals the
#: k=1 derivation at construction so the mechanism and the priced model
#: cannot silently diverge.
LEVEL_COVERAGE = {
    "task": "memory",
    "node": "local",
    "cluster": "remote",
}
_LEVELS = ("memory", "local", "remote")
_KINDS = ("task", "node", "cluster")


def level_survives(level: str, failure_kind: str,
                   replication_factor: int = 1) -> bool:
    """Whether one storage level survives one failure kind — the single
    derivation both the store substrate and the cost model price from.

    * ``memory`` lives in the process: only task restarts keep it.
    * ``local`` always survives a task restart; it survives a NODE loss
      iff k >= 1 peers hold replicas of the dead host's shards (the
      mechanism ``PeerReplicatedStore`` implements); a cluster failure
      takes every node's disk with it regardless of k.
    * ``remote`` is durable against everything modeled.
    """
    if level not in _LEVELS:
        raise ValueError(f"unknown level {level!r}; levels are {_LEVELS}")
    if failure_kind not in _KINDS:
        raise ValueError(
            f"unknown failure kind {failure_kind!r}; known kinds are "
            f"{sorted(_KINDS)} (see LEVEL_COVERAGE)")
    if level == "remote":
        return True
    if level == "memory":
        return failure_kind == "task"
    # local
    if failure_kind == "task":
        return True
    return failure_kind == "node" and replication_factor >= 1


def derived_coverage(replication_factor: int = 1) -> dict[str, str]:
    """failure kind -> minimum surviving level, derived from
    ``level_survives`` at the given replication factor.
    ``derived_coverage(1) == LEVEL_COVERAGE`` (asserted by SimCostModel);
    ``derived_coverage(0)["node"] == "remote"``."""
    return {kind: next(l for l in _LEVELS
                       if level_survives(l, kind, replication_factor))
            for kind in _KINDS}


@dataclass
class MultiLevelCheckpointer:
    local_store: Optional[CheckpointStore] = None
    remote_store: Optional[CheckpointStore] = None
    local_every: int = 2
    remote_every: int = 8
    _memory: dict = field(default_factory=dict)     # step -> state snapshot
    _count: int = 0
    saves_by_level: dict = field(default_factory=lambda: {l: 0 for l in _LEVELS})

    def save(self, step: int, state: Any, timestamp: float = 0.0,
             extra: Optional[dict] = None) -> list[str]:
        levels = ["memory"]
        if self.local_store and self._count % self.local_every == 0:
            levels.append("local")
        if self.remote_store and self._count % self.remote_every == 0:
            levels.append("remote")
        snap = jax.tree_util.tree_map(np.asarray, state)
        self._memory = {step: snap}                 # keep newest only
        self.saves_by_level["memory"] += 1
        if "local" in levels:
            self.local_store.save(step, snap, timestamp, extra)
            self.saves_by_level["local"] += 1
        if "remote" in levels:
            self.remote_store.save(step, snap, timestamp, extra)
            self.saves_by_level["remote"] += 1
        self._count += 1
        return levels

    def restore(self, treedef_like: Any, failure_kind: str = "task"
                ) -> tuple[Any, int, str]:
        """Restore the newest checkpoint that survives ``failure_kind``.
        Returns (state, step, level)."""
        min_level = LEVEL_COVERAGE[failure_kind]
        allowed = _LEVELS[_LEVELS.index(min_level):]
        candidates: list[tuple[int, str]] = []
        if "memory" in allowed and self._memory:
            candidates.append((max(self._memory), "memory"))
        if "local" in allowed and self.local_store:
            s = self.local_store.newest()
            if s is not None:
                candidates.append((s, "local"))
        if "remote" in allowed and self.remote_store:
            s = self.remote_store.newest()
            if s is not None:
                candidates.append((s, "remote"))
        if not candidates:
            raise FileNotFoundError(f"no checkpoint survives {failure_kind}")
        # newest step wins; on ties prefer the fastest level to restore from
        speed = {"memory": 2, "local": 1, "remote": 0}
        step, level = max(candidates, key=lambda c: (c[0], speed[c[1]]))
        if level == "memory":
            return copy.deepcopy(self._memory[step]), step, level
        store = self.local_store if level == "local" else self.remote_store
        state, _ = store.restore(treedef_like, step)
        return state, step, level

    def on_node_failure(self) -> None:
        """Node loss wipes the in-memory level (and, in the sim, local disk
        is handled by the caller's cost model)."""
        self._memory.clear()

    def stats(self) -> dict:
        return {"saves": self._count,
                "saves_by_level": dict(self.saves_by_level)}


def allowed_levels(failure_kind: str, replication_factor: int = 1
                   ) -> tuple[str, ...]:
    """Levels that survive ``failure_kind``, fastest-to-restore first,
    derived from ``level_survives`` at ``replication_factor`` (default 1 =
    the LEVEL_COVERAGE table).  Unknown kinds are an error, not a silent
    worst-case default — a typo'd kind would otherwise quietly restore
    from the wrong level."""
    if failure_kind not in _KINDS:
        raise ValueError(
            f"unknown failure kind {failure_kind!r}; known kinds are "
            f"{sorted(_KINDS)} (see LEVEL_COVERAGE)")
    return tuple(l for l in _LEVELS
                 if level_survives(l, failure_kind, replication_factor))
