"""Multi-level checkpointing (paper-cited related work [12–17], [21]).

Level 1  memory  — in-process snapshot; survives task restarts within the
                   same process/host (transient failures), lost on node loss
Level 2  local   — node-local disk (fast, lost with the node in the sim's
                   failure model unless peers hold replicas)
Level 3  remote  — durable remote store (slowest, survives everything)

Schedule: level-1 on every trigger, level-2 every ``local_every``-th,
level-3 every ``remote_every``-th.  Restore walks levels newest-first,
constrained by the failure type's coverage.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import numpy as np

from repro.checkpoint.store import CheckpointStore

#: failure kind -> minimum level that survives it.  NOTE the deliberate
#: modeling assumption ``"node" -> "local"``: node-local checkpoints are
#: treated as surviving a node loss, i.e. the level-2 store behaves as if
#: peers replicate it (paper-cited SCR/multi-level schemes).  Plain
#: un-replicated node-local disk would degrade node failures to "remote".
#: ``sim.costmodel.SimCostModel`` asserts this exact mapping at
#: construction so a silent edit here cannot skew priced recoveries.
LEVEL_COVERAGE = {
    "task": "memory",
    "node": "local",
    "cluster": "remote",
}
_LEVELS = ("memory", "local", "remote")


@dataclass
class MultiLevelCheckpointer:
    local_store: Optional[CheckpointStore] = None
    remote_store: Optional[CheckpointStore] = None
    local_every: int = 2
    remote_every: int = 8
    _memory: dict = field(default_factory=dict)     # step -> state snapshot
    _count: int = 0
    saves_by_level: dict = field(default_factory=lambda: {l: 0 for l in _LEVELS})

    def save(self, step: int, state: Any, timestamp: float = 0.0,
             extra: Optional[dict] = None) -> list[str]:
        levels = ["memory"]
        if self.local_store and self._count % self.local_every == 0:
            levels.append("local")
        if self.remote_store and self._count % self.remote_every == 0:
            levels.append("remote")
        snap = jax.tree_util.tree_map(np.asarray, state)
        self._memory = {step: snap}                 # keep newest only
        self.saves_by_level["memory"] += 1
        if "local" in levels:
            self.local_store.save(step, snap, timestamp, extra)
            self.saves_by_level["local"] += 1
        if "remote" in levels:
            self.remote_store.save(step, snap, timestamp, extra)
            self.saves_by_level["remote"] += 1
        self._count += 1
        return levels

    def restore(self, treedef_like: Any, failure_kind: str = "task"
                ) -> tuple[Any, int, str]:
        """Restore the newest checkpoint that survives ``failure_kind``.
        Returns (state, step, level)."""
        min_level = LEVEL_COVERAGE[failure_kind]
        allowed = _LEVELS[_LEVELS.index(min_level):]
        candidates: list[tuple[int, str]] = []
        if "memory" in allowed and self._memory:
            candidates.append((max(self._memory), "memory"))
        if "local" in allowed and self.local_store:
            s = self.local_store.newest()
            if s is not None:
                candidates.append((s, "local"))
        if "remote" in allowed and self.remote_store:
            s = self.remote_store.newest()
            if s is not None:
                candidates.append((s, "remote"))
        if not candidates:
            raise FileNotFoundError(f"no checkpoint survives {failure_kind}")
        # newest step wins; on ties prefer the fastest level to restore from
        speed = {"memory": 2, "local": 1, "remote": 0}
        step, level = max(candidates, key=lambda c: (c[0], speed[c[1]]))
        if level == "memory":
            return copy.deepcopy(self._memory[step]), step, level
        store = self.local_store if level == "local" else self.remote_store
        state, _ = store.restore(treedef_like, step)
        return state, step, level

    def on_node_failure(self) -> None:
        """Node loss wipes the in-memory level (and, in the sim, local disk
        is handled by the caller's cost model)."""
        self._memory.clear()

    def stats(self) -> dict:
        return {"saves": self._count,
                "saves_by_level": dict(self.saves_by_level)}


def allowed_levels(failure_kind: str) -> tuple[str, ...]:
    """Levels that survive ``failure_kind``, fastest-to-restore first.
    Unknown kinds are an error, not a silent worst-case default — a typo'd
    kind would otherwise quietly restore from the wrong level."""
    if failure_kind not in LEVEL_COVERAGE:
        raise ValueError(
            f"unknown failure kind {failure_kind!r}; known kinds are "
            f"{sorted(LEVEL_COVERAGE)} (see LEVEL_COVERAGE)")
    min_level = LEVEL_COVERAGE[failure_kind]
    return _LEVELS[_LEVELS.index(min_level):]
