"""Sharding rules: logical parameter/activation layout -> NamedSharding.

Policy (DESIGN.md §5):
  * batch dims shard over the data-parallel axes — ('pod', 'data') on the
    multi-pod mesh, ('data',) on a single pod;
  * tensor-parallel 'model' axis shards attention heads, ffn hidden, vocab;
  * FSDP (ZeRO-3 style) shards the non-TP weight dim over 'data' for models
    above ``fsdp_min_params`` — weight all-gathers stay *within* a pod, only
    gradient reductions cross the 'pod' axis;
  * MoE experts shard over 'model' when divisible (olmoe 64e), otherwise
    experts keep TP-sharded ffn dims (grok 8e);
  * KV caches: batch -> data axes, kv-heads -> 'model' when divisible,
    otherwise the cache *sequence* dim shards over 'model'
    (flash-decoding-style contraction, GSPMD inserts the softmax combine).

Every rule degrades to replication when a dim is not divisible by the axis
size — a sharding must never make a cell uncompilable.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import MeshConfig, ModelConfig, ShardingConfig
from repro.utils.trees import tree_map_with_names

Axis = Optional[Any]   # None | str | tuple[str, ...]


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return int(mesh.shape[axis])


def _place(shape: Sequence[int], prefs: Sequence[tuple[int, Axis]],
           mesh: Mesh) -> P:
    """Assign axes to dims in priority order, skipping non-divisible dims."""
    spec: list[Axis] = [None] * len(shape)
    used: set = set()
    for dim, axis in prefs:
        if axis is None or dim >= len(shape):
            continue
        names = axis if isinstance(axis, tuple) else (axis,)
        if any(n in used for n in names):
            continue
        if spec[dim] is not None:
            continue
        if shape[dim] % _axis_size(mesh, axis) != 0:
            continue
        spec[dim] = axis
        used.update(names)
    return P(*spec)


class ShardingRules:
    """Resolves PartitionSpecs for params, inputs and caches of one job."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, scfg: ShardingConfig):
        self.cfg = cfg
        self.mesh = mesh
        self.scfg = scfg
        axes = mesh.axis_names
        self.dp: Axis = tuple(a for a in ("pod", "data") if a in axes) or None
        if isinstance(self.dp, tuple) and len(self.dp) == 1:
            self.dp = self.dp[0]
        self.tp: Axis = "model" if "model" in axes else None
        use_fsdp = scfg.fsdp and cfg.param_count() >= scfg.fsdp_min_params
        if use_fsdp and "data" in axes:
            # ZeRO-3 over every data-parallel axis: on the multi-pod mesh the
            # 'pod' axis joins so 316B-class optimizer state halves at 512
            # chips (weight gathers then cross DCI — the documented tradeoff).
            self.fsdp = ("pod", "data") if "pod" in axes else "data"
        else:
            self.fsdp = None

    # -- parameters ----------------------------------------------------------
    def param_spec(self, name: str, shape: Sequence[int]) -> P:
        leaf = name.split("/")[-1]
        in_moe = "/moe/" in f"/{name}/"
        mesh, fsdp, tp = self.mesh, self.fsdp, self.tp

        def tail(base_rank: int, prefs):
            """Rules are defined on the trailing base_rank dims; leading
            (scan-stacked) dims stay unsharded."""
            off = len(shape) - base_rank
            assert off >= 0, (name, shape, base_rank)
            return _place(shape, [(d + off, a) for d, a in prefs], mesh)

        if leaf in ("embed",):              # (V, d)
            return tail(2, [(0, tp), (1, fsdp)])
        if leaf in ("unembed",):            # (d, V)
            return tail(2, [(1, tp), (0, fsdp)])
        if leaf in ("wq", "wk", "wv"):      # (d, N, h)
            # heads shard over TP when divisible; otherwise attention weights
            # replicate (no head-dim sharding — the score contraction would
            # force per-layer all-reduces).  Archs whose head counts don't
            # divide 16 (qwen2-vl 28H, whisper 12H, RG 10H) run attention
            # data-parallel only — surfaced in §Roofline as a TP gap.
            return tail(3, [(1, tp), (0, fsdp)])
        if leaf in ("bq", "bk", "bv"):      # (N, h)
            return tail(2, [(0, tp)])
        if leaf == "wo":                    # (N, h, d)
            return tail(3, [(0, tp), (1, tp), (2, fsdp)])
        if leaf == "router":                # (d, E)
            return tail(2, [(0, fsdp)])
        if leaf in ("w_up", "w_gate") and in_moe:      # (E, d, f)
            ea = self._expert_axis(shape[-3])
            if self.scfg.moe_megatron and ea is None:
                # Megatron MLP inside each expert: f column-parallel over the
                # combined (fsdp x tp) axis, d unsharded -> exactly one
                # output all-reduce per expert block instead of partial-sum
                # reductions on BOTH einsums (grok: 8 experts don't divide
                # the tp axis, so this is the EP-free fallback).
                return tail(3, [(2, self._ftp())])
            return tail(3, [(0, ea), (2, tp), (1, fsdp)])
        if leaf == "w_down" and in_moe:                # (E, f, d)
            ea = self._expert_axis(shape[-3])
            if self.scfg.moe_megatron and ea is None:
                return tail(3, [(1, self._ftp())])     # row-parallel
            return tail(3, [(0, ea), (1, tp), (2, fsdp)])
        if leaf in ("w_up", "w_gate", "w_x", "cm_wk", "cm_wr",
                    "w_r", "w_k", "w_v", "w_g", "wA"):  # (d, f)
            return tail(2, [(1, tp), (0, fsdp)])
        if leaf in ("w_down", "w_out", "cm_wv", "w_o", "wB"):   # (f, d)
            return tail(2, [(0, tp), (1, fsdp)])
        # everything else (norms, biases, conv, gates, mu, LoRA vectors) is
        # small: replicate.
        return P()

    def _ftp(self) -> Axis:
        """Combined (fsdp..., tp) axis tuple for maximal weight sharding."""
        parts: list = []
        if self.fsdp is not None:
            parts.extend(self.fsdp if isinstance(self.fsdp, tuple) else (self.fsdp,))
        if self.tp is not None:
            parts.append(self.tp)
        if not parts:
            return None
        return tuple(parts) if len(parts) > 1 else parts[0]

    def _expert_axis(self, n_experts: int) -> Axis:
        mode = self.scfg.expert_axis
        if mode == "none":
            return None
        if mode == "auto":
            mode = "model"
        axis = {"model": self.tp, "data": "data" if "data" in self.mesh.axis_names else None}[mode]
        if axis is not None and n_experts % _axis_size(self.mesh, axis) == 0:
            return axis
        return None

    # -- inputs / activations -------------------------------------------------
    def input_spec(self, name: str, shape: Sequence[int]) -> P:
        leaf = name.split("/")[-1]
        mesh, dp = self.mesh, self.dp
        if leaf in ("tokens", "labels", "dec_tokens"):      # (B, S)
            return _place(shape, [(0, dp)], mesh)
        if leaf == "positions":                             # (3, B, S)
            return _place(shape, [(1, dp)], mesh)
        if leaf in ("frames", "vision_embeds"):             # (B, S, d)
            return _place(shape, [(0, dp)], mesh)
        if leaf == "pos":                                   # (B,)
            return _place(shape, [(0, dp)], mesh)
        return P()

    # -- caches ---------------------------------------------------------------
    def cache_spec(self, name: str, shape: Sequence[int]) -> P:
        leaf = name.split("/")[-1]
        mesh, dp, tp = self.mesh, self.dp, self.tp
        seq = tp if self.scfg.decode_kv_seq_shard else None

        def tail(base_rank: int, prefs):
            off = len(shape) - base_rank
            return _place(shape, [(d + off, a) for d, a in prefs], mesh)

        if leaf in ("k", "v", "ck", "cv"):       # (B, S, K, h)
            return tail(4, [(0, dp), (2, tp), (1, seq)])
        if leaf == "tm_s":                       # (B, H, hs, hs)
            return tail(4, [(0, dp), (1, tp), (2, tp)])
        if leaf in ("tm_x", "cm_x"):             # (B, d)
            return tail(2, [(0, dp), (1, tp)])
        if leaf == "h":                          # (B, lru)
            return tail(2, [(0, dp), (1, tp)])
        if leaf == "conv":                       # (B, cw-1, lru)
            return tail(3, [(0, dp), (2, tp)])
        return P()

    # -- tree-level helpers ----------------------------------------------------
    def _named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def params_shardings(self, params_tree):
        return tree_map_with_names(
            lambda n, l: self._named(self.param_spec(n, l.shape)), params_tree)

    def state_shardings(self, state_tree):
        """TrainState {params, opt, step}: opt m/v mirror the param layout."""
        def rule(name, leaf):
            if name == "step":
                return self._named(P())
            # strip 'params/' or 'opt/m/' etc. prefixes
            parts = name.split("/")
            if parts[0] == "params":
                core = "/".join(parts[1:])
            elif parts[0] == "opt":
                core = "/".join(parts[2:])
            else:
                core = name
            return self._named(self.param_spec(core, leaf.shape))
        return tree_map_with_names(rule, state_tree)

    def batch_shardings(self, batch_tree):
        return tree_map_with_names(
            lambda n, l: self._named(self.input_spec(n, l.shape)), batch_tree)

    def cache_shardings(self, cache_tree):
        return tree_map_with_names(
            lambda n, l: self._named(self.cache_spec(n, l.shape)), cache_tree)

    def replicated(self):
        return self._named(P())

    def dp_vector(self, shape: Sequence[int]):
        return self._named(_place(shape, [(0, self.dp)], self.mesh))

    # -- activation annotations (with_sharding_constraint inside the model) ---
    def act_spec(self, kind: str, shape: Sequence[int]) -> P:
        mesh, dp, tp = self.mesh, self.dp, self.tp
        if kind == "hidden":       # (B, S, d)
            if self.scfg.seq_shard_hidden:
                # Megatron sequence parallelism: residual-stream activations
                # (incl. scan carries / saved microbatch residuals) shard
                # their SEQ dim over 'model'; GSPMD turns the TP all-reduce
                # into reduce-scatter + all-gather around attention/ffn.
                return _place(shape, [(0, dp), (1, tp)], mesh)
            return _place(shape, [(0, dp)], mesh)
        if kind in ("heads",):     # (B, S, N, hd)
            return _place(shape, [(0, dp), (2, tp)], mesh)
        if kind in ("wide",):      # (B, S, f) — ffn/lru hidden
            return _place(shape, [(0, dp), (2, tp)], mesh)
        if kind == "logits":       # (B, S, V)
            return _place(shape, [(0, dp), (2, tp)], mesh)
        if kind == "moe_buf":      # (G, E, C, d)
            return _place(shape, [(0, dp), (1, self._expert_axis(shape[1]))], mesh)
        if kind == "moe_hidden":   # (G, E, C, f)
            return _place(shape, [(0, dp), (1, self._expert_axis(shape[1])), (3, tp)], mesh)
        return P()

    @property
    def dp_size(self) -> int:
        return _axis_size(self.mesh, self.dp)

    def annotator(self) -> "ActivationAnnotator":
        return ActivationAnnotator(self)


class ActivationAnnotator:
    """Threaded through the model code as ``ann``; pins activation layouts
    inside scan bodies so GSPMD never loses batch sharding across the layer
    loop (see DESIGN.md §5)."""

    def __init__(self, rules: ShardingRules):
        self.rules = rules
        self.dp_size = rules.dp_size
        self.moe_groups = rules.dp_size

    def constrain(self, x, kind: str):
        spec = self.rules.act_spec(kind, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.rules.mesh, spec))
