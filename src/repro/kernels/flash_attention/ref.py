"""Pure-jnp oracle for the flash attention kernel (GQA, causal, window,
softcap) — naive full-materialization softmax attention in fp32."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqnh,bsnh->bnqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(k.shape[1])[None, :]
    allow = jnp.ones((S, k.shape[1]), bool)
    if causal:
        allow = allow & (k_pos <= q_pos)
    if window > 0:
        allow = allow & (q_pos - k_pos < window)
    s = jnp.where(allow[None, None], s, -1e30)
    p = jnp.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = jnp.einsum("bnqs,bsnh->bqnh", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
