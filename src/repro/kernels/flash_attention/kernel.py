"""Flash attention forward kernel (TPU Pallas).

Canonical TPU streaming-softmax layout:
  grid = (B, H, nq, nkv) — the innermost kv axis is sequential on TPU, so
  the output block (index_map independent of j) stays resident in VMEM and
  accumulates across kv chunks; running max/denominator live in two small
  side outputs.

  q     (B, S, H, hd)   block (1, bq, 1, hd)
  k, v  (B, S, K, hd)   block (1, bkv, 1, hd); GQA: kv head = h // (H // K)
  o     (B, S, H, hd)   block (1, bq, 1, hd)  fp32 accumulator
  m, l  (B, H, S)       block (1, 1, bq)      running max / sum

VMEM working set per step: bq*hd + 2*bkv*hd + bq*bkv fp32
(512, 1024, hd=128 -> ~1.3 MB) — MXU dims are multiples of 128.

Causal / sliding-window masking is positional; fully-masked (i, j) pairs
are skipped with pl.when (the DMA still runs; the paper's roofline
methodology charges the skipped FLOPs at zero).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, softcap: float,
            bq: int, bkv: int, nkv: int):
    j = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q_start = qi * bq
    k_start = j * bkv
    # skip blocks that are entirely masked out
    relevant = jnp.bool_(True)
    if causal:
        relevant = relevant & (k_start <= q_start + bq - 1)
    if window > 0 and causal:
        relevant = relevant & (k_start + bkv - 1 >= q_start - (window - 1))

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)        # (bq, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # (bkv, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        allow = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            allow = allow & (k_pos <= q_pos)
        if window > 0:
            allow = allow & (q_pos - k_pos < window)
        s = jnp.where(allow, s, -1e30)

        m_prev = m_ref[0, 0, :]                          # (bq,)
        l_prev = l_ref[0, 0, :]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        o_ref[0, :, 0, :] = o_ref[0, :, 0, :] * corr[:, None] + pv
        m_ref[0, 0, :] = m_new
        l_ref[0, 0, :] = l_new

    @pl.when(j == nkv - 1)
    def _finalize():
        l = l_ref[0, 0, :]
        o_ref[0, :, 0, :] = o_ref[0, :, 0, :] / jnp.maximum(l, 1e-30)[:, None]


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        softcap: float = 0.0, block_q: int = 128,
                        block_kv: int = 128, interpret: bool = False
                        ) -> jax.Array:
    B, S, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(block_q, S)
    bkv = min(block_kv, Skv)
    assert S % bq == 0 and Skv % bkv == 0, "seq must divide block size"
    nq, nkv = S // bq, Skv // bkv
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bkv=bkv, nkv=nkv)

    o, m, l = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bkv, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, bkv, 1, hd), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    del m, l
    return o.astype(q.dtype)
