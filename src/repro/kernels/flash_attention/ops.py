"""Public jit'd wrapper for the flash attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_fwd


@partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                   "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_kv: int = 128, interpret: bool = False):
    """q (B,S,H,hd); k/v (B,S,K,hd) with K | H (GQA). Returns (B,S,H,hd)."""
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               softcap=softcap, block_q=block_q,
                               block_kv=block_kv, interpret=interpret)
