from repro.kernels.rwkv6.ops import wkv6

__all__ = ["wkv6"]
