"""Public jit'd wrapper for the WKV-6 kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rwkv6.kernel import wkv6_fwd


@partial(jax.jit, static_argnames=("chunk_t", "interpret"))
def wkv6(r, k, v, w, u, s0, *, chunk_t: int = 128, interpret: bool = False):
    """r/k/v/w (B,S,H,hs); u (H,hs); s0 (B,H,hs,hs) -> (y, s_final)."""
    return wkv6_fwd(r, k, v, w, u, s0, chunk_t=chunk_t, interpret=interpret)
