"""RWKV-6 (Finch) WKV recurrence kernel (TPU Pallas).

    y_t[j]    = sum_i r_t[i] * (S_t[i,j] + u[i] * k_t[i] * v_t[j])
    S_{t+1}   = diag(w_t) S_t + k_t^T v_t

Grid (B, H, nt) with the time axis innermost; the per-head state
S (hs x hs) fp32 persists in a VMEM-resident output block across chunk
steps.  Inside a chunk the recurrence is sequential over ct timesteps
(fori_loop) — each step is an (hs x hs) rank-1 update + matvec, which is
VPU/MXU-friendly at hs = 64.

  r,k,v,w  (B, S, H, hs)  block (1, ct, 1, hs)
  u        (H, hs)        block (1, hs)
  y        (B, S, H, hs)  block (1, ct, 1, hs) fp32
  S        (B, H, hs, hs) block (1, 1, hs, hs) fp32 (also returned)

VMEM per step: 4*ct*hs + hs*hs + ct*hs fp32 (ct=128, hs=64 -> ~0.2 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, s_ref, *,
            ct: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        s_ref[0, 0, :, :] = s0_ref[0, 0, :, :].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)   # (ct, hs)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)         # (hs,)

    def step(i, carry):
        s, y = carry
        kv = k[i][:, None] * v[i][None, :]             # (hs, hs)
        yt = (r[i][None, :] @ (s + u[:, None] * kv))[0]  # (hs,)
        s = w[i][:, None] * s + kv
        y = y.at[i].set(yt)
        return s, y

    s0 = s_ref[0, 0, :, :]
    y0 = jnp.zeros_like(r)
    s_final, y = jax.lax.fori_loop(0, ct, step, (s0, y0))
    y_ref[0, :, 0, :] = y
    s_ref[0, 0, :, :] = s_final


def wkv6_fwd(r, k, v, w, u, s0, *, chunk_t: int = 128,
             interpret: bool = False):
    B, S, H, hs = r.shape
    ct = min(chunk_t, S)
    assert S % ct == 0
    nt = S // ct

    kernel = functools.partial(_kernel, ct=ct)
    y, s = pl.pallas_call(
        kernel,
        grid=(B, H, nt),
        in_specs=[
            pl.BlockSpec((1, ct, 1, hs), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, ct, 1, hs), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, ct, 1, hs), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, ct, 1, hs), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, hs), lambda b, h, t: (h, 0)),
            pl.BlockSpec((1, 1, hs, hs), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ct, 1, hs), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, 1, hs, hs), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, hs), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hs, hs), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s
