"""Oracle: sequential WKV-6 recurrence (mirrors models/layers._rwkv_wkv_scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, s0):
    r, k, v, w = (x.astype(jnp.float32) for x in (r, k, v, w))
    u = u.astype(jnp.float32)
    s0 = s0.astype(jnp.float32)

    def step(s, rkvw):
        rt, kt, vt, wt = rkvw                       # (B, H, hs)
        kv = kt[..., :, None] * vt[..., None, :]    # (B, H, hs, hs)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(x.swapaxes(0, 1) for x in (r, k, v, w))
    s_final, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1), s_final
