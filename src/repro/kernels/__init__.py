"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each kernel ships three files:
  kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — the jit'd public wrapper (``interpret=`` switch for CPU)
  ref.py    — pure-jnp/numpy oracle used by the allclose test sweeps

Kernels are the TPU fast path behind the model zoo's ``attn_impl="pallas"``;
the XLA fallbacks remain the default on CPU (DESIGN.md §8).
"""
