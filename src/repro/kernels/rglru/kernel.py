"""RG-LRU diagonal linear recurrence kernel (TPU Pallas).

Computes h_t = a_t * h_{t-1} + b_t over the time axis with the channel dim
tiled across the grid and time chunked on the innermost (sequential) grid
axis; the carry h lives in the last row of the output block, so each chunk
step reads its predecessor's carry from VMEM.

  a, b  (B, S, D)  block (1, ct, bd)   grid (B, nd, nt) — nt innermost
  h0    (B, D)     block (1, bd)
  h     (B, S, D)  block (1, ct, bd)   fp32

VMEM per step: 3 * ct * bd fp32 (256 x 512 -> 1.5 MB).  Inside a chunk the
scan runs as a log2(ct)-step Blelloch-style doubling on registers (VPU
friendly) rather than a length-ct sequential loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h0_ref, a_ref, b_ref, h_ref, carry_ref, *, ct: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        carry_ref[0, :] = h0_ref[0, :].astype(jnp.float32)

    a = a_ref[0, :, :].astype(jnp.float32)    # (ct, bd)
    b = b_ref[0, :, :].astype(jnp.float32)
    h_prev = carry_ref[0, :]                  # (bd,)

    # in-chunk associative doubling: (A, B) composition
    # h_t = (prod a_{<=t}) * h_in + B_t
    A, Bc = a, b
    shift = 1
    while shift < ct:
        A_s = jnp.concatenate([jnp.ones((shift, A.shape[1]), A.dtype),
                               A[:-shift]], axis=0)
        B_s = jnp.concatenate([jnp.zeros((shift, Bc.shape[1]), Bc.dtype),
                               Bc[:-shift]], axis=0)
        Bc = A * B_s + Bc
        A = A * A_s
        shift *= 2
    h_seq = A * h_prev[None, :] + Bc
    h_ref[0, :, :] = h_seq
    carry_ref[0, :] = h_seq[-1, :]


def rglru_scan_fwd(a: jax.Array, b: jax.Array, h0: jax.Array, *,
                   chunk_t: int = 256, block_d: int = 512,
                   interpret: bool = False) -> jax.Array:
    B, S, D = a.shape
    ct = min(chunk_t, S)
    bd = min(block_d, D)
    assert S % ct == 0 and D % bd == 0
    nt, nd = S // ct, D // bd

    kernel = functools.partial(_kernel, ct=ct)
    h, carry = pl.pallas_call(
        kernel,
        grid=(B, nd, nt),
        in_specs=[
            pl.BlockSpec((1, bd), lambda bi, di, ti: (bi, di)),
            pl.BlockSpec((1, ct, bd), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, ct, bd), lambda bi, di, ti: (bi, ti, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, ct, bd), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, bd), lambda bi, di, ti: (bi, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, D), jnp.float32),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        interpret=interpret,
    )(h0, a, b)
    del carry
    return h
