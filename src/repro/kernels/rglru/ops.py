"""Public jit'd wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rglru.kernel import rglru_scan_fwd


@partial(jax.jit, static_argnames=("chunk_t", "block_d", "interpret"))
def rglru_scan(a, b, h0, *, chunk_t: int = 256, block_d: int = 512,
               interpret: bool = False):
    """h_t = a_t * h_{t-1} + b_t; a/b (B,S,D), h0 (B,D) -> h (B,S,D) fp32."""
    return rglru_scan_fwd(a, b, h0, chunk_t=chunk_t, block_d=block_d,
                          interpret=interpret)
