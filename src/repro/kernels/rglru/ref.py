"""Oracle: sequential scan for h_t = a_t h_{t-1} + b_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(a, b, h0):
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    h0 = h0.astype(jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
    return hs.swapaxes(0, 1)
