"""Public jit'd wrappers for the delta codec kernels.

Three tiers of entry points:

  * whole-buffer codec ops (``delta_encode``/``lossless_decode``/...):
    shape-generic, used by the host<->device decode paths.

  * per-leaf fused ops (``lossless_encode_leaf``/``int8_encode_leaf``):
    encode + unchanged-leaf detection + residual-sparsity count in one
    jitted call per leaf.  The pre-flat device delta plane dispatched
    these once per f32 leaf; they remain as the host-fallback building
    block and the dispatch-overhead baseline ``bench_ckpt`` records
    (``per_leaf_encode_s`` in the bench_ckpt/3 artifact).

  * flat fused ops (``pack_flat``/``flat_lossless_encode``/
    ``flat_int8_encode``): the hot path.  ``pack_flat`` concatenates the
    f32 subtree into ONE GROUP-aligned device mega-buffer (one jitted
    dispatch); the flat encoders run ONE pallas_call over it and reduce
    the kernel's per-group change statistics to per-LEAF counts with a
    scatter-add over the layout's group->leaf map — all inside the same
    jit, so a delta trigger costs one pack dispatch + one encode dispatch
    regardless of how many hundreds of leaves the state has.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ckpt_delta.kernel import (GROUP, delta_decode_fwd,
                                             delta_encode_fwd,
                                             flat_delta_encode_fwd,
                                             flat_lossless_encode_fwd,
                                             lossless_decode_fwd,
                                             lossless_encode_fwd)


def default_interpret() -> bool:
    """Pallas interpret mode is required off-accelerator (the CPU backend
    has no Mosaic lowering); on TPU the compiled kernels run."""
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def delta_encode(new, base, *, block_groups: int = 8, interpret: bool = False):
    """(new - base) -> (int8 payload, per-1024-group fp32 scales)."""
    return delta_encode_fwd(new, base, block_groups=block_groups,
                            interpret=interpret)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def delta_decode(q, scales, *, block_groups: int = 8, interpret: bool = False):
    """Inverse of delta_encode (returns fp32 delta)."""
    return delta_decode_fwd(q, scales, block_groups=block_groups,
                            interpret=interpret)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def lossless_encode(new, base, *, block_groups: int = 8,
                    interpret: bool = False):
    """Fused lossless encode: (f32 delta, u32 XOR residual) vs base."""
    return lossless_encode_fwd(new, base, block_groups=block_groups,
                               interpret=interpret)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def lossless_decode(base, delta, resid, *, block_groups: int = 8,
                    interpret: bool = False):
    """Bit-exact inverse of lossless_encode (returns the original f32)."""
    return lossless_decode_fwd(base, delta, resid, block_groups=block_groups,
                               interpret=interpret)


# ---------------------------------------------------------------------------
# Per-leaf fused entry points for the device-resident delta plane
# ---------------------------------------------------------------------------

def _bits_changed(new_f32: jax.Array, base_f32: jax.Array) -> jax.Array:
    """True iff any f32 bit pattern differs — the device twin of the host
    path's raw-byte equality check that gates the manifest "zero" marker."""
    return jnp.any(jax.lax.bitcast_convert_type(new_f32, jnp.uint32)
                   != jax.lax.bitcast_convert_type(base_f32, jnp.uint32))


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def lossless_encode_leaf(new, base, *, block_groups: int = 8,
                         interpret: bool = False):
    """One leaf's on-device lossless encode: (delta f32, resid u32 — both
    GROUP-padded), plus ``changed`` (any bit differs -> leaf must be
    written) and ``resid_nnz`` (nonzero residual words).  The residual is
    almost always all-zero (base + delta rounds back exactly whenever
    new/base are within 2x of each other), so the caller skips its D2H
    when ``resid_nnz == 0`` and reconstructs zeros host-side — the blob on
    disk stays byte-identical to the host encoder's."""
    nf = new.reshape(-1).astype(jnp.float32)
    bf = base.reshape(-1).astype(jnp.float32)
    d, r = lossless_encode_fwd(nf, bf, block_groups=block_groups,
                               interpret=interpret)
    return d, r, _bits_changed(nf, bf), jnp.count_nonzero(r)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def int8_encode_leaf(new, base, *, block_groups: int = 8,
                     interpret: bool = False):
    """One leaf's on-device int8 group-quantized delta encode: (q int8
    GROUP-padded, per-group f32 scales, changed).  Worst-case error per
    element is half a quantization step: |err| <= max|delta_group| / 254
    (scale = amax/127, round-to-nearest) — the documented bound the
    round-trip test asserts."""
    nf = new.reshape(-1).astype(jnp.float32)
    bf = base.reshape(-1).astype(jnp.float32)
    q, s = delta_encode_fwd(nf, bf, block_groups=block_groups,
                            interpret=interpret)
    return q, s, _bits_changed(nf, bf)


# ---------------------------------------------------------------------------
# Flat (mega-buffer) entry points for the packed device delta plane
# ---------------------------------------------------------------------------

@jax.jit
def pack_flat(leaves):
    """Pack a sequence of f32 leaves into ONE flat device buffer, each
    leaf zero-padded to a whole number of GROUPs so it starts at a
    GROUP-aligned offset (``pipeline.FlatLayout`` records the offsets).
    One jitted dispatch for the whole subtree; jit retraces per distinct
    layout (leaf shape set), which the device base caches across
    triggers."""
    parts = []
    for leaf in leaves:
        v = leaf.reshape(-1).astype(jnp.float32)
        pad = (-v.shape[0]) % GROUP
        if pad:
            v = jnp.concatenate([v, jnp.zeros((pad,), jnp.float32)])
        parts.append(v)
    return jnp.concatenate(parts)


def _flat_blocks(new_flat, base_flat, group_leaf, block_groups: int,
                 interpret: bool):
    """Pick the effective block size and zero-pad the flat pair to a whole
    number of kernel BLOCKS (= bg groups), so ``_grid_block`` never has to
    shrink the block to divide an awkward group count.  Interpret mode
    (CPU backend) pays per grid STEP — each step re-slices the full
    operands — so there the whole buffer becomes ONE block (no VMEM bound
    applies off-accelerator); compiled mode keeps ``block_groups`` (64
    groups x 4 f32 planes = 1 MiB of VMEM).  Pad groups diff zero-vs-zero
    (changed == rnnz == 0) and scatter onto leaf 0, adding nothing;
    callers slice payloads back to ``n``."""
    n = new_flat.shape[0]
    if interpret:
        block_groups = max(1, n // GROUP)
    pad_g = (-(n // GROUP)) % block_groups
    if pad_g:
        z = jnp.zeros((pad_g * GROUP,), jnp.float32)
        new_flat = jnp.concatenate([new_flat, z])
        base_flat = jnp.concatenate([base_flat, z])
        group_leaf = jnp.concatenate(
            [group_leaf, jnp.zeros((pad_g,), group_leaf.dtype)])
    return new_flat, base_flat, group_leaf, n, block_groups


@partial(jax.jit, static_argnames=("num_leaves", "block_groups", "interpret"))
def flat_lossless_encode(new_flat, base_flat, group_leaf, *, num_leaves: int,
                         block_groups: int = 64, interpret: bool = False):
    """Fused lossless encode of the packed mega-buffer: ONE pallas_call
    emits (delta f32, resid u32) plus per-group change stats, and a
    scatter-add over ``group_leaf`` (the layout's group->leaf index map)
    reduces them to per-LEAF counts — returns (delta, resid,
    leaf_changed i32[num_leaves], leaf_rnnz i32[num_leaves]).  A leaf
    with ``leaf_changed == 0`` is bit-identical to the base (the skip-zero
    manifest marker); ``leaf_rnnz.sum() == 0`` means the residual plane is
    all-zero and its D2H can be skipped entirely."""
    new_flat, base_flat, group_leaf, n, block_groups = _flat_blocks(
        new_flat, base_flat, group_leaf, block_groups, interpret)
    d, r, gc, gz = flat_lossless_encode_fwd(new_flat, base_flat,
                                            block_groups=block_groups,
                                            interpret=interpret)
    leaf_changed = jnp.zeros((num_leaves,), jnp.int32).at[group_leaf].add(gc)
    leaf_rnnz = jnp.zeros((num_leaves,), jnp.int32).at[group_leaf].add(gz)
    return d[:n], r[:n], leaf_changed, leaf_rnnz


@partial(jax.jit, static_argnames=("num_leaves", "block_groups", "interpret"))
def flat_int8_encode(new_flat, base_flat, group_leaf, *, num_leaves: int,
                     block_groups: int = 64, interpret: bool = False):
    """Fused int8 encode of the packed mega-buffer: ONE pallas_call emits
    (q int8, per-1024-group f32 scales) plus per-group change counts,
    reduced to per-leaf via scatter-add — returns (q, scales,
    leaf_changed i32[num_leaves]).  Group alignment keeps every scale
    group within a single leaf, so the payload matches the per-leaf
    encoder's bit for bit."""
    new_flat, base_flat, group_leaf, n, block_groups = _flat_blocks(
        new_flat, base_flat, group_leaf, block_groups, interpret)
    q, s, gc = flat_delta_encode_fwd(new_flat, base_flat,
                                     block_groups=block_groups,
                                     interpret=interpret)
    leaf_changed = jnp.zeros((num_leaves,), jnp.int32).at[group_leaf].add(gc)
    return q[:n], s[:n // GROUP], leaf_changed
