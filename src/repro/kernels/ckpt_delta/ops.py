"""Public jit'd wrappers for the delta codec kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ckpt_delta.kernel import (delta_decode_fwd,
                                             delta_encode_fwd,
                                             lossless_decode_fwd,
                                             lossless_encode_fwd)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def delta_encode(new, base, *, block_groups: int = 8, interpret: bool = False):
    """(new - base) -> (int8 payload, per-1024-group fp32 scales)."""
    return delta_encode_fwd(new, base, block_groups=block_groups,
                            interpret=interpret)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def delta_decode(q, scales, *, block_groups: int = 8, interpret: bool = False):
    """Inverse of delta_encode (returns fp32 delta)."""
    return delta_decode_fwd(q, scales, block_groups=block_groups,
                            interpret=interpret)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def lossless_encode(new, base, *, block_groups: int = 8,
                    interpret: bool = False):
    """Fused lossless encode: (f32 delta, u32 XOR residual) vs base."""
    return lossless_encode_fwd(new, base, block_groups=block_groups,
                               interpret=interpret)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def lossless_decode(base, delta, resid, *, block_groups: int = 8,
                    interpret: bool = False):
    """Bit-exact inverse of lossless_encode (returns the original f32)."""
    return lossless_decode_fwd(base, delta, resid, block_groups=block_groups,
                               interpret=interpret)
