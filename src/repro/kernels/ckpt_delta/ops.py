"""Public jit'd wrappers for the delta codec kernel, plus the per-leaf
fused entry points the device-resident delta plane
(``checkpoint.pipeline.DeltaLeafSource``) dispatches in front of D2H:
encode + unchanged-leaf detection + residual-sparsity count in ONE jitted
call per leaf, so the snapshot path issues a single async dispatch per
encodable leaf and the host only ever pulls the encoded payload."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ckpt_delta.kernel import (delta_decode_fwd,
                                             delta_encode_fwd,
                                             lossless_decode_fwd,
                                             lossless_encode_fwd)


def default_interpret() -> bool:
    """Pallas interpret mode is required off-accelerator (the CPU backend
    has no Mosaic lowering); on TPU the compiled kernels run."""
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def delta_encode(new, base, *, block_groups: int = 8, interpret: bool = False):
    """(new - base) -> (int8 payload, per-1024-group fp32 scales)."""
    return delta_encode_fwd(new, base, block_groups=block_groups,
                            interpret=interpret)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def delta_decode(q, scales, *, block_groups: int = 8, interpret: bool = False):
    """Inverse of delta_encode (returns fp32 delta)."""
    return delta_decode_fwd(q, scales, block_groups=block_groups,
                            interpret=interpret)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def lossless_encode(new, base, *, block_groups: int = 8,
                    interpret: bool = False):
    """Fused lossless encode: (f32 delta, u32 XOR residual) vs base."""
    return lossless_encode_fwd(new, base, block_groups=block_groups,
                               interpret=interpret)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def lossless_decode(base, delta, resid, *, block_groups: int = 8,
                    interpret: bool = False):
    """Bit-exact inverse of lossless_encode (returns the original f32)."""
    return lossless_decode_fwd(base, delta, resid, block_groups=block_groups,
                               interpret=interpret)


# ---------------------------------------------------------------------------
# Per-leaf fused entry points for the device-resident delta plane
# ---------------------------------------------------------------------------

def _bits_changed(new_f32: jax.Array, base_f32: jax.Array) -> jax.Array:
    """True iff any f32 bit pattern differs — the device twin of the host
    path's raw-byte equality check that gates the manifest "zero" marker."""
    return jnp.any(jax.lax.bitcast_convert_type(new_f32, jnp.uint32)
                   != jax.lax.bitcast_convert_type(base_f32, jnp.uint32))


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def lossless_encode_leaf(new, base, *, block_groups: int = 8,
                         interpret: bool = False):
    """One leaf's on-device lossless encode: (delta f32, resid u32 — both
    GROUP-padded), plus ``changed`` (any bit differs -> leaf must be
    written) and ``resid_nnz`` (nonzero residual words).  The residual is
    almost always all-zero (base + delta rounds back exactly whenever
    new/base are within 2x of each other), so the caller skips its D2H
    when ``resid_nnz == 0`` and reconstructs zeros host-side — the blob on
    disk stays byte-identical to the host encoder's."""
    nf = new.reshape(-1).astype(jnp.float32)
    bf = base.reshape(-1).astype(jnp.float32)
    d, r = lossless_encode_fwd(nf, bf, block_groups=block_groups,
                               interpret=interpret)
    return d, r, _bits_changed(nf, bf), jnp.count_nonzero(r)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def int8_encode_leaf(new, base, *, block_groups: int = 8,
                     interpret: bool = False):
    """One leaf's on-device int8 group-quantized delta encode: (q int8
    GROUP-padded, per-group f32 scales, changed).  Worst-case error per
    element is half a quantization step: |err| <= max|delta_group| / 254
    (scale = amax/127, round-to-nearest) — the documented bound the
    round-trip test asserts."""
    nf = new.reshape(-1).astype(jnp.float32)
    bf = base.reshape(-1).astype(jnp.float32)
    q, s = delta_encode_fwd(nf, bf, block_groups=block_groups,
                            interpret=interpret)
    return q, s, _bits_changed(nf, bf)
