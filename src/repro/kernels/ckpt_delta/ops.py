"""Public jit'd wrappers for the delta codec kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ckpt_delta.kernel import delta_decode_fwd, delta_encode_fwd


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def delta_encode(new, base, *, block_groups: int = 8, interpret: bool = False):
    """(new - base) -> (int8 payload, per-1024-group fp32 scales)."""
    return delta_encode_fwd(new, base, block_groups=block_groups,
                            interpret=interpret)


@partial(jax.jit, static_argnames=("block_groups", "interpret"))
def delta_decode(q, scales, *, block_groups: int = 8, interpret: bool = False):
    """Inverse of delta_encode (returns fp32 delta)."""
    return delta_decode_fwd(q, scales, block_groups=block_groups,
                            interpret=interpret)
