from repro.kernels.ckpt_delta.ops import (delta_decode, delta_encode,
                                          flat_int8_encode,
                                          flat_lossless_encode, pack_flat)

__all__ = ["delta_encode", "delta_decode", "pack_flat",
           "flat_lossless_encode", "flat_int8_encode"]
