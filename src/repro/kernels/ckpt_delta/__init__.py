from repro.kernels.ckpt_delta.ops import delta_encode, delta_decode

__all__ = ["delta_encode", "delta_decode"]
