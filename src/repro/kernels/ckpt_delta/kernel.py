"""Incremental-checkpoint delta codec kernels (TPU Pallas).

Two fused on-device encoders, both running as part of the async snapshot
so less (or cheaper-to-compress) data crosses the device->host link:

  * int8 (lossy): delta = new - base, per-group symmetric int8
    quantization (group = 1024 elements) — an ~3.5x cut of checkpoint
    bytes *before* host-side zstd (the level-1 codec in the multi-level
    scheme, and the same payload format the cross-pod gradient compressor
    uses).

  * lossless sub+XOR-residual: delta = new - base (fp32) plus the XOR of
    the true and predicted bit patterns (bitcast to uint32).  The
    subtraction makes slowly-drifting tensors compress hard and the
    residual makes restore BIT-exact where float rounding perturbs
    base + delta; fusing both on device removes the float math + byte-XOR
    the host CPU used to do per leaf (``ref.py`` is the host oracle and
    the fallback ``checkpoint/incremental.py`` uses off-accelerator).

Both encoders come in two granularities:

  * per-leaf (``delta_encode_fwd``/``lossless_encode_fwd``): one
    pallas_call per f32 tensor — kept as the building block of the
    per-leaf host fallback path and the dispatch-overhead baseline that
    ``benchmarks/bench_ckpt.py`` records.

  * flat (``flat_delta_encode_fwd``/``flat_lossless_encode_fwd``): ONE
    pallas_call over the packed mega-buffer the whole f32 subtree of a
    train state is flattened into (``checkpoint.pipeline.FlatLayout``:
    each leaf starts at a GROUP-aligned offset, zero-padded to a whole
    number of groups, so every group holds elements of exactly one
    leaf).  Besides the payload, the flat kernels emit per-GROUP change
    statistics in the same pass — ``group_changed`` (count of elements
    whose f32 bit pattern differs from the base) and, for lossless,
    ``group_rnnz`` (nonzero residual words) — which ``ops.py`` reduces
    to per-LEAF counts with one scatter-add over the layout's
    group->leaf map.  That is how the skip-zero manifest markers and the
    residual-D2H skip survive the fusion of N kernel launches into one.

  new, base  (N,)        viewed as (N/G, G); block (bg, G)
  q          (N,) int8   block (bg, G)          [int8 encode]
  scale      (N/G,) f32  block (bg,)            [int8 encode]
  delta      (N,) f32    block (bg, G)          [lossless encode]
  resid      (N,) u32    block (bg, G)          [lossless encode]
  group_changed (N/G,) i32  block (bg,)         [flat encoders]
  group_rnnz    (N/G,) i32  block (bg,)         [flat lossless]

VMEM per step: 3-4 * bg * G fp32 (8 x 1024 -> 96-128 KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 1024


def _encode_kernel(new_ref, base_ref, q_ref, s_ref):
    d = new_ref[...].astype(jnp.float32) - base_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(d), axis=1)                    # (bg,)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(d / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _decode_kernel(q_ref, s_ref, d_ref):
    d_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...][:, None]


def _pad_to_groups(x: jax.Array) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % GROUP
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x, n


def delta_encode_fwd(new: jax.Array, base: jax.Array, *, block_groups: int = 8,
                     interpret: bool = False):
    new, n = _pad_to_groups(new.reshape(-1))
    base, _ = _pad_to_groups(base.reshape(-1))
    ng = new.shape[0] // GROUP
    bg = _grid_block(ng, block_groups)
    new2 = new.reshape(ng, GROUP)
    base2 = base.reshape(ng, GROUP)
    q, s = pl.pallas_call(
        _encode_kernel,
        grid=(ng // bg,),
        in_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                  pl.BlockSpec((bg, GROUP), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                   pl.BlockSpec((bg,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((ng, GROUP), jnp.int8),
                   jax.ShapeDtypeStruct((ng,), jnp.float32)],
        interpret=interpret,
    )(new2, base2)
    del n
    return q.reshape(-1), s   # padded to a multiple of GROUP; decode+slice


def _lossless_encode_kernel(new_ref, base_ref, d_ref, r_ref):
    new = new_ref[...]
    base = base_ref[...]
    d = new - base
    pred = base + d          # what decode will reconstruct, same rounding
    d_ref[...] = d
    r_ref[...] = (jax.lax.bitcast_convert_type(new, jnp.uint32)
                  ^ jax.lax.bitcast_convert_type(pred, jnp.uint32))


def _lossless_decode_kernel(base_ref, d_ref, r_ref, out_ref):
    pred = base_ref[...] + d_ref[...]
    bits = jax.lax.bitcast_convert_type(pred, jnp.uint32) ^ r_ref[...]
    out_ref[...] = jax.lax.bitcast_convert_type(bits, jnp.float32)


def _grid_block(ng: int, block_groups: int) -> int:
    bg = min(block_groups, ng)
    while ng % bg != 0:
        bg -= 1
    return bg


def lossless_encode_fwd(new: jax.Array, base: jax.Array, *,
                        block_groups: int = 8, interpret: bool = False):
    """Fused lossless encode: (f32 delta, u32 XOR residual), padded to a
    multiple of GROUP (zero padding encodes to zero delta + zero residual,
    so the padding compresses away)."""
    new, n = _pad_to_groups(new.reshape(-1).astype(jnp.float32))
    base, _ = _pad_to_groups(base.reshape(-1).astype(jnp.float32))
    ng = new.shape[0] // GROUP
    bg = _grid_block(ng, block_groups)
    d, r = pl.pallas_call(
        _lossless_encode_kernel,
        grid=(ng // bg,),
        in_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                  pl.BlockSpec((bg, GROUP), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                   pl.BlockSpec((bg, GROUP), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((ng, GROUP), jnp.float32),
                   jax.ShapeDtypeStruct((ng, GROUP), jnp.uint32)],
        interpret=interpret,
    )(new.reshape(ng, GROUP), base.reshape(ng, GROUP))
    del n
    return d.reshape(-1), r.reshape(-1)


def lossless_decode_fwd(base: jax.Array, delta: jax.Array, resid: jax.Array,
                        *, block_groups: int = 8,
                        interpret: bool = False) -> jax.Array:
    """Exact inverse of ``lossless_encode_fwd`` (returns the original f32
    bit patterns; caller slices to the unpadded leaf size)."""
    base, n = _pad_to_groups(base.reshape(-1).astype(jnp.float32))
    delta, _ = _pad_to_groups(delta.reshape(-1).astype(jnp.float32))
    resid, _ = _pad_to_groups(resid.reshape(-1).astype(jnp.uint32))
    ng = base.shape[0] // GROUP
    bg = _grid_block(ng, block_groups)
    out = pl.pallas_call(
        _lossless_decode_kernel,
        grid=(ng // bg,),
        in_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                  pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                  pl.BlockSpec((bg, GROUP), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ng, GROUP), jnp.float32),
        interpret=interpret,
    )(base.reshape(ng, GROUP), delta.reshape(ng, GROUP),
      resid.reshape(ng, GROUP))
    del n
    return out.reshape(-1)


# ---------------------------------------------------------------------------
# Flat (mega-buffer) encoders: one pallas_call over the packed f32 subtree,
# with per-group change statistics fused into the same pass
# ---------------------------------------------------------------------------

def _flat_lossless_encode_kernel(new_ref, base_ref, d_ref, r_ref,
                                 c_ref, n_ref):
    new = new_ref[...]
    base = base_ref[...]
    d = new - base
    pred = base + d          # what decode will reconstruct, same rounding
    r = (jax.lax.bitcast_convert_type(new, jnp.uint32)
         ^ jax.lax.bitcast_convert_type(pred, jnp.uint32))
    d_ref[...] = d
    r_ref[...] = r
    changed = (jax.lax.bitcast_convert_type(new, jnp.uint32)
               != jax.lax.bitcast_convert_type(base, jnp.uint32))
    c_ref[...] = jnp.sum(changed.astype(jnp.int32), axis=1)
    n_ref[...] = jnp.sum((r != 0).astype(jnp.int32), axis=1)


def flat_lossless_encode_fwd(new: jax.Array, base: jax.Array, *,
                             block_groups: int = 8, interpret: bool = False):
    """One fused pass over the packed flat buffer (length a multiple of
    GROUP — ``pipeline.FlatLayout`` guarantees the alignment): returns
    (delta f32, resid u32, group_changed i32, group_rnnz i32)."""
    n = new.reshape(-1).shape[0]
    assert n % GROUP == 0, f"flat buffer length {n} not GROUP-aligned"
    ng = n // GROUP
    bg = _grid_block(ng, block_groups)
    d, r, c, z = pl.pallas_call(
        _flat_lossless_encode_kernel,
        grid=(ng // bg,),
        in_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                  pl.BlockSpec((bg, GROUP), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                   pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                   pl.BlockSpec((bg,), lambda i: (i,)),
                   pl.BlockSpec((bg,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((ng, GROUP), jnp.float32),
                   jax.ShapeDtypeStruct((ng, GROUP), jnp.uint32),
                   jax.ShapeDtypeStruct((ng,), jnp.int32),
                   jax.ShapeDtypeStruct((ng,), jnp.int32)],
        interpret=interpret,
    )(new.reshape(ng, GROUP).astype(jnp.float32),
      base.reshape(ng, GROUP).astype(jnp.float32))
    return d.reshape(-1), r.reshape(-1), c, z


def _flat_encode_kernel(new_ref, base_ref, q_ref, s_ref, c_ref):
    new = new_ref[...].astype(jnp.float32)
    base = base_ref[...].astype(jnp.float32)
    d = new - base
    amax = jnp.max(jnp.abs(d), axis=1)                    # (bg,)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(d / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale
    changed = (jax.lax.bitcast_convert_type(new, jnp.uint32)
               != jax.lax.bitcast_convert_type(base, jnp.uint32))
    c_ref[...] = jnp.sum(changed.astype(jnp.int32), axis=1)


def flat_delta_encode_fwd(new: jax.Array, base: jax.Array, *,
                          block_groups: int = 8, interpret: bool = False):
    """One fused int8 pass over the packed flat buffer: returns
    (q int8, per-group f32 scales, group_changed i32).  Group alignment
    means every 1024-group quantizes elements of exactly one leaf, so the
    payload is numerically identical to the per-leaf encoder's."""
    n = new.reshape(-1).shape[0]
    assert n % GROUP == 0, f"flat buffer length {n} not GROUP-aligned"
    ng = n // GROUP
    bg = _grid_block(ng, block_groups)
    q, s, c = pl.pallas_call(
        _flat_encode_kernel,
        grid=(ng // bg,),
        in_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                  pl.BlockSpec((bg, GROUP), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                   pl.BlockSpec((bg,), lambda i: (i,)),
                   pl.BlockSpec((bg,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((ng, GROUP), jnp.int8),
                   jax.ShapeDtypeStruct((ng,), jnp.float32),
                   jax.ShapeDtypeStruct((ng,), jnp.int32)],
        interpret=interpret,
    )(new.reshape(ng, GROUP).astype(jnp.float32),
      base.reshape(ng, GROUP).astype(jnp.float32))
    return q.reshape(-1), s, c


def delta_decode_fwd(q: jax.Array, scales: jax.Array, *, block_groups: int = 8,
                     interpret: bool = False) -> jax.Array:
    qp, n = _pad_to_groups(q.reshape(-1))
    ng = qp.shape[0] // GROUP
    bg = _grid_block(ng, block_groups)
    d = pl.pallas_call(
        _decode_kernel,
        grid=(ng // bg,),
        in_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                  pl.BlockSpec((bg,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ng, GROUP), jnp.float32),
        interpret=interpret,
    )(qp.reshape(ng, GROUP), scales)
    del n
    return d.reshape(-1)   # padded length; caller slices to the leaf size
