"""Incremental-checkpoint delta codec kernel (TPU Pallas).

Fused on-device encode: delta = new - base, per-group symmetric int8
quantization (group = 1024 elements).  Runs as part of the async snapshot
so only int8 payload + fp32 scales cross the device->host link — an ~3.5x
cut of checkpoint bytes *before* host-side zstd (this is the level-1 codec
in the multi-level scheme, and the same payload format the cross-pod
gradient compressor uses).

  new, base  (N,)        viewed as (N/G, G); block (bg, G)
  q          (N,) int8   block (bg, G)
  scale      (N/G,) f32  block (bg,)

VMEM per step: 3 * bg * G fp32 (8 x 1024 -> 96 KB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 1024


def _encode_kernel(new_ref, base_ref, q_ref, s_ref):
    d = new_ref[...].astype(jnp.float32) - base_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(d), axis=1)                    # (bg,)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(d / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _decode_kernel(q_ref, s_ref, d_ref):
    d_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...][:, None]


def _pad_to_groups(x: jax.Array) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % GROUP
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x, n


def delta_encode_fwd(new: jax.Array, base: jax.Array, *, block_groups: int = 8,
                     interpret: bool = False):
    new, n = _pad_to_groups(new.reshape(-1))
    base, _ = _pad_to_groups(base.reshape(-1))
    ng = new.shape[0] // GROUP
    bg = min(block_groups, ng)
    while ng % bg != 0:
        bg -= 1
    new2 = new.reshape(ng, GROUP)
    base2 = base.reshape(ng, GROUP)
    q, s = pl.pallas_call(
        _encode_kernel,
        grid=(ng // bg,),
        in_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                  pl.BlockSpec((bg, GROUP), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                   pl.BlockSpec((bg,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((ng, GROUP), jnp.int8),
                   jax.ShapeDtypeStruct((ng,), jnp.float32)],
        interpret=interpret,
    )(new2, base2)
    del n
    return q.reshape(-1), s   # padded to a multiple of GROUP; decode+slice


def delta_decode_fwd(q: jax.Array, scales: jax.Array, *, block_groups: int = 8,
                     interpret: bool = False) -> jax.Array:
    qp, n = _pad_to_groups(q.reshape(-1))
    ng = qp.shape[0] // GROUP
    bg = min(block_groups, ng)
    while ng % bg != 0:
        bg -= 1
    d = pl.pallas_call(
        _decode_kernel,
        grid=(ng // bg,),
        in_specs=[pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
                  pl.BlockSpec((bg,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bg, GROUP), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ng, GROUP), jnp.float32),
        interpret=interpret,
    )(qp.reshape(ng, GROUP), scales)
    del n
    return d.reshape(-1)   # padded length; caller slices to the leaf size
