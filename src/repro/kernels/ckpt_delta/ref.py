"""NumPy oracle for the delta codec (also the host-side fallback used by
checkpoint/incremental.py in int8 mode)."""
from __future__ import annotations

import numpy as np

GROUP = 1024


def encode_ref(delta: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    d = np.asarray(delta, np.float32).reshape(-1)
    n = d.size
    pad = (-n) % GROUP
    if pad:
        d = np.concatenate([d, np.zeros(pad, np.float32)])
    d = d.reshape(-1, GROUP)
    scale = np.maximum(np.abs(d).max(axis=1), 1e-12) / 127.0
    q = np.clip(np.round(d / scale[:, None]), -127, 127).astype(np.int8)
    del n
    return q.reshape(-1), scale.astype(np.float32)   # padded payload


def decode_ref(q: np.ndarray, scales: np.ndarray) -> np.ndarray:
    q = np.asarray(q, np.int8).reshape(-1, GROUP)
    return (q.astype(np.float32) * np.asarray(scales, np.float32)[:, None]).reshape(-1)
