"""NumPy oracle for the delta codec (also the host-side fallback used by
checkpoint/incremental.py in int8 mode)."""
from __future__ import annotations

import numpy as np

GROUP = 1024


def encode_ref(delta: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    d = np.asarray(delta, np.float32).reshape(-1)
    n = d.size
    pad = (-n) % GROUP
    if pad:
        d = np.concatenate([d, np.zeros(pad, np.float32)])
    d = d.reshape(-1, GROUP)
    scale = np.maximum(np.abs(d).max(axis=1), 1e-12) / 127.0
    q = np.clip(np.round(d / scale[:, None]), -127, 127).astype(np.int8)
    del n
    return q.reshape(-1), scale.astype(np.float32)   # padded payload


def decode_ref(q: np.ndarray, scales: np.ndarray) -> np.ndarray:
    q = np.asarray(q, np.int8).reshape(-1, GROUP)
    return (q.astype(np.float32) * np.asarray(scales, np.float32)[:, None]).reshape(-1)


def lossless_encode_ref(new: np.ndarray, base: np.ndarray
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Host oracle for the fused lossless sub+XOR-residual encode (f32).

    Returns (delta f32, residual u32): delta = new - base, residual =
    bits(new) ^ bits(base + delta) — exactly what the Pallas kernel emits,
    and the vectorized host path ``checkpoint/incremental.py`` writes when
    the state is already off-accelerator.  The u32 residual's little-endian
    bytes equal the legacy per-byte u8 XOR, so on-disk blobs stay
    compatible in both directions.
    """
    new = np.ascontiguousarray(new, np.float32).reshape(-1)
    base = np.ascontiguousarray(base, np.float32).reshape(-1)
    delta = new - base
    pred = base + delta
    resid = new.view(np.uint32) ^ pred.view(np.uint32)
    return delta, resid


def lossless_decode_ref(base: np.ndarray, delta: np.ndarray,
                        resid: np.ndarray) -> np.ndarray:
    """Bit-exact inverse of ``lossless_encode_ref`` (returns f32)."""
    base = np.ascontiguousarray(base, np.float32).reshape(-1)
    pred = base + np.ascontiguousarray(delta, np.float32).reshape(-1)
    bits = pred.view(np.uint32) ^ np.ascontiguousarray(
        resid, np.uint32).reshape(-1)
    return bits.view(np.float32)


# ---------------------------------------------------------------------------
# Flat (mega-buffer) oracles: pack + encode with per-leaf change stats,
# mirroring kernels.ckpt_delta.ops.pack_flat / flat_*_encode
# ---------------------------------------------------------------------------

def pack_flat_ref(leaves) -> np.ndarray:
    """Host twin of ``ops.pack_flat``: concatenate f32 leaves, each
    zero-padded to a whole number of GROUPs (GROUP-aligned offsets)."""
    parts = []
    for leaf in leaves:
        v = np.ascontiguousarray(leaf, np.float32).reshape(-1)
        pad = (-v.size) % GROUP
        if pad:
            v = np.concatenate([v, np.zeros(pad, np.float32)])
        parts.append(v)
    return np.concatenate(parts) if parts else np.zeros(0, np.float32)


def _leaf_reduce(per_group: np.ndarray, group_leaf: np.ndarray,
                 num_leaves: int) -> np.ndarray:
    out = np.zeros(num_leaves, np.int64)
    np.add.at(out, np.asarray(group_leaf, np.int64), per_group)
    return out


def flat_lossless_encode_ref(new_flat: np.ndarray, base_flat: np.ndarray,
                             group_leaf: np.ndarray, num_leaves: int):
    """Oracle of ``ops.flat_lossless_encode``: (delta f32, resid u32,
    leaf_changed, leaf_rnnz) over the packed GROUP-aligned buffer."""
    new = np.ascontiguousarray(new_flat, np.float32).reshape(-1)
    base = np.ascontiguousarray(base_flat, np.float32).reshape(-1)
    assert new.size % GROUP == 0, new.size
    delta, resid = lossless_encode_ref(new, base)
    changed = (new.view(np.uint32) != base.view(np.uint32))
    gc = changed.reshape(-1, GROUP).sum(axis=1)
    gz = (resid.reshape(-1, GROUP) != 0).sum(axis=1)
    return (delta, resid, _leaf_reduce(gc, group_leaf, num_leaves),
            _leaf_reduce(gz, group_leaf, num_leaves))


def flat_int8_encode_ref(new_flat: np.ndarray, base_flat: np.ndarray,
                         group_leaf: np.ndarray, num_leaves: int):
    """Oracle of ``ops.flat_int8_encode``: (q int8, per-group f32 scales,
    leaf_changed) over the packed GROUP-aligned buffer."""
    new = np.ascontiguousarray(new_flat, np.float32).reshape(-1)
    base = np.ascontiguousarray(base_flat, np.float32).reshape(-1)
    assert new.size % GROUP == 0, new.size
    q, scales = encode_ref(new - base)
    changed = (new.view(np.uint32) != base.view(np.uint32))
    gc = changed.reshape(-1, GROUP).sum(axis=1)
    return q, scales, _leaf_reduce(gc, group_leaf, num_leaves)
