"""NumPy oracle for the delta codec (also the host-side fallback used by
checkpoint/incremental.py in int8 mode)."""
from __future__ import annotations

import numpy as np

GROUP = 1024


def encode_ref(delta: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    d = np.asarray(delta, np.float32).reshape(-1)
    n = d.size
    pad = (-n) % GROUP
    if pad:
        d = np.concatenate([d, np.zeros(pad, np.float32)])
    d = d.reshape(-1, GROUP)
    scale = np.maximum(np.abs(d).max(axis=1), 1e-12) / 127.0
    q = np.clip(np.round(d / scale[:, None]), -127, 127).astype(np.int8)
    del n
    return q.reshape(-1), scale.astype(np.float32)   # padded payload


def decode_ref(q: np.ndarray, scales: np.ndarray) -> np.ndarray:
    q = np.asarray(q, np.int8).reshape(-1, GROUP)
    return (q.astype(np.float32) * np.asarray(scales, np.float32)[:, None]).reshape(-1)


def lossless_encode_ref(new: np.ndarray, base: np.ndarray
                        ) -> tuple[np.ndarray, np.ndarray]:
    """Host oracle for the fused lossless sub+XOR-residual encode (f32).

    Returns (delta f32, residual u32): delta = new - base, residual =
    bits(new) ^ bits(base + delta) — exactly what the Pallas kernel emits,
    and the vectorized host path ``checkpoint/incremental.py`` writes when
    the state is already off-accelerator.  The u32 residual's little-endian
    bytes equal the legacy per-byte u8 XOR, so on-disk blobs stay
    compatible in both directions.
    """
    new = np.ascontiguousarray(new, np.float32).reshape(-1)
    base = np.ascontiguousarray(base, np.float32).reshape(-1)
    delta = new - base
    pred = base + delta
    resid = new.view(np.uint32) ^ pred.view(np.uint32)
    return delta, resid


def lossless_decode_ref(base: np.ndarray, delta: np.ndarray,
                        resid: np.ndarray) -> np.ndarray:
    """Bit-exact inverse of ``lossless_encode_ref`` (returns f32)."""
    base = np.ascontiguousarray(base, np.float32).reshape(-1)
    pred = base + np.ascontiguousarray(delta, np.float32).reshape(-1)
    bits = pred.view(np.uint32) ^ np.ascontiguousarray(
        resid, np.uint32).reshape(-1)
    return bits.view(np.float32)
