"""In-memory time-series store — the framework's "Prometheus".

The Khaos controller, the anomaly detector and the simulator all read and
write through this interface, so the same controller code runs against the
discrete-event simulator and the live trainer.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np


@dataclass
class TimeSeries:
    name: str
    times: list = field(default_factory=list)
    values: list = field(default_factory=list)

    def append(self, t: float, v: float) -> None:
        if self.times and t < self.times[-1]:
            raise ValueError(f"non-monotonic append to {self.name}: {t} < {self.times[-1]}")
        self.times.append(float(t))
        self.values.append(float(v))

    def __len__(self) -> int:
        return len(self.times)

    # -- queries -----------------------------------------------------------
    def window(self, t_start: float, t_end: float) -> tuple[np.ndarray, np.ndarray]:
        lo = bisect.bisect_left(self.times, t_start)
        hi = bisect.bisect_right(self.times, t_end)
        return np.asarray(self.times[lo:hi]), np.asarray(self.values[lo:hi])

    def last(self, n: int = 1) -> np.ndarray:
        return np.asarray(self.values[-n:])

    def latest(self, default: float = float("nan")) -> float:
        return self.values[-1] if self.values else default

    def mean_over(self, t_start: float, t_end: float, default: float = float("nan")) -> float:
        _, v = self.window(t_start, t_end)
        return float(v.mean()) if v.size else default

    def percentile_over(self, t_start: float, t_end: float, q: float,
                        default: float = float("nan")) -> float:
        _, v = self.window(t_start, t_end)
        return float(np.percentile(v, q)) if v.size else default

    def smoothed(self, window: int) -> np.ndarray:
        """Centered moving average (the paper's 'averaging window' over W(t))."""
        v = np.asarray(self.values, dtype=np.float64)
        if v.size == 0 or window <= 1:
            return v
        kernel = np.ones(window) / window
        pad = window // 2
        vp = np.pad(v, (pad, window - 1 - pad), mode="edge")
        return np.convolve(vp, kernel, mode="valid")


class MetricsStore:
    """Named time series with lazy creation."""

    def __init__(self) -> None:
        self._series: dict[str, TimeSeries] = {}

    def series(self, name: str) -> TimeSeries:
        if name not in self._series:
            self._series[name] = TimeSeries(name)
        return self._series[name]

    def record(self, name: str, t: float, v: float) -> None:
        self.series(name).append(t, v)

    def names(self) -> Iterable[str]:
        return self._series.keys()

    def __contains__(self, name: str) -> bool:
        return name in self._series
