"""In-memory time-series store — the framework's "Prometheus".

The Khaos controller, the anomaly detector and the simulator all read and
write through this interface, so the same controller code runs against the
discrete-event simulator and the live trainer.

Two retention modes:

* unbounded (the default, ``maxlen=None``) — every sample is kept, exactly
  the pre-fleet behavior; the windowed queries below are exact over the
  whole history.
* bounded (``maxlen=N``) — the fleet-plane mode: only the most recent N
  samples are held raw.  When the buffer overflows, the OLDEST half is
  evicted into one ``Rollup`` bucket (count/mean/min/max over the evicted
  span), and the rollup list itself is bounded (``max_rollups``) by
  merging adjacent buckets — halving historical resolution instead of
  growing — so memory stays flat no matter how long a campaign runs.
  Windowed queries (the controller's trailing-window reads) see the raw
  recent samples; lifetime aggregates (``lifetime_count``/
  ``lifetime_mean``) fold the rollups back in.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np


@dataclass
class Rollup:
    """Aggregate of an evicted sample span [t_start, t_end]."""
    t_start: float
    t_end: float
    count: int
    mean: float
    vmin: float
    vmax: float

    def merge(self, other: "Rollup") -> "Rollup":
        n = self.count + other.count
        return Rollup(min(self.t_start, other.t_start),
                      max(self.t_end, other.t_end), n,
                      (self.mean * self.count + other.mean * other.count) / n,
                      min(self.vmin, other.vmin),
                      max(self.vmax, other.vmax))


@dataclass
class TimeSeries:
    name: str
    times: list = field(default_factory=list)
    values: list = field(default_factory=list)
    maxlen: Optional[int] = None       # None = unbounded (exact history)
    max_rollups: int = 256             # bounded mode: history bucket cap
    rollups: list = field(default_factory=list)

    def append(self, t: float, v: float) -> None:
        if self.times and t < self.times[-1]:
            raise ValueError(f"non-monotonic append to {self.name}: {t} < {self.times[-1]}")
        self.times.append(float(t))
        self.values.append(float(v))
        if self.maxlen is not None and len(self.times) > self.maxlen:
            self._evict()

    def _evict(self) -> None:
        """Roll the oldest half of the raw buffer into one bucket."""
        k = max(1, len(self.times) // 2)
        ev_t, ev_v = self.times[:k], np.asarray(self.values[:k])
        self.rollups.append(Rollup(ev_t[0], ev_t[-1], k, float(ev_v.mean()),
                                   float(ev_v.min()), float(ev_v.max())))
        del self.times[:k]
        del self.values[:k]
        if len(self.rollups) > self.max_rollups:
            # halve historical resolution instead of growing
            self.rollups = [a.merge(b) for a, b in
                            zip(self.rollups[::2], self.rollups[1::2])] + \
                           (self.rollups[-1:] if len(self.rollups) % 2 else [])

    def __len__(self) -> int:
        return len(self.times)

    # -- lifetime aggregates (rollups + live samples) ------------------------
    def lifetime_count(self) -> int:
        return len(self.times) + sum(r.count for r in self.rollups)

    def lifetime_mean(self, default: float = float("nan")) -> float:
        n = self.lifetime_count()
        if n == 0:
            return default
        s = float(np.sum(self.values)) + sum(r.mean * r.count
                                             for r in self.rollups)
        return s / n

    def lifetime_max(self, default: float = float("nan")) -> float:
        cands = ([max(self.values)] if self.values else []) + \
                [r.vmax for r in self.rollups]
        return max(cands) if cands else default

    # -- queries -----------------------------------------------------------
    def window(self, t_start: float, t_end: float) -> tuple[np.ndarray, np.ndarray]:
        lo = bisect.bisect_left(self.times, t_start)
        hi = bisect.bisect_right(self.times, t_end)
        return np.asarray(self.times[lo:hi]), np.asarray(self.values[lo:hi])

    def last(self, n: int = 1) -> np.ndarray:
        return np.asarray(self.values[-n:])

    def latest(self, default: float = float("nan")) -> float:
        return self.values[-1] if self.values else default

    def mean_over(self, t_start: float, t_end: float, default: float = float("nan")) -> float:
        _, v = self.window(t_start, t_end)
        return float(v.mean()) if v.size else default

    def percentile_over(self, t_start: float, t_end: float, q: float,
                        default: float = float("nan")) -> float:
        _, v = self.window(t_start, t_end)
        return float(np.percentile(v, q)) if v.size else default

    def smoothed(self, window: int) -> np.ndarray:
        """Centered moving average (the paper's 'averaging window' over W(t))."""
        v = np.asarray(self.values, dtype=np.float64)
        if v.size == 0 or window <= 1:
            return v
        kernel = np.ones(window) / window
        pad = window // 2
        vp = np.pad(v, (pad, window - 1 - pad), mode="edge")
        return np.convolve(vp, kernel, mode="valid")


class MetricsStore:
    """Named time series with lazy creation.

    ``maxlen`` selects the bounded/windowed retention mode for every series
    created through this store (None = unbounded, the default) — the fleet
    metrics plane runs bounded so supervising many jobs under heavy traffic
    holds memory flat.
    """

    def __init__(self, maxlen: Optional[int] = None,
                 max_rollups: int = 256) -> None:
        self._series: dict[str, TimeSeries] = {}
        self.maxlen = maxlen
        self.max_rollups = max_rollups

    def series(self, name: str) -> TimeSeries:
        if name not in self._series:
            self._series[name] = TimeSeries(name, maxlen=self.maxlen,
                                            max_rollups=self.max_rollups)
        return self._series[name]

    def record(self, name: str, t: float, v: float) -> None:
        self.series(name).append(t, v)

    def names(self) -> Iterable[str]:
        return self._series.keys()

    def __contains__(self, name: str) -> bool:
        return name in self._series
