from repro.metrics.timeseries import TimeSeries, MetricsStore

__all__ = ["TimeSeries", "MetricsStore"]
