from repro.metrics.timeseries import MetricsStore, Rollup, TimeSeries

__all__ = ["MetricsStore", "Rollup", "TimeSeries"]
