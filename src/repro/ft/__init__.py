from repro.ft.failures import FailureModel, FailureInjector, InjectedFailure
from repro.ft.detector import HeartbeatDetector
from repro.ft.elastic import plan_rescale, RescalePlan
from repro.ft.straggler import StragglerDetector

__all__ = [
    "FailureModel", "FailureInjector", "InjectedFailure",
    "HeartbeatDetector", "plan_rescale", "RescalePlan", "StragglerDetector",
]
