from repro.ft.failures import FailureModel, FailureInjector, InjectedFailure
from repro.ft.detector import HeartbeatDetector
from repro.ft.elastic import (plan_recovery, plan_rescale, RecoveryPlan,
                              RescalePlan)
from repro.ft.straggler import StragglerDetector

__all__ = [
    "FailureModel", "FailureInjector", "InjectedFailure",
    "HeartbeatDetector", "plan_recovery", "plan_rescale", "RecoveryPlan",
    "RescalePlan", "StragglerDetector",
]
