from repro.ft.failures import (CRASH_KINDS, DEGRADATION_KINDS, DIRECTIONS,
                               KINDS, Degradation, FailureModel,
                               FailureInjector, InjectedFailure, jitter_phase)
from repro.ft.detector import HeartbeatDetector
from repro.ft.elastic import (plan_recovery, plan_rescale, RecoveryPlan,
                              RescalePlan)
from repro.ft.straggler import StragglerDetector

__all__ = [
    "CRASH_KINDS", "DEGRADATION_KINDS", "DIRECTIONS", "KINDS",
    "Degradation", "FailureModel", "FailureInjector", "InjectedFailure",
    "jitter_phase", "HeartbeatDetector", "plan_recovery", "plan_rescale",
    "RecoveryPlan", "RescalePlan", "StragglerDetector",
]
