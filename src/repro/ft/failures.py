"""Failure taxonomy and injection (the chaos in Khaos).

The vocabulary splits into two families with different semantics:

**Crashes** (``CRASH_KINDS`` — task/node/cluster) kill the job: detect →
restart → restore from the newest surviving checkpoint level → offset
rollback → catch-up.  What survives is placement- and replication-derived
(a node crash takes its local disk with it unless the level is peer-
replicated), so the *kind* decides the restore path and its price.

**Degradations** (``DEGRADATION_KINDS``) are gray failures: the job stays
up but its dynamics bend — real DSP deployments degrade before they die.

* ``net_delay`` — mean network delay + jitter, DIRECTIONAL: injected
  ``to_source`` it sits on the source→job path and inflates end-to-end
  latency; injected ``to_ckpt_store`` it sits under the checkpoint
  barrier and stretches every trigger's write duration (longer sync
  pauses, staler completed offsets).
* ``straggler`` — one host's step time inflated by a factor for a window;
  under a synchronous barrier the slowest host gates everyone, so
  effective capacity drops by the cost model's barrier fraction.
* ``backpressure`` — checkpoint barriers/triggers are delayed past their
  cadence slot (a backpressured source cannot propagate the barrier), so
  the checkpoint is taken too late and the NEXT crash replays extra work.

Both families share one closed ``KINDS`` set: ``FailureModel`` and the
injectors validate against it and raise on unknowns (mirroring
``core.controller.Decision.KINDS``) instead of accepting any string.

* ``FailureModel`` samples failures from exponential (Poisson process)
  or Weibull (infant-mortality / wear-out) inter-arrival distributions —
  feeds both the simulator's background failures and MTBF estimates for
  the Young/Daly baseline.
* ``FailureInjector`` implements the paper's worst-case injection: given
  the checkpoint schedule, a requested injection time is snapped to just
  before the *next checkpoint completes* (maximizing lost work, §III-C).
* ``Degradation`` is the injectable gray-failure event, consumed by the
  scalar simulator (``inject_degradation``), by campaign lanes
  (``LaneSpec.degradations``), and by the live trainer
  (``ResilientTrainer.inject_degradation_at``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

#: crashes: the job dies and restores from a checkpoint
CRASH_KINDS = ("task", "node", "cluster")
#: gray failures: the job stays up but its dynamics degrade
DEGRADATION_KINDS = ("net_delay", "straggler", "backpressure")
#: the closed failure vocabulary (validated everywhere, like Decision.KINDS)
KINDS = CRASH_KINDS + DEGRADATION_KINDS

#: directional injection targets for ``net_delay``
DIRECTIONS = ("to_source", "to_ckpt_store")


def jitter_phase(t, t0):
    """Deterministic ±1 jitter phase: alternates each second of the
    degradation window.  Elementwise on arrays and exact on scalars, so
    the scalar simulator and the batched lanes price the same jittered
    delay bit-for-bit (no RNG in the tick loop)."""
    return np.where((t - t0) % 2.0 < 1.0, 1.0, -1.0)


@dataclass
class Degradation:
    """One gray-failure window, starting at ``t`` for ``duration_s``.

    ``severity`` is kind-specific: mean delay seconds (``net_delay``) or
    the step-time inflation factor (``straggler``); ``backpressure`` only
    needs the window (triggers are suppressed for its whole span).
    ``direction`` applies to ``net_delay`` only; ``host`` optionally pins
    a straggler to a concrete host for detector-facing drills.
    """
    t: float
    kind: str
    duration_s: float
    severity: float = 0.0
    jitter_s: float = 0.0
    direction: str = "to_source"
    host: Optional[int] = None

    def __post_init__(self) -> None:
        if self.kind not in DEGRADATION_KINDS:
            raise ValueError(f"unknown degradation kind {self.kind!r}; "
                             f"expected one of {DEGRADATION_KINDS}")
        if self.direction not in DIRECTIONS:
            raise ValueError(f"unknown direction {self.direction!r}; "
                             f"expected one of {DIRECTIONS}")
        if self.duration_s <= 0:
            raise ValueError("degradation window must have duration_s > 0")


class InjectedFailure(RuntimeError):
    """Raised inside the live trainer loop to simulate a host crash.
    ``host=None`` is an untargeted process loss (the node's disk
    survives); a concrete host number kills that host's node-local
    checkpoint files with it (placement-aware injection)."""

    def __init__(self, kind: str = "node", host: Optional[int] = None,
                 t: float = 0.0):
        if kind not in CRASH_KINDS:
            raise ValueError(f"unknown crash kind {kind!r}; expected one of "
                             f"{CRASH_KINDS} (degradations are Degradation "
                             f"windows, not raised failures)")
        where = "" if host is None else f" on host {host}"
        super().__init__(f"injected {kind} failure{where} at t={t:.1f}")
        self.kind = kind
        self.host = host
        self.t = t


@dataclass
class FailureModel:
    mtbf_node_s: float = 86_400.0      # per-node MTBF
    num_nodes: int = 64
    distribution: str = "exponential"  # exponential | weibull
    weibull_shape: float = 0.7         # <1: infant mortality
    seed: int = 0
    kinds: tuple = (("task", 0.3), ("node", 0.65), ("cluster", 0.05))

    def __post_init__(self) -> None:
        for kind, _w in self.kinds:
            if kind not in KINDS:
                raise ValueError(f"unknown failure kind {kind!r}; expected "
                                 f"one of {KINDS}")
        self._rng = np.random.default_rng(self.seed)

    @property
    def cluster_mtbf_s(self) -> float:
        return self.mtbf_node_s / max(1, self.num_nodes)

    def next_failure_after(self, t: float) -> float:
        scale = self.cluster_mtbf_s
        if self.distribution == "exponential":
            dt = self._rng.exponential(scale)
        else:
            k = self.weibull_shape
            lam = scale / math.gamma(1 + 1 / k)   # mean matches the MTBF
            dt = lam * self._rng.weibull(k)
        return t + float(max(dt, 1.0))

    def sample_kind(self) -> str:
        kinds, probs = zip(*self.kinds)
        return str(self._rng.choice(kinds, p=probs))

    def sample_host(self) -> int:
        return int(self._rng.integers(self.num_nodes))


@dataclass
class FailureInjector:
    """Deterministic injection scheduler for profiling and baselines.

    Beyond the paper's worst-case *timing* (§III-C), the injector is
    placement-aware: ``worst_case_failure`` targets a specific HOST (so
    the checkpoint plane's host->shard placement decides exactly which
    files die), and ``peer_loss`` composes the worst case for k=1
    replication — the host AND one of its ring replica peers inside the
    same window, leaving some shard with no surviving local copy."""
    epsilon_s: float = 1.0
    log: list = field(default_factory=list)

    def worst_case_time(self, requested_t: float, last_ckpt_t: float,
                        interval_s: float, ckpt_cost_s: float) -> float:
        """Paper §III-C: inject just before the next checkpoint *completes*.

        The next checkpoint after ``requested_t`` starts at the next
        multiple of the interval and completes ``ckpt_cost_s`` later; we
        inject epsilon before that completion so the job replays a full
        interval's worth of work.
        """
        if interval_s <= 0:
            return requested_t
        k = np.ceil(max(requested_t - last_ckpt_t, 0.0) / interval_s)
        next_start = last_ckpt_t + k * interval_s
        if next_start < requested_t:
            next_start += interval_s
        completion = next_start + ckpt_cost_s
        t = max(requested_t, completion - self.epsilon_s)
        self.log.append({"requested": requested_t, "injected": t})
        return float(t)

    def worst_case_failure(self, requested_t: float, last_ckpt_t: float,
                           interval_s: float, ckpt_cost_s: float,
                           kind: str = "node", host: int = 0
                           ) -> InjectedFailure:
        """Host-targeted worst-case injection: the §III-C timing plus a
        placement — ``host``'s node-local files (its primary shards and
        the replicas it held) die with it, so the restore that follows
        exercises the degraded-partial path, not a free local read."""
        if kind not in CRASH_KINDS:
            raise ValueError(f"unknown crash kind {kind!r}; expected one of "
                             f"{CRASH_KINDS}")
        t = self.worst_case_time(requested_t, last_ckpt_t, interval_s,
                                 ckpt_cost_s)
        self.log[-1].update({"kind": kind, "host": host})
        return InjectedFailure(kind=kind, host=host, t=t)

    def peer_loss(self, requested_t: float, last_ckpt_t: float,
                  interval_s: float, ckpt_cost_s: float, host: int,
                  num_hosts: int, replication_factor: int = 1,
                  window_s: float = 5.0) -> list[InjectedFailure]:
        """The k=1 worst case: kill ``host`` at the worst-case time AND
        its first ring replica peer (the host holding ``host``'s shard
        copies) ``window_s`` later — inside the window no new checkpoint
        can complete, so the dead host's shards lose every local copy
        and recovery must fall back per-shard to the remote level.
        Returns the two failures in injection order."""
        from repro.checkpoint.replication import ring_peers

        first = self.worst_case_failure(requested_t, last_ckpt_t,
                                        interval_s, ckpt_cost_s,
                                        kind="node", host=host)
        peers = ring_peers(host, num_hosts, max(1, replication_factor))
        if not peers:
            return [first]
        window_s = min(window_s, max(interval_s - 2 * self.epsilon_s,
                                     self.epsilon_s))
        second = InjectedFailure(kind="node", host=peers[0],
                                 t=first.t + window_s)
        self.log.append({"requested": first.t, "injected": second.t,
                         "kind": "node", "host": peers[0],
                         "scenario": "peer_loss"})
        return [first, second]
