"""Heartbeat-based failure detection (the paper's 50s Flink taskmanager
timeout maps to ``timeout_s``)."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class HeartbeatDetector:
    num_hosts: int
    timeout_s: float = 50.0
    _last: dict = field(default_factory=dict)

    def heartbeat(self, host: int, t: float) -> None:
        self._last[host] = t

    def heartbeat_all(self, t: float) -> None:
        for h in range(self.num_hosts):
            self._last[h] = t

    def failed_hosts(self, t: float) -> list[int]:
        return [h for h in range(self.num_hosts)
                if t - self._last.get(h, -1e18) > self.timeout_s]

    def healthy(self, t: float) -> bool:
        return not self.failed_hosts(t)

    def detection_delay(self) -> float:
        """Expected detection latency for a crash (uniform in [0, timeout])
        plus the timeout itself — used by the simulator's recovery model."""
        return self.timeout_s
