"""Elastic rescaling: derive a runnable mesh from the surviving hosts and
restore the latest checkpoint onto it.

The checkpoint store's manifest-driven restore is shard-count agnostic
(checkpoint/store.py), so a rescale is: plan new mesh -> restore -> resume
from the checkpointed stream cursor.  The planner keeps the TP degree
(model-parallel sharding must divide weight dims) and shrinks the data
axis to the largest value that fits — spare hosts become hot standbys.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.config import MeshConfig


@dataclass
class RescalePlan:
    old: MeshConfig
    new: MeshConfig
    hosts_alive: int
    hosts_used: int
    standby: int
    batch_ok: bool         # global batch still divisible by the new dp

    @property
    def changed(self) -> bool:
        return self.new.shape != self.old.shape


def plan_rescale(mesh: MeshConfig, hosts_alive: int, chips_per_host: int = 4,
                 global_batch: Optional[int] = None) -> RescalePlan:
    """Largest (data' x model) mesh that fits the surviving chips.

    TP ('model') is pinned: resharding TP requires repartitioning every
    weight, while shrinking 'data' only re-spreads the batch and FSDP
    shards — exactly what manifest-driven restore gives us for free.
    """
    chips = hosts_alive * chips_per_host
    model = mesh.model
    pods = mesh.pods if mesh.multi_pod else 1
    if chips < model:
        raise ValueError(f"cannot keep TP={model} with only {chips} chips")
    # keep multi-pod only if both pods can stay symmetric
    new_multi = mesh.multi_pod and chips >= 2 * model
    per_pod_chips = chips // (2 if new_multi else 1)
    new_data = max(1, per_pod_chips // model)
    # data axis must divide the global batch for clean batch sharding
    if global_batch:
        dp_total = new_data * (2 if new_multi else 1)
        while new_data > 1 and global_batch % dp_total != 0:
            new_data -= 1
            dp_total = new_data * (2 if new_multi else 1)
    new = MeshConfig(multi_pod=new_multi, data=new_data, model=model,
                     pods=2 if new_multi else mesh.pods)
    used_chips = new.num_devices
    batch_ok = (global_batch is None) or (
        global_batch % (new_data * (2 if new_multi else 1)) == 0)
    return RescalePlan(
        old=mesh, new=new, hosts_alive=hosts_alive,
        hosts_used=-(-used_chips // chips_per_host),
        standby=hosts_alive - (-(-used_chips // chips_per_host)),
        batch_ok=batch_ok)


@dataclass
class RecoveryPlan:
    """How a node failure lands: replace the dead hosts from hot standbys
    (mesh unchanged) when any remain, otherwise rescale DOWN onto the
    survivors.  ``rescale`` is None on the standby path."""
    mesh: MeshConfig
    hosts_lost: int
    standbys_used: int
    standbys_left: int
    rescale: Optional[RescalePlan] = None

    @property
    def rescaled(self) -> bool:
        return self.rescale is not None and self.rescale.changed


def plan_recovery(mesh: MeshConfig, hosts_lost: int, standbys: int,
                  chips_per_host: int = 4,
                  global_batch: Optional[int] = None) -> RecoveryPlan:
    """Compose failure recovery with elasticity: the degraded partial
    restore (checkpoint/replication.py) rebuilds the dead hosts' shards,
    and THIS decides which mesh receives them.  While hot standbys cover
    the losses the mesh shape is untouched (restore is a same-shape shard
    rebuild); once standbys are exhausted, recovery lands on the smaller
    mesh ``plan_rescale`` derives from the true survivor count — the
    manifest-driven restore reshards onto it for free."""
    if hosts_lost < 0:
        raise ValueError(f"hosts_lost must be >= 0, got {hosts_lost}")
    if hosts_lost <= standbys:
        return RecoveryPlan(mesh=mesh, hosts_lost=hosts_lost,
                            standbys_used=hosts_lost,
                            standbys_left=standbys - hosts_lost)
    in_mesh = -(-mesh.num_devices // chips_per_host)
    alive = in_mesh + standbys - hosts_lost
    rs = plan_rescale(mesh, alive, chips_per_host, global_batch)
    return RecoveryPlan(mesh=rs.new, hosts_lost=hosts_lost,
                        standbys_used=standbys, standbys_left=0,
                        rescale=rs)
