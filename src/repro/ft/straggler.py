"""Straggler mitigation — reuses the paper's online-ARIMA anomaly detector
(core/anomaly.py) on per-host step times.

A host whose step-time stream turns anomalous for ``patience`` consecutive
observations is flagged; the runtime's mitigation ladder is
(1) re-balance input shards away from it, (2) evict + elastic rescale
(ft/elastic.py) when it persists.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.arima import OnlineARIMA


@dataclass
class StragglerDetector:
    num_hosts: int
    slow_factor: float = 1.5       # x median counts as slow
    patience: int = 5
    _models: dict = field(default_factory=dict)
    _slow_streak: dict = field(default_factory=dict)
    flagged: set = field(default_factory=set)
    history: list = field(default_factory=list)

    def observe_step(self, t: float, host_step_times: dict) -> list[int]:
        """Feed per-host step times for one step; returns hosts flagged."""
        times = sorted(host_step_times.values())
        mid = len(times) // 2
        # true median: averaging the middle pair matters for even host
        # counts — taking the upper element would compare every host in a
        # 2-host cluster against the SLOWER one, hiding the straggler
        median = times[mid] if len(times) % 2 else \
            0.5 * (times[mid - 1] + times[mid])
        newly = []
        for host, st in host_step_times.items():
            model = self._models.setdefault(host, OnlineARIMA(p=6, d=0, lr=0.1))
            pred, _ = model.update(st)
            slow = st > self.slow_factor * max(median, 1e-9)
            drifting = model.warmed_up and st > self.slow_factor * max(pred, 1e-9)
            streak = self._slow_streak.get(host, 0)
            streak = streak + 1 if (slow or drifting) else 0
            self._slow_streak[host] = streak
            if streak >= self.patience and host not in self.flagged:
                self.flagged.add(host)
                newly.append(host)
                self.history.append((t, host))
        return newly

    def clear(self, host: int) -> None:
        self.flagged.discard(host)
        self._slow_streak[host] = 0
