"""Phase 3 — the runtime optimization loop (paper §III-D).

Monitors the production job for violations of the two QoS constraints
(average end-to-end latency vs ``l_const``; predicted worst-case recovery
time vs ``r_const``), defers reconfiguration when the TSF expects the
workload to drop >10%, pre-acts when ``cfg.proactive`` is set and the TSF
forecasts a rise that would breach a constraint within the horizon
(re-optimizing at the predicted peak so the switch lands before the
load), and otherwise solves Eq. 8 for a new CI — or, when
a cost model is attached (``cost``), for a new *checkpoint plan*: the
search then spans mechanism variants (incremental encoding, async commit,
multi-level routing, and the encode placement — device variants priced as
one pack + one fused flat-kernel encode per trigger from the bench_ckpt/3
calibration) in addition to the interval, and a Decision can carry
"switch to incr8-async at CI=42s" instead of just a number.

The control-plane contract is the ``JobHandle`` protocol below: ONE
complete interface every supervised substrate implements in full —
``sim.SimJobHandle`` (scalar simulator), ``sim.BatchedLaneHandle`` (one
lane of a vectorized campaign) and ``runtime.TrainerJobHandle`` (the live
JAX trainer).  There are no optional methods and no capability probing:
a handle that cannot switch plans on its substrate still implements
``reconfigure_plan`` (typically as drain + CI apply) so the controller
code is identical everywhere.  ``core.runtime.KhaosRuntime`` sequences
the three phases and drives this controller against any handle.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, ClassVar, Optional, Protocol, runtime_checkable

import numpy as np

from repro.config import CheckpointPlan, KhaosConfig
from repro.core.ci_optimizer import optimize_ci, optimize_plan
from repro.core.forecast import WorkloadForecaster
from repro.core.qos_models import QoSModel, RescalingTracker

#: every JobHandle method; the protocol-conformance test asserts each one
#: is present and callable on every registered handle implementation
JOB_HANDLE_METHODS = ("now", "current_ci", "current_plan", "avg_latency",
                      "avg_throughput", "healthy", "drain", "reconfigure",
                      "reconfigure_plan")


@runtime_checkable
class JobHandle(Protocol):
    """The controller's complete view of the supervised production job.

    This is a FULL protocol, not a base class with optional extensions:
    every method below is mandatory.  The controller never probes for
    capabilities — ``KhaosController`` calls ``current_plan`` and
    ``reconfigure_plan`` directly, and ``KhaosRuntime`` drives any handle
    through the same three-phase sequence, so the sim and the live
    trainer are interchangeable supervision targets.
    """

    def now(self) -> float:
        """The job's clock (virtual seconds for sim/trainer substrates)."""
        ...

    def current_ci(self) -> float:
        """The checkpoint interval currently in force."""
        ...

    def current_plan(self) -> CheckpointPlan:
        """The full checkpoint mechanism currently in force (its
        ``interval_s`` must agree with ``current_ci``)."""
        ...

    def avg_latency(self, window_s: float) -> float:
        """Mean end-to-end latency over the trailing window (NaN when the
        window holds no samples)."""
        ...

    def avg_throughput(self, window_s: float) -> float:
        """Mean arrival rate TR over the trailing window."""
        ...

    def healthy(self) -> bool:
        """False while the job is down or catching up after a failure —
        latency samples then reflect the failure, not the (CI, TR) -> L
        mapping, and reconfiguration would be aborted anyway (§IV-D)."""
        ...

    def drain(self) -> None:
        """Checkpoint-now barrier: persist current progress and quiesce
        in-flight commits so a reconfiguration loses nothing.  Substrates
        whose reconfigure path already takes a savepoint (the simulator's
        flink-semantics controlled restart) implement this as a no-op."""
        ...

    def reconfigure(self, new_ci: float) -> None:
        """Controlled reconfiguration of the CI knob only (drain, then
        apply the new interval; the mechanism is unchanged)."""
        ...

    def reconfigure_plan(self, plan: CheckpointPlan) -> None:
        """Controlled mechanism switch: drain, rebuild the checkpoint
        plane from ``plan`` (mode/levels/commit AND interval), resume."""
        ...


@dataclass
class Decision:
    """One optimization-cycle outcome.  ``kind`` is always a member of
    ``Decision.KINDS``:

      none         constraints satisfied (or change below actuation threshold)
      defer        TSF predicts a >10% workload drop -> wait it out
      reconfigure  actuated: ``new_ci`` (and ``new_plan`` when the
                   mechanism search is active) were applied to the job
      proactive    actuated BEFORE any breach: the TSF forecast a rate
                   rise that would violate a constraint within the
                   horizon, so the plan was re-optimized at the predicted
                   peak (``cfg.proactive`` gates this path)
      infeasible   no (CI, plan) satisfies both constraints
      cooldown     a reconfiguration happened too recently
      unhealthy    the job is down/catching up; samples were discarded
    """

    KINDS: ClassVar[tuple[str, ...]] = ("none", "defer", "reconfigure",
                                        "proactive", "infeasible",
                                        "cooldown", "unhealthy")

    t: float
    kind: str
    latency: float
    tr_avg: float
    predicted_recovery: float
    new_ci: Optional[float] = None
    new_plan: Optional[CheckpointPlan] = None

    def __post_init__(self) -> None:
        assert self.kind in self.KINDS, f"unknown Decision kind {self.kind!r}"


@dataclass
class KhaosController:
    cfg: KhaosConfig
    m_l: QoSModel
    m_r: QoSModel
    forecaster: WorkloadForecaster = None
    rescaler: RescalingTracker = None
    # mechanism optimization: attach a sim.costmodel.SimCostModel to let
    # Eq. 8 search checkpoint-plan variants, not just the CI grid
    cost: Optional[Any] = None
    plan_variants: Optional[list] = None
    mtbf_s: float = 3600.0
    decisions: list = field(default_factory=list)
    # fleet-shared decision log: when many controllers supervise many jobs
    # in one process (fleet.FleetSupervisor), every Decision is ALSO
    # appended to ``decision_log`` as ``(label, Decision)`` — one audit
    # trail across the whole fleet, in global decision order.  ``label``
    # names this controller's job in that log.  Both default off, so a
    # solo controller is unchanged.
    label: Optional[str] = None
    decision_log: Optional[list] = None
    _last_reconfig_t: float = -1e18
    _last_opt_t: float = -1e18
    # the M_L evaluation of the most recent due poll — consumers that
    # score the same (CI, TR) point (fleet divergence watchdogs) read it
    # instead of paying a second ``QoSModel.predict``
    last_pred_lat: float = float("nan")
    # error-analysis tracking (Tables II(a)/III(a))
    latency_obs: list = field(default_factory=list)    # (ci, tr, observed)
    recovery_obs: list = field(default_factory=list)

    def __post_init__(self) -> None:
        if self.forecaster is None:
            self.forecaster = WorkloadForecaster(
                horizon=self.cfg.forecast_horizon,
                defer_drop_fraction=self.cfg.defer_drop_fraction)
        if self.rescaler is None:
            self.rescaler = RescalingTracker(k=self.cfg.rescale_history)

    # ------------------------------------------------------------------
    def record_recovery(self, ci: float, tr: float, recovery_s: float) -> None:
        """Called by the runtime when an actual failure recovery was measured."""
        self.recovery_obs.append((ci, tr, recovery_s))

    def initial_ci(self, tr_avg: float) -> Optional[float]:
        """Pick the starting CI from the freshly-fitted models (end of
        Phase 2): the Eq. 8 optimum at the recorded average throughput."""
        res = optimize_ci(self.m_l, self.m_r, tr_avg,
                          self.cfg.latency_constraint,
                          self.cfg.recovery_constraint, 1.0,
                          self.cfg.ci_min, self.cfg.ci_max)
        return res.ci if res.feasible else None

    def maybe_optimize(self, job: JobHandle,
                       shared_pred: Optional[tuple] = None
                       ) -> Optional[Decision]:
        """Run one optimization cycle if the period elapsed. Returns the
        decision made (or None if not yet due).

        ``shared_pred`` is the batched-evaluation hook used by
        ``KhaosRuntime.drive_campaign``: a pre-computed ``(pred_lat,
        pred_rec)`` pair for this job's current (CI, TR), evaluated ONCE
        over all lanes' vectors per period instead of twice per lane.
        ``QoSModel.predict`` is row-independent, so the shared values are
        bit-identical to the per-lane ones and Decisions are unchanged."""
        t = job.now()
        if t - self._last_opt_t < self.cfg.optimization_period:
            return None
        self._last_opt_t = t
        self.last_pred_lat = float("nan")

        if not job.healthy():
            return self._decide(t, "unhealthy", float("nan"), float("nan"),
                                float("nan"))

        window = self.cfg.optimization_period
        lat = job.avg_latency(window)
        tr_avg = job.avg_throughput(window)
        ci_now = job.current_ci()
        self.forecaster.observe(tr_avg)

        if not np.isfinite(lat) or not np.isfinite(tr_avg):
            return self._decide(t, "none", lat, tr_avg, float("nan"))

        # localize M_L predictions to current conditions (rescaling factor p)
        if shared_pred is not None:
            pred_lat, pred_rec = float(shared_pred[0]), float(shared_pred[1])
        else:
            p_l, p_r = self.m_l.predict_pair(self.m_r,
                                             np.array([ci_now]), tr_avg)
            pred_lat, pred_rec = float(p_l[0]), float(p_r[0])
        self.last_pred_lat = pred_lat
        self.rescaler.track(lat, pred_lat)
        self.latency_obs.append((ci_now, tr_avg, lat))

        # violation checks
        lat_violation = lat > self.cfg.latency_constraint
        rec_violation = pred_rec > self.cfg.recovery_constraint
        if not (lat_violation or rec_violation):
            if self.cfg.proactive:
                pre = self._maybe_preact(job, t, lat, tr_avg, ci_now,
                                         pred_rec)
                if pre is not None:
                    return pre
            return self._decide(t, "none", lat, tr_avg, pred_rec)

        # TSF deferral: workload expected to drop > 10% -> defer
        if self.forecaster.should_defer():
            return self._decide(t, "defer", lat, tr_avg, pred_rec)

        if t - self._last_reconfig_t < self.cfg.reconfig_cooldown:
            return self._decide(t, "cooldown", lat, tr_avg, pred_rec)

        if self.cost is not None:
            return self._optimize_mechanism(job, t, lat, tr_avg, ci_now,
                                            pred_rec)

        res = optimize_ci(self.m_l, self.m_r, tr_avg,
                          self.cfg.latency_constraint,
                          self.cfg.recovery_constraint,
                          self.rescaler.p,
                          self.cfg.ci_min, self.cfg.ci_max)
        if not res.feasible or res.ci is None:
            return self._decide(t, "infeasible", lat, tr_avg, pred_rec)
        if abs(res.ci - ci_now) < 1.0:   # no meaningful change
            return self._decide(t, "none", lat, tr_avg, pred_rec)

        job.reconfigure(res.ci)
        self._last_reconfig_t = t
        return self._decide(t, "reconfigure", lat, tr_avg, pred_rec, res.ci)

    def _maybe_preact(self, job: JobHandle, t, lat, tr_avg, ci_now,
                      pred_rec) -> Optional[Decision]:
        """Forecast-driven pre-switching: no constraint is violated *now*,
        but the TSF predicts the rate rising enough within the horizon to
        break one.  Re-optimize at the PREDICTED peak rate and actuate
        immediately, so the switch (and its drain cost) lands before the
        load does — the mirror image of the defer rule, which only ever
        postpones action on downswings.  Returns None to fall through to
        the ordinary "none" decision: an unwarmed forecaster, a flat
        forecast, a peak the current config already satisfies, an active
        cooldown, and an infeasible peak all stay silent — a *forecast*
        never logs "infeasible" or "cooldown", only a breach does."""
        fr = self.forecaster
        if not fr.warmed_up:
            return None
        tr_peak = fr.predicted_peak()
        rise_gate = (1.0 + self.cfg.proactive_rise_fraction) * tr_avg
        if not np.isfinite(tr_peak) or tr_peak <= rise_gate:
            return None
        # would the CURRENT config violate a constraint at the peak rate?
        peak_lat = float(self.m_l.predict(np.array([ci_now]), tr_peak)[0])
        peak_rec = float(self.m_r.predict(np.array([ci_now]), tr_peak)[0])
        if not (peak_lat * self.rescaler.p > self.cfg.latency_constraint
                or peak_rec > self.cfg.recovery_constraint):
            return None
        if t - self._last_reconfig_t < self.cfg.reconfig_cooldown:
            return None
        if self.cost is not None:
            res = optimize_plan(self.m_l, self.m_r, tr_peak,
                                self.cfg.latency_constraint,
                                self.cfg.recovery_constraint,
                                self.rescaler.p,
                                self.cfg.ci_min, self.cfg.ci_max,
                                self.cost, variants=self.plan_variants,
                                mtbf_s=self.mtbf_s)
            if not res.feasible or res.plan is None:
                return None
            same_mechanism = res.plan.name == job.current_plan().name
            if same_mechanism and abs(res.ci - ci_now) < 1.0:
                return None
            if same_mechanism:
                job.reconfigure(res.ci)
                self._last_reconfig_t = t
                return self._decide(t, "proactive", lat, tr_avg, peak_rec,
                                    res.ci)
            job.reconfigure_plan(res.plan)
            self._last_reconfig_t = t
            return self._decide(t, "proactive", lat, tr_avg, peak_rec,
                                res.ci, res.plan)
        res = optimize_ci(self.m_l, self.m_r, tr_peak,
                          self.cfg.latency_constraint,
                          self.cfg.recovery_constraint,
                          self.rescaler.p,
                          self.cfg.ci_min, self.cfg.ci_max)
        if not res.feasible or res.ci is None:
            return None
        if abs(res.ci - ci_now) < 1.0:
            return None
        job.reconfigure(res.ci)
        self._last_reconfig_t = t
        return self._decide(t, "proactive", lat, tr_avg, peak_rec, res.ci)

    def _optimize_mechanism(self, job: JobHandle, t, lat, tr_avg, ci_now,
                            pred_rec) -> Decision:
        """Eq. 8 over (CI x plan variants); actuates through the handle's
        ``reconfigure_plan`` — the protocol guarantees it exists, so there
        is no CI-only fallback path anymore."""
        res = optimize_plan(self.m_l, self.m_r, tr_avg,
                            self.cfg.latency_constraint,
                            self.cfg.recovery_constraint,
                            self.rescaler.p,
                            self.cfg.ci_min, self.cfg.ci_max,
                            self.cost, variants=self.plan_variants,
                            mtbf_s=self.mtbf_s)
        if not res.feasible or res.plan is None:
            return self._decide(t, "infeasible", lat, tr_avg, pred_rec)
        same_mechanism = res.plan.name == job.current_plan().name
        if same_mechanism and abs(res.ci - ci_now) < 1.0:
            return self._decide(t, "none", lat, tr_avg, pred_rec)
        if same_mechanism:
            # mechanism unchanged: the CI knob is the cheap actuation — a
            # plan switch would pay a drain savepoint + manager rebuild
            # for a cadence change the hot path applies in place
            job.reconfigure(res.ci)
            self._last_reconfig_t = t
            return self._decide(t, "reconfigure", lat, tr_avg, pred_rec,
                                res.ci)
        job.reconfigure_plan(res.plan)
        self._last_reconfig_t = t
        return self._decide(t, "reconfigure", lat, tr_avg, pred_rec, res.ci,
                            res.plan)

    def _decide(self, t, kind, lat, tr, rec, new_ci=None,
                new_plan=None) -> Decision:
        d = Decision(t, kind, lat, tr, rec, new_ci, new_plan)
        self.decisions.append(d)
        if self.decision_log is not None:
            self.decision_log.append((self.label, d))
        return d

    # -- post-execution error analysis (paper Tables II(a)/III(a)) -----------
    def error_analysis(self) -> dict:
        out = {}
        if self.latency_obs:
            ci, tr, y = map(np.array, zip(*self.latency_obs))
            out["latency_avg_pct_error"] = self.m_l.avg_percent_error(ci, tr, y)
        if self.recovery_obs:
            ci, tr, y = map(np.array, zip(*self.recovery_obs))
            out["recovery_avg_pct_error"] = self.m_r.avg_percent_error(ci, tr, y)
        return out
