"""Phase 3 — the runtime optimization loop (paper §III-D).

Monitors the production job for violations of the two QoS constraints
(average end-to-end latency vs ``l_const``; predicted worst-case recovery
time vs ``r_const``), defers reconfiguration when the TSF expects the
workload to drop >10%, and otherwise solves Eq. 8 for a new CI — or, when
a cost model is attached (``cost``), for a new *checkpoint plan*: the
search then spans mechanism variants (incremental encoding, async commit,
multi-level routing) in addition to the interval, and a Decision can carry
"switch to incr8-async at CI=42s" instead of just a number.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Protocol

import numpy as np

from repro.config import CheckpointPlan, KhaosConfig
from repro.core.ci_optimizer import optimize_ci, optimize_plan
from repro.core.forecast import WorkloadForecaster
from repro.core.qos_models import QoSModel, RescalingTracker


class JobHandle(Protocol):
    """The controller's view of the supervised production job."""

    def now(self) -> float: ...
    def current_ci(self) -> float: ...
    def avg_latency(self, window_s: float) -> float: ...
    def avg_throughput(self, window_s: float) -> float: ...
    def healthy(self) -> bool:
        """False while the job is down or catching up after a failure —
        latency samples then reflect the failure, not the (CI, TR) -> L
        mapping, and reconfiguration would be aborted anyway (§IV-D)."""
        ...

    def reconfigure(self, new_ci: float) -> None:
        """Controlled reconfiguration: checkpoint-now, then apply the CI."""
        ...

    # Optional extensions (duck-typed; SimJobHandle implements both):
    #   current_plan() -> CheckpointPlan
    #   reconfigure_plan(plan: CheckpointPlan) -> None


@dataclass
class Decision:
    t: float
    kind: str            # none | defer | reconfigure | infeasible | cooldown
    latency: float
    tr_avg: float
    predicted_recovery: float
    new_ci: Optional[float] = None
    new_plan: Optional[CheckpointPlan] = None


@dataclass
class KhaosController:
    cfg: KhaosConfig
    m_l: QoSModel
    m_r: QoSModel
    forecaster: WorkloadForecaster = None
    rescaler: RescalingTracker = None
    # mechanism optimization: attach a sim.costmodel.SimCostModel to let
    # Eq. 8 search checkpoint-plan variants, not just the CI grid
    cost: Optional[Any] = None
    plan_variants: Optional[list] = None
    mtbf_s: float = 3600.0
    decisions: list = field(default_factory=list)
    _last_reconfig_t: float = -1e18
    _last_opt_t: float = -1e18
    _last_plan_name: Optional[str] = None   # fallback when the handle has
                                            # no current_plan()
    # error-analysis tracking (Tables II(a)/III(a))
    latency_obs: list = field(default_factory=list)    # (ci, tr, observed)
    recovery_obs: list = field(default_factory=list)

    def __post_init__(self) -> None:
        if self.forecaster is None:
            self.forecaster = WorkloadForecaster(
                horizon=self.cfg.forecast_horizon,
                defer_drop_fraction=self.cfg.defer_drop_fraction)
        if self.rescaler is None:
            self.rescaler = RescalingTracker(k=self.cfg.rescale_history)

    # ------------------------------------------------------------------
    def record_recovery(self, ci: float, tr: float, recovery_s: float) -> None:
        """Called by the runtime when an actual failure recovery was measured."""
        self.recovery_obs.append((ci, tr, recovery_s))

    def initial_ci(self, tr_avg: float) -> Optional[float]:
        """Pick the starting CI from the freshly-fitted models (end of
        Phase 2): the Eq. 8 optimum at the recorded average throughput."""
        res = optimize_ci(self.m_l, self.m_r, tr_avg,
                          self.cfg.latency_constraint,
                          self.cfg.recovery_constraint, 1.0,
                          self.cfg.ci_min, self.cfg.ci_max)
        return res.ci if res.feasible else None

    def maybe_optimize(self, job: JobHandle) -> Optional[Decision]:
        """Run one optimization cycle if the period elapsed. Returns the
        decision made (or None if not yet due)."""
        t = job.now()
        if t - self._last_opt_t < self.cfg.optimization_period:
            return None
        self._last_opt_t = t

        if not getattr(job, "healthy", lambda: True)():
            return self._decide(t, "unhealthy", float("nan"), float("nan"),
                                float("nan"))

        window = self.cfg.optimization_period
        lat = job.avg_latency(window)
        tr_avg = job.avg_throughput(window)
        ci_now = job.current_ci()
        self.forecaster.observe(tr_avg)

        if not np.isfinite(lat) or not np.isfinite(tr_avg):
            return self._decide(t, "none", lat, tr_avg, float("nan"))

        # localize M_L predictions to current conditions (rescaling factor p)
        pred_lat = float(self.m_l.predict(np.array([ci_now]), tr_avg)[0])
        self.rescaler.track(lat, pred_lat)
        self.latency_obs.append((ci_now, tr_avg, lat))

        # violation checks
        pred_rec = float(self.m_r.predict(np.array([ci_now]), tr_avg)[0])
        lat_violation = lat > self.cfg.latency_constraint
        rec_violation = pred_rec > self.cfg.recovery_constraint
        if not (lat_violation or rec_violation):
            return self._decide(t, "none", lat, tr_avg, pred_rec)

        # TSF deferral: workload expected to drop > 10% -> defer
        if self.forecaster.should_defer():
            return self._decide(t, "defer", lat, tr_avg, pred_rec)

        if t - self._last_reconfig_t < self.cfg.reconfig_cooldown:
            return self._decide(t, "cooldown", lat, tr_avg, pred_rec)

        if self.cost is not None:
            return self._optimize_mechanism(job, t, lat, tr_avg, ci_now,
                                            pred_rec)

        res = optimize_ci(self.m_l, self.m_r, tr_avg,
                          self.cfg.latency_constraint,
                          self.cfg.recovery_constraint,
                          self.rescaler.p,
                          self.cfg.ci_min, self.cfg.ci_max)
        if not res.feasible or res.ci is None:
            return self._decide(t, "infeasible", lat, tr_avg, pred_rec)
        if abs(res.ci - ci_now) < 1.0:   # no meaningful change
            return self._decide(t, "none", lat, tr_avg, pred_rec)

        job.reconfigure(res.ci)
        self._last_reconfig_t = t
        return self._decide(t, "reconfigure", lat, tr_avg, pred_rec, res.ci)

    def _optimize_mechanism(self, job: JobHandle, t, lat, tr_avg, ci_now,
                            pred_rec) -> Decision:
        """Eq. 8 over (CI x plan variants); actuates a plan switch when the
        job handle supports it, otherwise falls back to the CI knob."""
        res = optimize_plan(self.m_l, self.m_r, tr_avg,
                            self.cfg.latency_constraint,
                            self.cfg.recovery_constraint,
                            self.rescaler.p,
                            self.cfg.ci_min, self.cfg.ci_max,
                            self.cost, variants=self.plan_variants,
                            mtbf_s=self.mtbf_s)
        if not res.feasible or res.plan is None:
            return self._decide(t, "infeasible", lat, tr_avg, pred_rec)
        current_plan = getattr(job, "current_plan", lambda: None)()
        current_name = (current_plan.name if current_plan is not None
                        else self._last_plan_name)
        same_mechanism = current_name is not None \
            and res.plan.name == current_name
        reconfigure_plan = getattr(job, "reconfigure_plan", None)
        if reconfigure_plan is None:
            # handle only exposes the CI knob: actuate (and report) CI only
            if abs(res.ci - ci_now) < 1.0:
                return self._decide(t, "none", lat, tr_avg, pred_rec)
            job.reconfigure(res.ci)
            self._last_reconfig_t = t
            return self._decide(t, "reconfigure", lat, tr_avg, pred_rec,
                                res.ci)
        if same_mechanism and abs(res.ci - ci_now) < 1.0:
            return self._decide(t, "none", lat, tr_avg, pred_rec)
        reconfigure_plan(res.plan)
        self._last_plan_name = res.plan.name
        self._last_reconfig_t = t
        return self._decide(t, "reconfigure", lat, tr_avg, pred_rec, res.ci,
                            res.plan)

    def _decide(self, t, kind, lat, tr, rec, new_ci=None,
                new_plan=None) -> Decision:
        d = Decision(t, kind, lat, tr, rec, new_ci, new_plan)
        self.decisions.append(d)
        return d

    # -- post-execution error analysis (paper Tables II(a)/III(a)) -----------
    def error_analysis(self) -> dict:
        out = {}
        if self.latency_obs:
            ci, tr, y = map(np.array, zip(*self.latency_obs))
            out["latency_avg_pct_error"] = self.m_l.avg_percent_error(ci, tr, y)
        if self.recovery_obs:
            ci, tr, y = map(np.array, zip(*self.recovery_obs))
            out["recovery_avg_pct_error"] = self.m_r.avg_percent_error(ci, tr, y)
        return out
