"""Phase 1 — establishing the steady state (paper §III-B, Eqs. 1–5).

Records the incoming event stream for ``k`` seconds, smooths ``W(t)`` with
an averaging window, and selects ``m`` failure points spanning the observed
throughput range.

The paper's Eq. 4 as printed spaces *timestamps* equidistantly in
[t_min, t_max]; the prose asks for "equidistantly spaced throughput rates".
``mode="throughput"`` implements the prose (default), ``mode="time"`` the
literal equation — see DESIGN.md §7.5.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.stream import WorkloadRecording


@dataclass
class SteadyState:
    recording: WorkloadRecording
    smoothed: np.ndarray
    failure_times: np.ndarray      # F
    failure_rates: np.ndarray      # TR = {W(f) | f in F}


def select_failure_points(recording: WorkloadRecording, m: int,
                          smoothing_window: int = 30,
                          mode: str = "throughput") -> SteadyState:
    if m < 2:
        raise ValueError("need at least 2 failure points")
    w = recording.workload(smoothing_window)
    t = recording.times
    i_min = int(np.argmin(w))      # t_min = argmin W  (Eq. 3)
    i_max = int(np.argmax(w))      # t_max = argmax W

    if mode == "time":
        # Eq. 4 literal: equidistant timestamps between t_min and t_max
        lo, hi = sorted((t[i_min], t[i_max]))
        times = np.linspace(lo, hi, m)
        idx = np.searchsorted(t, times).clip(0, len(t) - 1)
    elif mode == "throughput":
        # prose intent: equidistant throughput levels between W_min and W_max,
        # each mapped to the closest-matching timestamp (distinct per level)
        levels = np.linspace(w[i_min], w[i_max], m)
        idx = []
        taken: set = set()
        for lv in levels:
            order = np.argsort(np.abs(w - lv))
            pick = next((int(j) for j in order if int(j) not in taken), int(order[0]))
            taken.add(pick)
            idx.append(pick)
        idx = np.array(sorted(idx))
    else:
        raise ValueError(mode)

    return SteadyState(
        recording=recording,
        smoothed=w,
        failure_times=t[idx],
        failure_rates=w[idx],
    )
