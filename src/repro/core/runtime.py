"""The Khaos control-plane runtime: ONE phase machine driving the paper's
three phases against any ``JobHandle`` — simulator or live trainer.

Before this module every caller (examples, launchers, benchmarks) hand-
stitched the sequence "record -> select failure points -> profile ->
fit M_L/M_R -> build controller -> poll maybe_optimize".  ``KhaosRuntime``
makes the sequence a formal state machine:

    idle ──record_steady_state()──▶ steady_state          (Phase 1, §III-B)
         ──run_profiling()───────▶ profiled               (Phase 2, §III-C)
         ──attach(job)───────────▶ optimizing             (Phase 3, §III-D)

Each transition validates its prerequisites (``PhaseError`` on a skipped
or repeated phase) and appends a ``PhaseEvent`` to ``phase_log`` — the
record the smoke gate (``benchmarks/run.py --smoke``) asserts phase order
against.  ``install_models`` is the explicit escape hatch for callers
that bring pre-fitted QoS models (it logs phases 1-2 as ``skipped``).

Phase 3 runs in two shapes:

  * ``attach(job)`` + ``step()`` — classic single-job supervision: the
    caller ticks its substrate and polls ``step()``, which forwards to
    ``KhaosController.maybe_optimize`` against the attached handle;
  * ``drive_campaign(campaign)`` — controller-IN-THE-LOOP over a
    ``sim.BatchedCampaign``: every lane gets its own controller and a
    ``BatchedLaneHandle``, the campaign advances in optimization-period
    chunks, and each chunk boundary applies a ``maybe_optimize`` step
    across all live lanes.  This vectorizes Phase-3 *evaluation* the way
    ``BatchedDeployment`` vectorized Phase-2 profiling — day-scale E1/E2
    controlled runs no longer tick the scalar engine lane by lane.
    ``lane_cfgs`` gives selected lanes their own ``KhaosConfig`` so e.g.
    proactive and reactive controllers run as lanes of ONE campaign.

Phase 3 also carries the *mitigation ladder* for gray failures — the
degradations of ``ft.failures`` that slow a job without killing it:

  rung 1  ``attach_anomaly_detector`` + ``observe_metrics``: a sustained
          anomaly on the supervised metrics (the QoS models no longer
          describe the degraded cluster) triggers ``reprofile()`` — a
          legal re-entry into Phase 2 that re-runs the chaos campaign,
          refits M_L/M_R and swaps them onto every live controller;
  rung 2  ``attach_straggler_detector`` + ``observe_host_steps``: a host
          flagged as a persistent straggler escalates to an elastic
          recovery plan (``ft.elastic.plan_recovery`` — replace from hot
          standbys, else rescale down), recorded in ``mitigations``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.config import KhaosConfig
from repro.core.controller import (JOB_HANDLE_METHODS, Decision, JobHandle,
                                   KhaosController)
from repro.core.profiler import (ProfilingResult, run_profiling,
                                 run_profiling_campaign)
from repro.core.qos_models import QoSModel
from repro.core.steady_state import SteadyState, select_failure_points

#: legal phase order; every transition must advance exactly one slot
PHASES = ("idle", "steady_state", "profiled", "optimizing")


class PhaseError(RuntimeError):
    """A phase was entered out of order (skipped prerequisite or repeat)."""


def missing_handle_methods(job: Any) -> list:
    """The protocol methods ``job`` fails to provide (empty = conformant).
    The single source for every conformance check (``KhaosRuntime.attach``,
    the ``run.py --smoke`` gate, the protocol tests)."""
    return [m for m in JOB_HANDLE_METHODS
            if not callable(getattr(job, m, None))]


@dataclass
class PhaseEvent:
    """One transition of the phase machine (``phase_log`` entry)."""
    phase: str
    info: dict = field(default_factory=dict)


class KhaosRuntime:
    """Sequences Phase 1 -> Phase 2 -> Phase 3 against any ``JobHandle``.

    Construction takes the paper's knobs (``KhaosConfig``) plus the
    optional mechanism-search attachments (``cost``/``plan_variants``/
    ``verifier``/``mtbf_s``) that are forwarded to every controller this
    runtime builds.
    """

    def __init__(self, cfg: KhaosConfig, cost: Optional[Any] = None,
                 plan_variants: Optional[list] = None,
                 mtbf_s: float = 3600.0,
                 verifier: Optional[Callable] = None):
        self.cfg = cfg
        self.cost = cost
        self.plan_variants = plan_variants
        self.mtbf_s = mtbf_s
        self.verifier = verifier
        self.phase: str = "idle"
        self.phase_log: list[PhaseEvent] = []
        # phase artifacts
        self.steady: Optional[SteadyState] = None
        self.profile: Optional[ProfilingResult] = None
        self.m_l: Optional[QoSModel] = None
        self.m_r: Optional[QoSModel] = None
        self.controller: Optional[KhaosController] = None
        self.job: Optional[JobHandle] = None
        # mitigation ladder (gray failures): optional attachments
        self.anomaly: Optional[Any] = None
        self.anomaly_lane: int = 0
        self.straggler: Optional[Any] = None
        self.mesh: Optional[Any] = None
        self.standbys: int = 0
        self.chips_per_host: int = 4
        self.global_batch: Optional[int] = None
        self.mitigations: list = []          # (t, kind, info) escalations
        self._reprofile_source: Optional[tuple] = None
        self._reprofiled_episode = False     # one reprofile per anomaly
        self._active_controllers: list = []  # model-swap targets
        # fleet hooks: every controller this runtime builds logs its
        # Decisions into the shared ``decision_log`` under ``label``
        # (fleet.FleetSupervisor threads one list through N runtimes);
        # ``transferred`` records that Phase 2 was skipped via the
        # QoS-model-transfer fast path (``adopt_models``)
        self.decision_label: Optional[str] = None
        self.decision_log: Optional[list] = None
        self.transferred: bool = False

    # -- phase machinery ----------------------------------------------------
    def _transition(self, to: str, **info) -> None:
        if PHASES.index(to) != PHASES.index(self.phase) + 1:
            raise PhaseError(f"cannot enter phase {to!r} from {self.phase!r} "
                             f"(order is {' -> '.join(PHASES)})")
        self.phase = to
        self.phase_log.append(PhaseEvent(to, info))

    def phase_sequence(self) -> list[str]:
        """The phases entered so far, in order (the smoke-gate assertion)."""
        return [ev.phase for ev in self.phase_log]

    # -- Phase 1: steady state (§III-B) -------------------------------------
    def record_steady_state(self, recording,
                            m: Optional[int] = None) -> SteadyState:
        """Analyze the workload recording and select the ``m`` failure
        points spanning the observed throughput range."""
        steady = select_failure_points(
            recording, m=m or self.cfg.num_failure_points,
            smoothing_window=self.cfg.smoothing_window,
            mode=self.cfg.failure_point_mode)
        self._transition("steady_state",
                         failure_points=len(steady.failure_times),
                         tr_range=[float(steady.failure_rates.min()),
                                   float(steady.failure_rates.max())])
        self.steady = steady
        return steady

    # -- Phase 2: chaos profiling (§III-C) ----------------------------------
    def default_ci_grid(self) -> np.ndarray:
        """The z candidate CIs from the config window."""
        return np.linspace(self.cfg.ci_min, self.cfg.ci_max,
                           self.cfg.num_configs)

    def run_profiling(self, deployment, ci_values=None,
                      margin: Optional[float] = None,
                      progress: Optional[Callable[[str], None]] = None
                      ) -> ProfilingResult:
        """Profile the (CI x failure point) grid and fit M_L / M_R.

        ``deployment`` is either a ``CampaignDeployment`` (has
        ``profile_campaign`` — the whole grid as lanes of one batched
        campaign, e.g. ``sim.BatchedDeployment``) or a per-CI deployment
        factory ``ci -> Deployment`` (the sequential oracle path).
        """
        if self.phase != "steady_state":
            raise PhaseError("run_profiling requires Phase 1 "
                             "(record_steady_state) to have completed")
        ci_values = (self.default_ci_grid() if ci_values is None
                     else np.asarray(ci_values, np.float64))
        margin = self.cfg.profile_margin_seconds if margin is None else margin
        if hasattr(deployment, "profile_campaign"):
            prof = run_profiling_campaign(deployment, self.steady, ci_values,
                                          margin=margin, progress=progress)
            substrate = "campaign"
        else:
            prof = run_profiling(deployment, self.steady, ci_values,
                                 margin=margin, progress=progress)
            substrate = "sequential"
        ci_f, tr_f, L_f, R_f = prof.flat()
        self.m_l = QoSModel(degree=self.cfg.model_degree,
                            ridge_lambda=self.cfg.ridge_lambda
                            ).fit(ci_f, tr_f, L_f)
        self.m_r = QoSModel(degree=self.cfg.model_degree,
                            ridge_lambda=self.cfg.ridge_lambda
                            ).fit(ci_f, tr_f, R_f)
        self._transition("profiled", substrate=substrate,
                         cells=int(prof.latencies.size),
                         m_l_pct_error=self.m_l.avg_percent_error(
                             ci_f, tr_f, L_f),
                         m_r_pct_error=self.m_r.avg_percent_error(
                             ci_f, tr_f, R_f))
        self.profile = prof
        return prof

    def install_models(self, m_l: QoSModel, m_r: QoSModel,
                       steady: Optional[SteadyState] = None) -> None:
        """Skip phases 1-2 with pre-fitted QoS models (production installs
        models fitted on the cluster; demos install priors).  The skipped
        phases are still logged so ``phase_sequence`` stays truthful."""
        if self.phase != "idle":
            raise PhaseError("install_models replaces phases 1-2 and must "
                             "run from 'idle'")
        self.steady = steady
        self._transition("steady_state", skipped=True)
        self._transition("profiled", skipped=True)
        self.m_l, self.m_r = m_l, m_r

    def adopt_models(self, m_l: QoSModel, m_r: QoSModel,
                     source: str = "registry") -> None:
        """The QoS-model TRANSFER fast path (fleet admission): Phase 1 ran
        for real — this job's steady state and failure points are its own —
        but Phase 2 is skipped because a fitted neighbor with a matching
        profile fingerprint already exists (``fleet.QoSModelRegistry``).
        The machine walks ``steady_state -> profiled`` without a campaign;
        the transition is logged with ``transferred=True`` and the donor
        ``source`` so ``phase_sequence`` stays truthful.  Because
        ``steady`` is real, a later divergence-watchdog ``reprofile()`` is
        fully legal — that is the fallback when the transferred models turn
        out not to describe this job after all."""
        if self.phase != "steady_state":
            raise PhaseError("adopt_models is the skip-Phase-2 fast path "
                             "and requires Phase 1 (record_steady_state) "
                             "to have completed")
        self._transition("profiled", transferred=True, source=source)
        self.m_l, self.m_r = m_l, m_r
        self.transferred = True

    def attach_decision_log(self, log: list, label: str) -> None:
        """Arm the fleet-shared decision log: every controller this runtime
        builds (single-job, campaign, or reprofile-rebuilt) appends its
        Decisions to ``log`` as ``(label, Decision)`` — the one audit trail
        a ``FleetSupervisor`` reads across all supervised jobs."""
        self.decision_log = log
        self.decision_label = label
        for ctl in self._active_controllers:
            ctl.decision_log = log
            ctl.label = label

    # -- Phase 3: runtime optimization (§III-D) ------------------------------
    def _make_controller(self, cfg: Optional[KhaosConfig] = None
                         ) -> KhaosController:
        assert self.m_l is not None and self.m_r is not None
        return KhaosController(cfg=cfg or self.cfg, m_l=self.m_l,
                               m_r=self.m_r, cost=self.cost,
                               plan_variants=self.plan_variants,
                               mtbf_s=self.mtbf_s,
                               label=self.decision_label,
                               decision_log=self.decision_log)

    def initial_ci(self, tr_avg: float) -> Optional[float]:
        """The Eq.-8 optimum at the recorded average throughput (the CI the
        job should start Phase 3 with); None when infeasible."""
        if self.m_l is None:
            raise PhaseError("initial_ci requires fitted models (Phase 2)")
        return self._make_controller().initial_ci(tr_avg)

    def attach(self, job: JobHandle) -> KhaosController:
        """Enter Phase 3 supervising ``job``; returns the controller."""
        if self.phase != "profiled":
            raise PhaseError("attach requires Phase 2 (run_profiling or "
                             "install_models) to have completed")
        missing = missing_handle_methods(job)
        if missing:
            raise TypeError(f"{type(job).__name__} does not implement the "
                            f"JobHandle protocol: missing {missing}")
        self.controller = self._make_controller()
        self._active_controllers = [self.controller]
        self.job = job
        self._transition("optimizing", handle=type(job).__name__)
        return self.controller

    def step(self) -> Optional[Decision]:
        """One optimization poll against the attached job (call after each
        substrate tick; the controller gates itself on the period)."""
        if self.phase != "optimizing" or self.controller is None:
            raise PhaseError("step requires attach() (Phase 3)")
        return self.controller.maybe_optimize(self.job)

    # -- Phase 3, mitigation ladder (gray failures) ---------------------------
    def attach_anomaly_detector(self, detector, lane: int = 0) -> None:
        """Arm rung 1: ``detector`` (``core.anomaly.AnomalyDetector``) is
        fed by ``observe_metrics`` — directly or, under ``drive_campaign``,
        from the supervised lane ``lane`` at every chunk boundary.  Its
        metric names must come from {"throughput", "latency"} on the
        campaign path (those are the observables a lane exposes)."""
        self.anomaly = detector
        self.anomaly_lane = lane

    def attach_straggler_detector(self, detector, mesh=None, standbys: int = 0,
                                  chips_per_host: int = 4,
                                  global_batch: Optional[int] = None) -> None:
        """Arm rung 2: ``detector`` (``ft.straggler.StragglerDetector``)
        is fed by ``observe_host_steps``; a newly-flagged host escalates
        to ``ft.elastic.plan_recovery`` against ``mesh``/``standbys``
        (escalation is recorded but not actuated when ``mesh`` is None)."""
        self.straggler = detector
        self.mesh = mesh
        self.standbys = standbys
        self.chips_per_host = chips_per_host
        self.global_batch = global_batch

    def enable_reprofiling(self, deployment, ci_values=None) -> None:
        """Store the chaos-campaign substrate ``reprofile()`` re-runs when
        the anomaly rung fires (same contract as ``run_profiling``)."""
        self._reprofile_source = (deployment, ci_values)

    def reprofile(self, deployment=None, ci_values=None,
                  reason: str = "anomaly") -> ProfilingResult:
        """Anomaly-triggered re-entry into Phase 2: the QoS models no
        longer describe the (degraded) cluster, so re-run the chaos
        campaign, refit M_L/M_R and swap the fresh models onto every live
        controller.  Legal only from ``optimizing``; the detour is logged
        as a ``reprofile`` event so ``phase_log`` stays truthful, then the
        machine re-walks steady_state -> profiled -> optimizing."""
        if self.phase != "optimizing":
            raise PhaseError("reprofile is a Phase-3 mitigation and "
                             "requires phase 'optimizing'")
        if self.steady is None:
            raise PhaseError("reprofile requires a recorded steady state "
                             "(install_models skipped Phase 1)")
        if deployment is None:
            if self._reprofile_source is None:
                raise PhaseError("reprofile needs a deployment: pass one "
                                 "or call enable_reprofiling first")
            deployment, ci_values = self._reprofile_source
        self.phase_log.append(PhaseEvent("reprofile", {"reason": reason}))
        self.phase = "steady_state"
        prof = self.run_profiling(deployment, ci_values=ci_values)
        self._transition("optimizing", handle="reprofile", reason=reason)
        for ctl in self._active_controllers:
            ctl.m_l, ctl.m_r = self.m_l, self.m_r
        return prof

    def observe_metrics(self, t: float, values: dict,
                        healthy: bool = True) -> bool:
        """Rung 1 feed: one supervised-metrics sample for the anomaly
        detector (``healthy=False`` freezes learning so a failure is not
        learned as normal).  The FIRST observation of a sustained anomaly
        triggers ``reprofile()`` — once per anomaly episode, and only when
        a reprofiling substrate is armed.  Returns True when it fired."""
        if self.anomaly is None:
            return False
        anomalous = self.anomaly.observe(t, values, learn=healthy)
        if not anomalous:
            self._reprofiled_episode = False
            return False
        if (self._reprofiled_episode or self._reprofile_source is None
                or self.phase != "optimizing"):
            return False
        self._reprofiled_episode = True
        self.mitigations.append((t, "reprofile", {"reason": "anomaly"}))
        self.reprofile(reason="anomaly")
        return True

    def observe_host_steps(self, t: float, host_step_times: dict) -> list:
        """Rung 2 feed: per-host step times for the straggler detector.
        Every host it newly flags escalates to an elastic recovery plan —
        replace it from hot standbys when any remain, else rescale down —
        appended to ``mitigations``.  Returns the plans (None entries when
        no mesh was attached to plan against)."""
        if self.straggler is None:
            return []
        plans = []
        for host in self.straggler.observe_step(t, host_step_times):
            plan = None
            if self.mesh is not None:
                from repro.ft.elastic import plan_recovery   # local: core
                # must stay importable without the ft package loaded first
                plan = plan_recovery(self.mesh, hosts_lost=1,
                                     standbys=self.standbys,
                                     chips_per_host=self.chips_per_host,
                                     global_batch=self.global_batch)
                self.standbys = plan.standbys_left
                self.mesh = plan.mesh
            self.mitigations.append((t, "straggler_evict",
                                     {"host": host, "plan": plan}))
            plans.append(plan)
        return plans

    # -- Phase 3, vectorized: controller-in-the-loop campaigns ---------------
    def drive_campaign(self, campaign,
                       lanes: Optional[Sequence[int]] = None,
                       period_ticks: Optional[int] = None,
                       lane_cfgs: Optional[dict] = None
                       ) -> "CampaignSupervision":
        """Run Phase 3 across every lane of a ``sim.BatchedCampaign``.

        Each selected lane gets its own ``KhaosController`` and a
        ``BatchedLaneHandle``; the campaign advances in chunks of
        ``period_ticks`` and each chunk boundary applies one
        ``maybe_optimize`` step per live lane — a vectorized substrate
        under N independent scalar control loops.  The default chunk is
        the optimization period, so decisions fire at t0 + k*period;
        the scalar loop, polling after every tick, fires its first
        decision one tick after t0 and then every period from there.
        Pass ``period_ticks=1`` to poll every tick and reproduce the
        scalar decision clock exactly (bit-exact per lane, at more
        Python overhead per tick).  Requires the campaign to record
        history (the handles' latency windows read it).

        ``lane_cfgs`` maps lane id -> ``KhaosConfig`` override for that
        lane's controller (lanes absent from the map use the runtime's
        config) — the head-to-head harness: proactive vs reactive
        controllers supervising twin lanes of the SAME campaign.  When an
        anomaly detector is attached, the supervised lane's metrics are
        fed to it at every chunk boundary and a sustained anomaly fires
        the reprofile rung mid-campaign.
        """
        if self.phase not in ("profiled", "optimizing"):
            raise PhaseError("drive_campaign requires Phase 2 to have "
                             "completed")
        from repro.sim.batched import BatchedLaneHandle   # local: core must
        # stay importable without the sim package loaded first
        lane_ids = list(range(campaign.n_lanes)) if lanes is None \
            else list(lanes)
        handles = [BatchedLaneHandle(campaign, i) for i in lane_ids]
        controllers = [self._make_controller((lane_cfgs or {}).get(i))
                       for i in lane_ids]
        self._active_controllers = list(controllers)
        period = max(1, int(period_ticks if period_ticks is not None
                            else round(self.cfg.optimization_period)))
        if self.phase == "profiled":
            self._transition("optimizing", handle="BatchedLaneHandle",
                             lanes=len(lane_ids))
        while not campaign.done:
            campaign.run(n_ticks=period)
            live = [(ctl, h) for ctl, h in zip(controllers, handles)
                    if h.alive()]
            preds = self._shared_predictions(live)
            for (ctl, h), pred in zip(live, preds):
                ctl.maybe_optimize(h, shared_pred=pred)
            if self.anomaly is not None:
                self._feed_campaign_anomaly(handles, lane_ids)
        # the scalar loop polls once more after its final tick (alive()
        # is already False there, so the in-loop polls skip it); actuation
        # on a finished lane is as inert as the scalar's post-loop one
        pairs = list(zip(controllers, handles))
        for (ctl, h), pred in zip(pairs, self._shared_predictions(pairs)):
            ctl.maybe_optimize(h, shared_pred=pred)
        return CampaignSupervision(campaign, lane_ids, handles, controllers)

    def _feed_campaign_anomaly(self, handles, lane_ids) -> None:
        """One anomaly-detector sample from the supervised lane's trailing
        window (skipped while the window is empty or the lane finished)."""
        if self.anomaly_lane not in lane_ids:
            return
        h = handles[lane_ids.index(self.anomaly_lane)]
        tr = h.avg_throughput(self.cfg.optimization_period)
        lat = h.avg_latency(self.cfg.optimization_period)
        if not (np.isfinite(tr) and np.isfinite(lat)):
            return
        vals = {m: {"throughput": tr, "latency": lat}[m]
                for m in self.anomaly.metrics}
        self.observe_metrics(h.now(), vals, healthy=h.healthy())

    def _shared_predictions(self, pairs: Sequence[tuple]) -> list:
        """One ``QoSModel.predict`` over ALL lanes' (CI, TR) vectors per
        optimization period, instead of two scalar evaluations per lane —
        the vectorized-controller cut for very wide supervised campaigns.
        Only lanes whose ``maybe_optimize`` will actually reach the
        prediction site are evaluated (the gating predicates below mirror
        its early exits exactly), and ``QoSModel.predict`` is
        row-independent, so per-lane Decisions are BIT-identical to the
        per-lane evaluation loop (asserted in tests).  Gating reads each
        controller's OWN config (``lane_cfgs`` lanes may differ from the
        runtime's)."""
        rows: list[tuple[int, float, float]] = []
        for i, (ctl, h) in enumerate(pairs):
            if h.now() - ctl._last_opt_t < ctl.cfg.optimization_period:
                continue                      # not due: returns None
            if not h.healthy():
                continue                      # "unhealthy" decision
            lat = h.avg_latency(ctl.cfg.optimization_period)
            tr = h.avg_throughput(ctl.cfg.optimization_period)
            if not (np.isfinite(lat) and np.isfinite(tr)):
                continue                      # empty-window "none" decision
            rows.append((i, h.current_ci(), tr))
        preds: list = [None] * len(pairs)
        if rows:
            idx, ci, tr = zip(*rows)
            p_l, p_r = self.m_l.predict_pair(self.m_r,
                                             np.asarray(ci, np.float64),
                                             np.asarray(tr, np.float64))
            for j, i in enumerate(idx):
                preds[i] = (float(p_l[j]), float(p_r[j]))
        return preds


@dataclass
class CampaignSupervision:
    """Result of a controller-in-the-loop campaign run."""
    campaign: Any
    lane_ids: list
    handles: list
    controllers: list

    def decisions(self, lane: int) -> list:
        return self.controllers[self.lane_ids.index(lane)].decisions

    def reconfigurations(self, lane: int) -> list:
        return self.handles[self.lane_ids.index(lane)].reconfigurations

    def summary(self) -> dict:
        kinds: dict[str, int] = {}
        for ctl in self.controllers:
            for d in ctl.decisions:
                kinds[d.kind] = kinds.get(d.kind, 0) + 1
        return {
            "lanes": len(self.lane_ids),
            "decisions_by_kind": kinds,
            "reconfigured_lanes": sum(1 for h in self.handles
                                      if h.reconfigurations),
            "plan_switched_lanes": sum(1 for h in self.handles
                                       if h.plan_changes),
        }
