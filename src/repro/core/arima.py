"""Online ARIMA via online gradient descent (after Anava et al., the method
behind the paper's anomaly detector [27]).

ARIMA(p, d, q) is approximated by an AR(p) model over the d-times
differenced series; the MA(q) component is absorbed by extending the AR
window (Anava's ARIMA-OGD).  Coefficients update per observation with
projected OGD, so the model tracks non-stationary streams — exactly what a
workload monitor needs.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class OnlineARIMA:
    p: int = 8              # AR window (covers AR(p') + MA(q) per Anava)
    d: int = 1              # differencing order
    lr: float = 0.05
    clip: float = 10.0      # coefficient L2 projection radius

    w: np.ndarray = field(default=None, repr=False)
    _diffs: list = field(default_factory=list, repr=False)    # last d raw tails
    _hist: np.ndarray = field(default=None, repr=False)       # last p differenced values
    _n: int = 0
    _scale: float = 1.0

    def __post_init__(self) -> None:
        if self.w is None:
            self.w = np.zeros(self.p)
            self.w[0] = 1.0     # start as "predict last value"
        if self._hist is None:
            self._hist = np.zeros(self.p)
        self._tails = np.zeros(self.d) if self.d else np.zeros(0)

    # -- internals ------------------------------------------------------------
    def _difference(self, y: float) -> float:
        """Apply d-order differencing incrementally; returns the d-diffed value."""
        v = y
        for i in range(self.d):
            prev = self._tails[i]
            self._tails[i] = v
            v = v - prev
        return v

    def _undifference(self, dv: float) -> float:
        """Invert differencing for a one-step prediction."""
        v = dv
        for i in reversed(range(self.d)):
            v = v + self._tails[i]
        return v

    # -- API --------------------------------------------------------------
    def predict(self) -> float:
        """One-step-ahead prediction of the raw series."""
        dv = float(self.w @ self._hist)
        return self._undifference(dv)

    def update(self, y: float) -> tuple[float, float]:
        """Observe y; returns (prediction_made_before_seeing_y, error)."""
        pred = self.predict()
        # adaptive scale keeps the OGD step size unit-free
        self._scale = max(0.95 * self._scale, abs(y), 1e-9)
        err = (y - pred) / self._scale
        if self._n > self.p + self.d:
            grad = -2.0 * err * self._hist / self._scale
            self.w = self.w - self.lr * grad
            norm = np.linalg.norm(self.w)
            if norm > self.clip:
                self.w *= self.clip / norm
        dv = self._difference(y)
        self._hist[1:] = self._hist[:-1]   # in-place roll: no allocation
        self._hist[0] = dv
        self._n += 1
        return pred, y - pred

    def forecast(self, steps: int) -> np.ndarray:
        """Multi-step-ahead forecast (feeding predictions back)."""
        hist = self._hist.copy()
        tails = self._tails.copy()
        out = np.empty(steps)
        for s in range(steps):
            dv = float(self.w @ hist)
            v = dv
            for i in reversed(range(self.d)):
                v = v + tails[i]
            out[s] = v
            # roll forward as if v was observed
            vv = v
            for i in range(self.d):
                prev = tails[i]
                tails[i] = vv
                vv = vv - prev
            hist = np.roll(hist, 1)
            hist[0] = vv if self.d else v
        return out

    @property
    def warmed_up(self) -> bool:
        return self._n > 2 * (self.p + self.d)
