"""Online-ARIMA anomaly detector (paper §III-C, after [27]).

Trained on failure-free ("positive") executions of the metrics stream
(input throughput, consumer lag).  A point is anomalous when the
normalized prediction error exceeds a threshold derived from a window of
past errors; *recovery time* is the length of the contiguous anomalous
interval — i.e. from failure until the job is producing results at the
latest offset again (§III-C's availability definition).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.core.arima import OnlineARIMA


@dataclass
class AnomalyDetector:
    metrics: Sequence[str] = ("throughput", "consumer_lag")
    p: int = 8
    d: int = 1
    threshold_sigma: float = 4.0
    error_window: int = 120           # window of past errors for the threshold
    min_anomaly_len: int = 2          # consecutive hits to enter anomalous
    recovery_normal_len: int = 3      # consecutive normals to exit

    _models: dict = field(default_factory=dict)
    _errors: dict = field(default_factory=dict)
    _state: str = "normal"
    _anomaly_started: Optional[float] = None
    _hit_streak: int = 0
    _normal_streak: int = 0
    recoveries: list = field(default_factory=list)   # (t_start, t_end)

    def __post_init__(self) -> None:
        for m in self.metrics:
            self._models[m] = OnlineARIMA(p=self.p, d=self.d)
            self._errors[m] = []

    # ------------------------------------------------------------------
    def observe(self, t: float, values: dict, learn: bool = True) -> bool:
        """Feed one metrics sample; returns True if currently anomalous.

        ``learn=False`` freezes coefficient updates *and* the error window
        (used during injected failures so the detector doesn't learn the
        anomaly as normal — the paper trains on positive executions).
        """
        hits = 0
        for m in self.metrics:
            model = self._models[m]
            y = float(values[m])
            if not learn and model.warmed_up:
                pred = model.predict()
                err = abs(y - pred) / max(abs(pred), 1e-6)
            else:
                pred, raw_err = model.update(y)
                err = abs(raw_err) / max(abs(pred), 1e-6)
            window = self._errors[m]
            if model.warmed_up and len(window) >= 10:
                mu = float(np.mean(window))
                sd = float(np.std(window)) + 1e-9
                if err > mu + self.threshold_sigma * sd:
                    hits += 1
            if learn:
                window.append(err)
                if len(window) > self.error_window:
                    window.pop(0)
        return self._advance_state(t, hits > 0)

    def _advance_state(self, t: float, hit: bool) -> bool:
        if self._state == "normal":
            self._hit_streak = self._hit_streak + 1 if hit else 0
            if self._hit_streak >= self.min_anomaly_len:
                self._state = "anomalous"
                self._anomaly_started = t
                self._normal_streak = 0
        else:
            self._normal_streak = self._normal_streak + 1 if not hit else 0
            if self._normal_streak >= self.recovery_normal_len:
                self.recoveries.append((self._anomaly_started, t))
                self._state = "normal"
                self._hit_streak = 0
                self._anomaly_started = None
        return self._state == "anomalous"

    # ------------------------------------------------------------------
    @property
    def anomalous(self) -> bool:
        return self._state == "anomalous"

    def last_recovery_time(self) -> Optional[float]:
        if not self.recoveries:
            return None
        s, e = self.recoveries[-1]
        return e - s

    @property
    def warmed_up(self) -> bool:
        return all(m.warmed_up for m in self._models.values())
