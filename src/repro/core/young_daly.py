"""Young/Daly optimal checkpoint interval — the classic HPC baseline the
paper cites as related work [8–10]; implemented both as a baseline and as
a prior for seeding the profiling grid."""
from __future__ import annotations

import math


def young_daly_interval(checkpoint_cost_s: float, mtbf_s: float,
                        higher_order: bool = True) -> float:
    """W = sqrt(2 * delta * MTBF)  (Young); Daly's higher-order correction
    when delta is not << MTBF."""
    if checkpoint_cost_s <= 0 or mtbf_s <= 0:
        raise ValueError("costs must be positive")
    w = math.sqrt(2.0 * checkpoint_cost_s * mtbf_s)
    if higher_order and checkpoint_cost_s < 2.0 * mtbf_s:
        # Daly 2006: W = sqrt(2 d M) [1 + 1/3 sqrt(d/(2M)) + (1/9)(d/(2M))] - d
        r = math.sqrt(checkpoint_cost_s / (2.0 * mtbf_s))
        w = w * (1.0 + r / 3.0 + (r * r) / 9.0) - checkpoint_cost_s
    return max(w, checkpoint_cost_s)
