"""Phase 3 optimization step — Eq. 8 (paper §III-D):

    min_C   Q_R + Q_L* + |Q_R - Q_L*|
    s.t.    Q_R < 1,  Q_L* < 1,  Q_R, Q_L* > 0

with Q_R = M_R(C, TR_avg)/r_const and Q_L* = p * M_L(C, TR_avg)/l_const.
The objective prefers the CI with the furthest *balanced* distance from
both upper bounds.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.qos_models import QoSModel


@dataclass
class CIOptimization:
    ci: Optional[float]
    feasible: bool
    q_r: float
    q_l: float
    objective: float


def optimize_ci(m_l: QoSModel, m_r: QoSModel, tr_avg: float,
                l_const: float, r_const: float, p: float,
                ci_min: float, ci_max: float, grid: int = 256) -> CIOptimization:
    ci = np.linspace(ci_min, ci_max, grid)
    q_r = m_r.predict(ci, tr_avg) / r_const
    q_l = p * m_l.predict(ci, tr_avg) / l_const
    obj = q_r + q_l + np.abs(q_r - q_l)
    feas = (q_r < 1.0) & (q_l < 1.0) & (q_r > 0.0) & (q_l > 0.0)

    if feas.any():
        masked = np.where(feas, obj, np.inf)
        i = int(np.argmin(masked))
        return CIOptimization(float(ci[i]), True, float(q_r[i]), float(q_l[i]),
                              float(obj[i]))
    # No feasible CI: the paper requires a constraint to be satisfiable to
    # optimize ("reconfigurations are applied sparsely ... CI updates were
    # aborted"); report the least-violating point but flag infeasible.
    viol = np.maximum(q_r - 1, 0) + np.maximum(q_l - 1, 0) + \
        np.maximum(-q_r, 0) + np.maximum(-q_l, 0)
    i = int(np.argmin(viol))
    return CIOptimization(None, False, float(q_r[i]), float(q_l[i]), float(obj[i]))
