"""Phase 3 optimization step — Eq. 8 (paper §III-D):

    min_C   Q_R + Q_L* + |Q_R - Q_L*|
    s.t.    Q_R < 1,  Q_L* < 1,  Q_R, Q_L* > 0

with Q_R = M_R(C, TR_avg)/r_const and Q_L* = p * M_L(C, TR_avg)/l_const.
The objective prefers the CI with the furthest *balanced* distance from
both upper bounds.

``optimize_ci`` is the paper's literal knob (CI only, mechanism fixed).
``optimize_plan`` extends the search to the cross-product of the CI grid
and checkpoint-*mechanism* variants (full vs incremental encoding, sync vs
async commit, multi-level routing with Young/Daly-seeded level cadences):
the fitted M_L/M_R surfaces — measured under the full-sync baseline — are
re-priced per variant with the cost model's duty-cycle and restore-path
deltas, so a Decision can switch mode ("go incremental with full_every=8")
when latency is the binding constraint, not only stretch the interval.

The search is only as honest as the cost model it prices against: pass a
``SimCostModel.from_calibration("BENCH_ckpt.json")`` (measured delta
fractions AND the per-byte host encode CPU) rather than defaults, or the
optimizer will happily pick a delta plan whose encode cost exceeds its
write win on small states.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Callable, Optional, Sequence

import numpy as np

from repro.config import CheckpointPlan
from repro.core.qos_models import QoSModel
from repro.core.young_daly import young_daly_interval

# P(failure kind) — matches ft.failures.FailureModel.kinds
FAILURE_MIX = (("task", 0.30), ("node", 0.65), ("cluster", 0.05))


@dataclass
class CIOptimization:
    ci: Optional[float]
    feasible: bool
    q_r: float
    q_l: float
    objective: float


def optimize_ci(m_l: QoSModel, m_r: QoSModel, tr_avg: float,
                l_const: float, r_const: float, p: float,
                ci_min: float, ci_max: float, grid: int = 256) -> CIOptimization:
    ci = np.linspace(ci_min, ci_max, grid)
    q_r = m_r.predict(ci, tr_avg) / r_const
    q_l = p * m_l.predict(ci, tr_avg) / l_const
    obj = q_r + q_l + np.abs(q_r - q_l)
    feas = (q_r < 1.0) & (q_l < 1.0) & (q_r > 0.0) & (q_l > 0.0)

    if feas.any():
        masked = np.where(feas, obj, np.inf)
        i = int(np.argmin(masked))
        return CIOptimization(float(ci[i]), True, float(q_r[i]), float(q_l[i]),
                              float(obj[i]))
    # No feasible CI: the paper requires a constraint to be satisfiable to
    # optimize ("reconfigurations are applied sparsely ... CI updates were
    # aborted"); report the least-violating point but flag infeasible.
    viol = np.maximum(q_r - 1, 0) + np.maximum(q_l - 1, 0) + \
        np.maximum(-q_r, 0) + np.maximum(-q_l, 0)
    i = int(np.argmin(viol))
    return CIOptimization(None, False, float(q_r[i]), float(q_l[i]), float(obj[i]))


# ---------------------------------------------------------------------------
# Plan-space optimization (mechanism x CI)
# ---------------------------------------------------------------------------

@dataclass
class PlanCandidate:
    plan: CheckpointPlan
    ci: Optional[float]
    feasible: bool
    q_r: float
    q_l: float
    objective: float
    overhead: float        # modeled steady-state checkpoint overhead fraction
    sim: Optional[dict] = None   # simulate-to-verify measurement, if replayed


#: the optimize_plan(verifier=...) contract: given [(plan, ci), ...] return
#: one dict per candidate with measured {"latency_s", "recovery_s", ...} —
#: sim.batched.make_plan_verifier builds one over a BatchedCampaign
PlanVerifier = Callable[[Sequence[tuple[CheckpointPlan, float]]], list]


@dataclass
class PlanOptimization:
    """Best (mechanism, CI) pair plus the full per-variant table; the
    full-sync baseline is kept for the before/after comparison."""
    plan: Optional[CheckpointPlan]
    ci: Optional[float]
    feasible: bool
    q_r: float
    q_l: float
    objective: float
    overhead: float
    baseline: PlanCandidate
    candidates: list
    verified: bool = False   # True when a simulate-to-verify pass re-ranked


def default_plan_variants(cost, ci_ref: float,
                          mtbf_s: float = 3600.0) -> list[CheckpointPlan]:
    """The mechanism grid: full/incremental x sync/async x single/multi
    level x (encode placement x delta codec).  Level cadences are seeded
    with the Young/Daly optimum for that level's write cost — e.g. the
    remote level writes every round(W_yd(remote_cost, MTBF) / CI)-th
    trigger.  The device-placement variants move the ckpt_delta encode in
    front of D2H — priced as one pack dispatch + ONE fused flat-kernel
    encode (``device_pack_s* + device_encode_s*``) instead of the
    per-trigger host-CPU encode, with (for int8) ~4x fewer bytes on the
    link — the dimension a Decision uses to switch a job onto an
    int8-delta plan when the QoS objective favors it; the multi-level
    device variant routes those fused deltas through the memory/local/
    remote cadence as well.  ``replication_factor`` is a searched
    dimension too: the rep0 variant drops peer replication (node
    failures degrade to the remote level — no replica traffic, slower
    node recovery, so it leans on a denser remote cadence), the rep2
    variant pays double replica traffic to tolerate two simultaneous
    host losses — the optimizer genuinely trades replication traffic
    against recovery time."""
    def yd_every(level: str) -> int:
        w = young_daly_interval(cost.write_duration("full", level), mtbf_s)
        return int(np.clip(round(w / max(ci_ref, 1e-9)), 2, 32))

    ml_levels = ("memory", "local", "remote")
    return [
        CheckpointPlan(),                                        # full-sync baseline
        CheckpointPlan(sync=False),                              # full-async
        CheckpointPlan(mode="incremental", full_every=4),
        CheckpointPlan(mode="incremental", full_every=8),
        CheckpointPlan(mode="incremental", full_every=8, sync=False),
        CheckpointPlan(mode="incremental", full_every=8,
                       encode_placement="device"),
        CheckpointPlan(mode="incremental", full_every=8,
                       encode_placement="device", delta_codec="int8"),
        CheckpointPlan(mode="incremental", full_every=8, sync=False,
                       encode_placement="device", delta_codec="int8"),
        CheckpointPlan(levels=ml_levels, local_every=max(1, yd_every("local") // 2),
                       remote_every=yd_every("remote")),
        CheckpointPlan(mode="incremental", full_every=8, levels=ml_levels,
                       local_every=1, remote_every=yd_every("remote")),
        CheckpointPlan(mode="incremental", full_every=8, levels=ml_levels,
                       local_every=1, remote_every=yd_every("remote"),
                       encode_placement="device", delta_codec="int8"),
        # replication dimension: rep0 has no peer replicas, so node
        # failures fall through to remote — it compensates with a denser
        # remote cadence; rep2 survives a simultaneous two-host loss at
        # double the replica traffic
        CheckpointPlan(levels=ml_levels, replication_factor=0,
                       local_every=max(1, yd_every("local") // 2),
                       remote_every=max(2, yd_every("remote") // 2)),
        CheckpointPlan(levels=ml_levels, replication_factor=2,
                       local_every=max(1, yd_every("local") // 2),
                       remote_every=yd_every("remote")),
    ]


def _variant_predictions(m_l: QoSModel, m_r: QoSModel, cost,
                         plans: Sequence[CheckpointPlan], ci: np.ndarray,
                         tr_avg: float, baseline: CheckpointPlan,
                         failure_mix=FAILURE_MIX
                         ) -> tuple[list, list, list]:
    """Re-price the fitted (full-sync) QoS surfaces for EVERY plan variant.

    Latency: the excess over the base latency is driven by the checkpoint
    duty cycle (capacity lost to sync pauses / the async tax), so it is
    scaled by each variant's overhead relative to the baseline's.

    Recovery: lost work is bounded by the cadence of the fastest level
    surviving each failure kind (a cluster failure replays back to the
    last remote full), so M_R is evaluated at the per-kind effective CI
    and shifted by the restore-path downtime delta; kinds are mixed with
    the failure model's probabilities.

    Evaluation is batched across variants: the variant-independent
    pieces (M_L at the grid, the baseline overhead) are computed once,
    and the (variant x kind) M_R reads go through ONE stacked
    ``QoSModel.predict`` — its reduction is row-independent, so the
    per-variant values are bit-identical to per-variant calls.
    """
    if hasattr(cost, "plan_overhead_fractions"):   # vectorized fast path
        o_base = np.asarray(cost.plan_overhead_fractions(baseline, ci))
        o_vs = [np.asarray(cost.plan_overhead_fractions(p, ci))
                for p in plans]
    else:
        o_base = np.array([cost.plan_overhead_fraction(baseline, c)
                           for c in ci])
        o_vs = [np.array([cost.plan_overhead_fraction(p, c) for c in ci])
                for p in plans]
    o_floor = np.maximum(o_base, 1e-9)
    excess = np.maximum(m_l.predict(ci, tr_avg) - cost.base_latency_s, 0.0)
    lats = [cost.base_latency_s + excess * (o_v / o_floor) for o_v in o_vs]

    ci_hi = float(ci.max())
    rows: list[tuple[int, float, float]] = []   # (plan idx, weight, dt)
    ci_effs: list[np.ndarray] = []
    for pi, plan in enumerate(plans):
        for kind, w in failure_mix:
            mult = cost.plan_lost_work_multiplier(plan, kind)
            if not np.isfinite(mult):
                # nothing survives this kind: replay-from-zero — price it
                # as the worst the fitted surface has seen, four CIs out
                ci_effs.append(np.full_like(ci, 4.0 * ci_hi))
            else:
                # avoid wild polynomial extrapolation beyond the fit range
                ci_effs.append(np.minimum(ci * mult, 4.0 * ci_hi))
            rows.append((pi, w, cost.plan_downtime_s(plan, kind)
                         - cost.plan_downtime_s(baseline, kind)))
    preds = m_r.predict(np.concatenate(ci_effs),
                        tr_avg).reshape(len(rows), len(ci))
    recs = [np.zeros_like(ci) for _ in plans]
    for (pi, w, d_downtime), pred in zip(rows, preds):
        recs[pi] = recs[pi] + w * (pred + d_downtime)
    return lats, recs, o_vs


def optimize_plan(m_l: QoSModel, m_r: QoSModel, tr_avg: float,
                  l_const: float, r_const: float, p: float,
                  ci_min: float, ci_max: float, cost,
                  variants: Optional[Sequence[CheckpointPlan]] = None,
                  mtbf_s: float = 3600.0, grid: int = 128,
                  verifier: Optional[PlanVerifier] = None,
                  verify_top_k: int = 3, exhaustive: bool = False,
                  engine: Optional[str] = None) -> PlanOptimization:
    """Eq. 8 over the (CI grid x plan variants) cross-product.

    ``cost`` is a ``sim.costmodel.SimCostModel`` (any object with the
    plan-pricing methods works).  Ties between feasible variants at equal
    objective break toward lower modeled checkpoint overhead.

    With a ``verifier`` (``sim.batched.make_plan_verifier``), the top-k
    feasible candidates are replayed through the batched chaos campaign and
    re-ranked by their MEASURED Eq.-8 objective — the re-priced surfaces
    pick the shortlist, the simulator picks the winner.  Candidates that
    were replayed carry the measurement in ``PlanCandidate.sim``.

    ``exhaustive=True`` drops the shortlist: EVERY feasible variant is
    replayed and ranked by its measured objective.  That many replay lanes
    is what the device engine exists for — pass ``engine="device"`` to
    route the verifier's campaigns through ``sim.device.DeviceCampaign``
    (any verifier exposing a mutable ``engine`` attribute honors it; the
    one from ``make_plan_verifier`` does).  Because exhaustive mode scores
    a superset of the top-k shortlist with the same measurements, its pick
    can only match or improve on the top-k pick's measured objective.
    """
    ci = np.linspace(ci_min, ci_max, grid)
    baseline = CheckpointPlan()
    if variants is None:
        variants = default_plan_variants(cost, ci_ref=float(np.median(ci)),
                                         mtbf_s=mtbf_s)

    candidates: list[PlanCandidate] = []
    lats, recs, o_vs = _variant_predictions(m_l, m_r, cost, list(variants),
                                            ci, tr_avg, baseline)
    for plan, lat, rec, o_v in zip(variants, lats, recs, o_vs):
        q_r = rec / r_const
        q_l = p * lat / l_const
        obj = q_r + q_l + np.abs(q_r - q_l)
        feas = (q_r < 1.0) & (q_l < 1.0) & (q_r > 0.0) & (q_l > 0.0)
        if feas.any():
            masked = np.where(feas, obj, np.inf)
            i = int(np.argmin(masked))
            candidates.append(PlanCandidate(
                replace(plan, interval_s=float(ci[i])), float(ci[i]), True,
                float(q_r[i]), float(q_l[i]), float(obj[i]), float(o_v[i])))
        else:
            viol = np.maximum(q_r - 1, 0) + np.maximum(q_l - 1, 0) + \
                np.maximum(-q_r, 0) + np.maximum(-q_l, 0)
            i = int(np.argmin(viol))
            candidates.append(PlanCandidate(
                plan, None, False, float(q_r[i]), float(q_l[i]),
                float(obj[i]), float(o_v[i])))

    base_cand = candidates[0] if variants and variants[0].name == baseline.name \
        else next((c for c in candidates if c.plan.name == baseline.name),
                  candidates[0])
    feasible = [c for c in candidates if c.feasible]
    if feasible:
        best = min(feasible, key=lambda c: (c.objective, c.overhead))
        verified = False
        if verifier is not None and engine is not None \
                and hasattr(verifier, "engine"):
            verifier.engine = engine
        if exhaustive:
            verify_top_k = len(feasible)
        if verifier is not None and verify_top_k > 0:
            sim_best = _verify_candidates(
                feasible, verifier, verify_top_k, l_const, r_const, p)
            # only claim a verified pick when the simulator accepted one;
            # otherwise keep the surface winner, unverified
            if sim_best is not None:
                best, verified = sim_best, True
        return PlanOptimization(best.plan, best.ci, True, best.q_r, best.q_l,
                                best.objective, best.overhead, base_cand,
                                candidates, verified=verified)
    least = min(candidates, key=lambda c: c.objective)
    return PlanOptimization(None, None, False, least.q_r, least.q_l,
                            least.objective, least.overhead, base_cand,
                            candidates)


def _verify_candidates(feasible: list, verifier: PlanVerifier, top_k: int,
                       l_const: float, r_const: float, p: float
                       ) -> Optional[PlanCandidate]:
    """Simulate-to-verify: replay the surface-ranked top-k through the
    batched campaign, score the measurements with the same Eq.-8 objective,
    and pick the sim-best among the sim-feasible (falling back to the
    surface ranking when the simulator rejects every shortlisted plan)."""
    short = sorted(feasible, key=lambda c: (c.objective, c.overhead))[:top_k]
    results = verifier([(c.plan, c.ci) for c in short])
    sim_ranked: list[tuple[float, PlanCandidate]] = []
    for cand, meas in zip(short, results):
        q_r = meas["recovery_s"] / r_const
        q_l = p * meas["latency_s"] / l_const
        obj = q_r + q_l + abs(q_r - q_l)
        feas = 0.0 < q_r < 1.0 and 0.0 < q_l < 1.0
        cand.sim = dict(meas, q_r=q_r, q_l=q_l, objective=obj, feasible=feas)
        if feas:
            sim_ranked.append((obj, cand))
    if not sim_ranked:
        return None
    return min(sim_ranked, key=lambda t: (t[0], t[1].overhead))[1]
