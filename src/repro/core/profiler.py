"""Phase 2 — experimentation & profiling (paper §III-C).

``z`` short-lived deployments (one per candidate CI) replay the recorded
workload; at each of the ``m`` failure points a failure is injected at the
WORST CASE instant — just before the next checkpoint completes — and the
recovery time is measured from the consumer-lag envelope (the online-ARIMA
detector runs alongside on the scalar path as a secondary measurement).
The average latency is sampled just before each injection.

Two execution substrates implement the profiling contract:

* ``Deployment`` (``sim.SimDeployment`` / ``runtime.LiveDeployment``) —
  one pipeline per CI, profiled point-by-point via ``run_profiling``;
* ``CampaignDeployment`` (``sim.BatchedDeployment``) — the whole z x m
  grid as array lanes of ONE vectorized campaign, via
  ``run_profiling_campaign``.

The paper runs deployments in parallel on Kubernetes; the batched campaign
maps those parallel VMs onto simulator lanes, so the full grid advances in
one fused sweep — the former "deployments execute sequentially" deviation
(DESIGN.md §7.6) is retired; the sequential path remains as the oracle and
for live (subprocess) deployments that cannot be vectorized.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Protocol

import numpy as np

from repro.core.steady_state import SteadyState


class Deployment(Protocol):
    """One profiling pipeline with a fixed checkpoint-interval config."""

    def profile_failure(self, failure_time: float, margin: float) -> tuple[float, float]:
        """Replay [failure_time - margin, failure_time + horizon] and inject a
        failure at the worst-case instant near ``failure_time``.

        Returns (avg_latency_before_failure_s, recovery_time_s).
        """
        ...


class CampaignDeployment(Protocol):
    """All z CIs x m failure points profiled in one batched sweep."""

    def profile_campaign(self, failure_times, ci_values, margin: float
                         ) -> tuple[np.ndarray, np.ndarray]:
        """Returns ((m, z) latencies, (m, z) recoveries) for the full grid."""
        ...


@dataclass
class ProfilingResult:
    ci_values: np.ndarray      # C  (z,)
    failure_rates: np.ndarray  # TR (m,)
    latencies: np.ndarray      # L  (m, z)
    recoveries: np.ndarray     # R  (m, z)

    def flat(self) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """(ci, tr, l, r) flattened for model fitting."""
        m, z = self.latencies.shape
        ci = np.tile(self.ci_values[None, :], (m, 1)).ravel()
        tr = np.tile(self.failure_rates[:, None], (1, z)).ravel()
        return ci, tr, self.latencies.ravel(), self.recoveries.ravel()


def run_profiling(deployment_factory: Callable[[float], Deployment],
                  steady: SteadyState, ci_values, margin: float = 90.0,
                  progress: Callable[[str], None] | None = None) -> ProfilingResult:
    ci_values = np.asarray(ci_values, np.float64)
    m = len(steady.failure_times)
    z = len(ci_values)
    L = np.zeros((m, z))
    R = np.zeros((m, z))
    for j, ci in enumerate(ci_values):
        dep = deployment_factory(float(ci))
        for i, ft in enumerate(steady.failure_times):
            lat, rec = dep.profile_failure(float(ft), margin)
            L[i, j] = lat
            R[i, j] = rec
            if progress:
                progress(f"profiled ci={ci:.0f}s fp#{i} tr={steady.failure_rates[i]:.0f}ev/s "
                         f"-> lat={lat*1e3:.0f}ms rec={rec:.0f}s")
    return ProfilingResult(ci_values, steady.failure_rates.copy(), L, R)


def run_profiling_campaign(campaign: CampaignDeployment, steady: SteadyState,
                           ci_values, margin: float = 90.0,
                           progress: Callable[[str], None] | None = None
                           ) -> ProfilingResult:
    """Phase 2 in one sweep: every (CI, failure point) cell is a lane of a
    single batched campaign (``sim.BatchedDeployment``), statistics
    identical to the sequential loop above."""
    ci_values = np.asarray(ci_values, np.float64)
    L, R = campaign.profile_campaign(steady.failure_times, ci_values, margin)
    assert L.shape == (len(steady.failure_times), len(ci_values)), L.shape
    if progress:
        progress(f"campaign profiled {L.size} (ci, failure-point) lanes in "
                 f"one sweep: rec {R.min():.0f}-{R.max():.0f}s")
    return ProfilingResult(ci_values, steady.failure_rates.copy(), L, R)
