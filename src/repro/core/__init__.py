"""Khaos core: the paper's contribution (chaos-engineering-driven runtime
optimization of the checkpoint interval).

Phase 1  steady_state     — workload recording analysis, failure-point selection
Phase 2  profiler          — parallel profiling deployments + worst-case failure
                             injection; anomaly-detector recovery measurement
Phase 3  qos_models        — M_L / M_R multivariate regression + rescaling p
         forecast          — TSF deferral rule
         ci_optimizer      — Eq. 8 multi-objective CI selection
         controller        — the runtime optimization loop + the JobHandle
                             protocol every supervised substrate implements
         runtime           — KhaosRuntime, the phase machine sequencing
                             1 -> 2 -> 3 against any JobHandle (single job
                             or controller-in-the-loop batched campaigns)

The control plane runs host-side (NumPy) — it supervises the JAX data plane
(the distributed training/serving job), exactly as the paper's controller
supervises Flink from outside the cluster.
"""
from repro.core.arima import OnlineARIMA
from repro.core.anomaly import AnomalyDetector
from repro.core.steady_state import select_failure_points, SteadyState
from repro.core.qos_models import (QoSModel, RescalingTracker,
                                   demo_prior_models)
from repro.core.forecast import WorkloadForecaster
from repro.core.ci_optimizer import (optimize_ci, optimize_plan,
                                     default_plan_variants, PlanCandidate,
                                     PlanOptimization)
from repro.core.controller import (Decision, JobHandle, JOB_HANDLE_METHODS,
                                   KhaosController)
from repro.core.young_daly import young_daly_interval
from repro.core.profiler import (run_profiling, run_profiling_campaign,
                                 ProfilingResult)
from repro.core.runtime import (CampaignSupervision, KhaosRuntime,
                                missing_handle_methods, PhaseError,
                                PhaseEvent, PHASES)

__all__ = [
    "OnlineARIMA", "AnomalyDetector", "select_failure_points", "SteadyState",
    "QoSModel", "RescalingTracker", "demo_prior_models",
    "WorkloadForecaster", "optimize_ci",
    "optimize_plan", "default_plan_variants", "PlanCandidate",
    "PlanOptimization", "Decision", "JobHandle", "JOB_HANDLE_METHODS",
    "KhaosController", "young_daly_interval",
    "run_profiling", "run_profiling_campaign", "ProfilingResult",
    "CampaignSupervision", "KhaosRuntime", "missing_handle_methods",
    "PhaseError", "PhaseEvent", "PHASES",
]
