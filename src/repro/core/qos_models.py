"""Phase 3 models — performance model M_L : (C, TR) -> L and recovery-time
model M_R : (C, TR) -> R (paper §III-D): multivariate polynomial ridge
regression, plus the prediction-rescaling factor ``p``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np


def _features(ci_n: np.ndarray, tr_n: np.ndarray, ci_raw: np.ndarray,
              degree: int, rational: bool) -> np.ndarray:
    """Design matrix over (ci, tr): full polynomial of ``degree`` plus
    (optionally) rational terms in CI.  Checkpoint economics are rational:
    per-checkpoint overhead scales with 1/CI while lost work scales with CI,
    so 1/ci and tr/ci features capture the recovery/latency surfaces that a
    plain quadratic cannot (this is still "multivariate regression" in the
    paper's sense — only the basis is richer)."""
    if degree == 2:
        # explicit degree-2 columns: same values as the generic loop
        # (integer powers 0/1/2 reduce to 1, x, x*x bit-exactly), ~2x
        # fewer ufunc dispatches on the controllers' per-poll hot path
        cols = [np.ones_like(ci_n), ci_n, tr_n,
                ci_n * ci_n, ci_n * tr_n, tr_n * tr_n]
    else:
        cols = [np.ones_like(ci_n)]
        for dtot in range(1, degree + 1):
            for i in range(dtot + 1):
                cols.append((ci_n ** (dtot - i)) * (tr_n ** i))
    if rational:
        inv = 1.0 / np.maximum(ci_raw, 1e-9)
        cols.append(inv)
        cols.append(inv * tr_n)
        cols.append(inv * inv)
    out = np.empty(np.shape(ci_n) + (len(cols),))
    for j, c in enumerate(cols):
        out[..., j] = c
    return out


@dataclass
class QoSModel:
    """Ridge regression y ~ basis(ci, tr)."""
    degree: int = 2
    ridge_lambda: float = 1e-3
    rational: bool = True
    _beta: Optional[np.ndarray] = None
    _mu: Optional[np.ndarray] = None
    _sd: Optional[np.ndarray] = None

    def _design(self, ci: np.ndarray, tr: np.ndarray) -> np.ndarray:
        return _features((ci - self._mu[0]) / self._sd[0],
                         (tr - self._mu[1]) / self._sd[1],
                         ci, self.degree, self.rational)

    def fit(self, ci: np.ndarray, tr: np.ndarray, y: np.ndarray) -> "QoSModel":
        ci, tr, y = map(lambda a: np.asarray(a, np.float64).ravel(), (ci, tr, y))
        self._mu = np.array([ci.mean(), tr.mean()])
        self._sd = np.array([ci.std() + 1e-9, tr.std() + 1e-9])
        X = self._design(ci, tr)
        lam = self.ridge_lambda * np.eye(X.shape[1])
        lam[0, 0] = 0.0   # don't penalize the intercept
        self._beta = np.linalg.solve(X.T @ X + lam, X.T @ y)
        return self

    def predict(self, ci, tr) -> np.ndarray:
        assert self._beta is not None, "fit first"
        ci = np.asarray(ci, np.float64)
        tr = np.broadcast_to(np.asarray(tr, np.float64), ci.shape)
        # row-independent reduction (not BLAS matmul): each prediction is
        # its own pairwise sum, so predicting a stacked batch of (ci, tr)
        # rows is BIT-identical to predicting them one at a time — the
        # property the controller's shared per-period evaluation
        # (KhaosRuntime.drive_campaign) relies on
        return (self._design(ci, tr) * self._beta).sum(axis=-1)

    def predict_pair(self, other: "QoSModel", ci, tr
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Evaluate this model AND ``other`` with one design matrix.

        Valid whenever both models share basis and normalization (the
        runtime fits M_L and M_R on the same profiling grid, so they
        do); asserted cheaply.  Each output is bit-identical to the
        model's own ``predict`` — same features, same reduction — this
        just halves the feature-building cost on the controllers'
        per-poll hot path.  Falls back to two plain predicts when the
        normalizations differ."""
        if not (self.degree == other.degree
                and self.rational == other.rational
                and np.array_equal(self._mu, other._mu)
                and np.array_equal(self._sd, other._sd)):
            return self.predict(ci, tr), other.predict(ci, tr)
        assert self._beta is not None and other._beta is not None, "fit first"
        ci = np.asarray(ci, np.float64)
        tr = np.broadcast_to(np.asarray(tr, np.float64), ci.shape)
        X = self._design(ci, tr)
        return (X * self._beta).sum(axis=-1), (X * other._beta).sum(axis=-1)

    def avg_percent_error(self, ci, tr, y) -> float:
        """The paper's post-execution error analysis (Tables II(a)/III(a))."""
        pred = self.predict(np.asarray(ci, np.float64), np.asarray(tr, np.float64))
        y = np.asarray(y, np.float64).ravel()
        return float(np.mean(np.abs(pred - y) / np.maximum(np.abs(y), 1e-9)))

    # -- persistence (fleet.QoSModelRegistry round-trip) ---------------------
    def to_dict(self) -> dict:
        """JSON-safe dump of a FITTED model (hyperparameters + solution)."""
        assert self._beta is not None, "fit first"
        return {"degree": self.degree, "ridge_lambda": self.ridge_lambda,
                "rational": self.rational, "beta": self._beta.tolist(),
                "mu": self._mu.tolist(), "sd": self._sd.tolist()}

    @classmethod
    def from_dict(cls, d: dict) -> "QoSModel":
        m = cls(degree=int(d["degree"]), ridge_lambda=float(d["ridge_lambda"]),
                rational=bool(d["rational"]))
        m._beta = np.asarray(d["beta"], np.float64)
        m._mu = np.asarray(d["mu"], np.float64)
        m._sd = np.asarray(d["sd"], np.float64)
        return m


def demo_prior_models(ci_lo: float = 5.0, ci_hi: float = 60.0,
                      tr_lo: float = 100.0, tr_hi: float = 800.0,
                      n: int = 64, seed: int = 0
                      ) -> tuple[QoSModel, QoSModel]:
    """Prior-fitted (M_L, M_R) for demos and smoke paths that skip
    Phases 1-2 (installed via ``KhaosRuntime.install_models``): a latency
    surface falling with CI and a recovery surface growing with CI — the
    one source for the recipe ``examples/train_stream.py`` and
    ``launch/train.py --khaos`` share."""
    rng = np.random.default_rng(seed)
    ci = rng.uniform(ci_lo, ci_hi, n)
    tr = rng.uniform(tr_lo, tr_hi, n)
    m_l = QoSModel().fit(ci, tr, 0.05 + 2.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 4.0 + 1.0 * ci + tr * 5e-3)
    return m_l, m_r


@dataclass
class RescalingTracker:
    """The paper's correction factor p: average of the k pairwise fractional
    differences between observed latencies and model predictions, used to
    localize M_L to current cluster conditions."""
    k: int = 5
    _pairs: list = field(default_factory=list)

    def track(self, observed: float, predicted: float) -> None:
        if predicted > 1e-12:
            self._pairs.append(observed / predicted)
            if len(self._pairs) > self.k:
                self._pairs.pop(0)

    @property
    def p(self) -> float:
        return float(np.mean(self._pairs)) if self._pairs else 1.0
