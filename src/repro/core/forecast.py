"""Phase 3 TSF — multi-step-ahead workload forecast and the deferral rule
(paper §III-D): if the incoming message rate is expected to decrease by
more than ``defer_drop_fraction`` (10%) before the next optimization cycle,
the reconfiguration decision is deferred.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.arima import OnlineARIMA


@dataclass
class WorkloadForecaster:
    horizon: int = 5
    defer_drop_fraction: float = 0.10
    p: int = 12
    d: int = 1
    _model: OnlineARIMA = field(default=None)
    _last: float = 0.0

    def __post_init__(self) -> None:
        if self._model is None:
            self._model = OnlineARIMA(p=self.p, d=self.d, lr=0.05)

    def observe(self, rate: float) -> None:
        self._model.update(float(rate))
        self._last = float(rate)

    def forecast(self, steps: int = 0) -> np.ndarray:
        return self._model.forecast(steps or self.horizon)

    def should_defer(self) -> bool:
        """True when the forecasted rate drops > defer fraction vs now."""
        if not self._model.warmed_up or self._last <= 0:
            return False
        fc = self.forecast()
        future = float(np.min(fc))   # most optimistic drop within the horizon
        return future < (1.0 - self.defer_drop_fraction) * self._last

    @property
    def warmed_up(self) -> bool:
        return self._model.warmed_up

    def predicted_peak(self) -> float:
        """Highest forecasted rate within the horizon — the load the
        proactive controller must already satisfy when it arrives.  Before
        warm-up (or with no positive observation yet) the forecast is
        meaningless, so the last observation stands in: the proactive rule
        then degenerates to the reactive one instead of acting on noise."""
        if not self._model.warmed_up or self._last <= 0:
            return self._last
        return float(np.max(self.forecast()))
