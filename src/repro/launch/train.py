"""Production training launcher: assembles mesh + sharding + jit'd step for
a real TPU slice, or falls back to the CPU-scale resilient trainer for
local runs.

    # local (CPU, reduced config, real checkpoints/failures):
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --local \
        --duration 60

    # TPU pod (lowers the sharded step exactly as the dry-run proves):
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --shape train_4k
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--local", action="store_true",
                    help="CPU-scale run with the reduced config")
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ci", type=float, default=30.0)
    ap.add_argument("--khaos", action="store_true",
                    help="local runs: supervise with a KhaosRuntime "
                         "(prior-fitted QoS models) through TrainerJobHandle")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.local:
        from repro.config import KhaosConfig, OptimizerConfig
        from repro.configs import get_smoke_config
        from repro.core import KhaosRuntime, demo_prior_models
        from repro.data.stream import EventStream, diurnal_rate
        from repro.runtime import (ResilientTrainer, TrainerConfig,
                                   TrainerJobHandle)

        cfg = get_smoke_config(args.arch)
        stream = EventStream(schedule=diurnal_rate(base=400.0, period=600.0))
        tcfg = TrainerConfig(batch=8, seq_len=32, ckpt_dir=args.ckpt_dir,
                             ckpt_interval_s=args.ci, ckpt_async=True,
                             time_scale=8.0)
        trainer = ResilientTrainer(cfg, tcfg, stream,
                                   OptimizerConfig(total_steps=10_000))
        on_second = None
        if args.khaos:
            rt = KhaosRuntime(KhaosConfig(latency_constraint=1.0,
                                          recovery_constraint=30.0,
                                          optimization_period=10.0,
                                          ci_min=5, ci_max=60))
            rt.install_models(*demo_prior_models())
            rt.attach(TrainerJobHandle(trainer))
            on_second = lambda sample: rt.step()
        summary = trainer.run(args.duration, on_second=on_second)
        print(summary)
        return

    # TPU path: identical plumbing to the dry-run, but with real devices.
    import jax

    from repro.config import SHAPES_BY_NAME, OptimizerConfig, ShardingConfig
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.models import zoo
    from repro.optim import make_optimizer
    from repro.sharding import ShardingRules

    cfg = get_config(args.arch)
    shape = SHAPES_BY_NAME[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    rules = ShardingRules(cfg, mesh, ShardingConfig())
    opt_cfg = OptimizerConfig()
    opt = make_optimizer(opt_cfg)
    step = zoo.make_train_step(cfg, opt, opt_cfg,
                               accum=max(1, shape.global_batch // rules.dp_size),
                               ann=rules.annotator())
    state_specs = zoo.state_specs(cfg, opt)
    batch_specs = zoo.input_specs(cfg, shape)
    out = jax.eval_shape(step, state_specs, batch_specs)
    jitted = jax.jit(
        step,
        in_shardings=(rules.state_shardings(state_specs),
                      rules.batch_shardings(batch_specs)),
        out_shardings=(rules.state_shardings(out[0]),
                       jax.tree_util.tree_map(lambda _: rules.replicated(),
                                              out[1])),
        donate_argnums=0)
    compiled = jitted.lower(state_specs, batch_specs).compile()
    print("compiled train step:", compiled.memory_analysis())
    print("ready — wire a StreamingBatcher + CheckpointManager + "
          "KhaosRuntime/TrainerJobHandle exactly as runtime/trainer.py "
          "and examples/train_stream.py do.")


if __name__ == "__main__":
    main()
