"""Serving launcher: compile the sharded prefill/decode steps for a
production mesh (TPU) or run the CPU-scale batched server.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --local
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--local", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.local:
        import jax
        import numpy as np

        from repro.configs import get_smoke_config
        from repro.models import zoo
        from repro.runtime.server import ServeRequest, StreamServer

        cfg = get_smoke_config(args.arch)
        params = zoo.init_params(cfg, jax.random.PRNGKey(0))
        srv = StreamServer(cfg, params)
        rng = np.random.default_rng(0)
        reqs = [ServeRequest(i, rng.integers(0, cfg.vocab_size, 16,
                                             dtype=np.int32), 8)
                for i in range(4)]
        print({k: v.tolist() for k, v in srv.serve_batch(reqs).items()})
        return

    import jax

    from repro.config import SHAPES_BY_NAME, ShardingConfig
    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.models import zoo
    from repro.sharding import ShardingRules
    from functools import partial

    cfg = get_config(args.arch)
    shape = SHAPES_BY_NAME[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    rules = ShardingRules(cfg, mesh, ShardingConfig())
    params = jax.eval_shape(partial(zoo.init_params, cfg), jax.random.PRNGKey(0))
    caches = zoo.cache_specs(cfg, shape)
    inputs = zoo.input_specs(cfg, shape)
    fn = zoo.make_decode_step(cfg, ann=rules.annotator())
    out = jax.eval_shape(fn, params, caches, inputs)
    jitted = jax.jit(fn,
                     in_shardings=(rules.params_shardings(params),
                                   rules.cache_shardings(caches),
                                   rules.batch_shardings(inputs)),
                     out_shardings=(rules.dp_vector(out[0].shape),
                                    rules.cache_shardings(out[1])),
                     donate_argnums=1)
    compiled = jitted.lower(params, caches, inputs).compile()
    print("compiled decode step:", compiled.memory_analysis())


if __name__ == "__main__":
    main()
