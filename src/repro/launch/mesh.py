"""Production mesh construction.

Functions (not module-level constants) so importing this module never
touches jax device state.  The production target is a TPU v5e pod of
16x16 = 256 chips; the multi-pod mesh stacks 2 pods on a leading 'pod'
axis (512 chips) connected by slower inter-pod links.
"""
from __future__ import annotations

import jax

from repro.config import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axis_names)


def make_test_mesh(data: int = 2, model: int = 2, pods: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_abstract_mesh(shape, axis_names):
    """Version-compatible ``AbstractMesh`` construction.

    JAX >= 0.5 takes split (axis_sizes, axis_names) args; 0.4.x takes a
    single tuple of (name, size) pairs.  Sharding-rule logic only needs
    ``.shape``/``.axis_names``, which both constructions provide.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(shape), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, shape)))
