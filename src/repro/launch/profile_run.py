"""Phase-2 profiling launcher: record a workload, run the z x m profiling
grid, and emit the (C, TR, L, R) grids + fitted QoS models — sequenced by
the ``KhaosRuntime`` phase machine (Phase 1 -> Phase 2).

By default the whole grid runs as lanes of ONE batched campaign
(``sim.BatchedDeployment`` — the paper's parallel Kubernetes deployments
mapped onto vectorized simulator state); ``--sequential`` keeps the
one-pipeline-per-CI oracle path (the ``Deployment`` protocol also accepts
cluster-backed implementations unchanged).

    PYTHONPATH=src python -m repro.launch.profile_run --ci 10,30,60,90,120 \
        --out experiments/profiling.json
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.config import KhaosConfig
from repro.core import KhaosRuntime
from repro.data.stream import diurnal_rate, record_workload
from repro.sim import BatchedDeployment, SimCostModel, SimDeployment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ci", default="10,30,60,90,120")
    ap.add_argument("--failure-points", type=int, default=5)
    ap.add_argument("--record-seconds", type=float, default=14_400.0)
    ap.add_argument("--capacity", type=float, default=4600.0)
    ap.add_argument("--ckpt-duration", type=float, default=3.0)
    ap.add_argument("--margin", type=float, default=90.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sequential", action="store_true",
                    help="one deployment per CI (the scalar oracle path) "
                         "instead of the batched campaign")
    ap.add_argument("--out", default="experiments/profiling.json")
    args = ap.parse_args()

    sched = diurnal_rate(base=0.5 * args.capacity, amplitude=0.55,
                         period=args.record_seconds, seed=args.seed)
    recording = record_workload(sched, duration=args.record_seconds,
                                seed=args.seed)
    cost = SimCostModel(capacity_eps=args.capacity,
                        ckpt_duration_s=args.ckpt_duration,
                        ckpt_sync_penalty=0.6)
    ci_values = [float(x) for x in args.ci.split(",")]

    rt = KhaosRuntime(KhaosConfig(num_failure_points=args.failure_points,
                                  ci_min=min(ci_values), ci_max=max(ci_values),
                                  num_configs=len(ci_values)))
    rt.record_steady_state(recording)
    deployment = (lambda ci: SimDeployment(ci, recording, cost)) \
        if args.sequential else BatchedDeployment(cost, recording)
    prof = rt.run_profiling(deployment, ci_values, margin=args.margin,
                            progress=lambda m: print("  " + m, flush=True))

    ci_f, tr_f, L_f, R_f = prof.flat()
    out = {
        "ci_values": ci_values,
        "failure_rates": prof.failure_rates.tolist(),
        "latencies": prof.latencies.tolist(),
        "recoveries": prof.recoveries.tolist(),
        "m_l_pct_error": rt.m_l.avg_percent_error(ci_f, tr_f, L_f),
        "m_r_pct_error": rt.m_r.avg_percent_error(ci_f, tr_f, R_f),
        "phases": rt.phase_sequence(),
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nM_L pct error {out['m_l_pct_error']:.3f}  "
          f"M_R pct error {out['m_r_pct_error']:.3f}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
