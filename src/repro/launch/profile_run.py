"""Phase-2 profiling launcher: record a workload, spin up the z parallel
profiling deployments (simulator-backed on this host; the Deployment
protocol accepts cluster-backed implementations unchanged), inject
worst-case failures and emit the (C, TR, L, R) grids + fitted QoS models.

    PYTHONPATH=src python -m repro.launch.profile_run --ci 10,30,60,90,120 \
        --out experiments/profiling.json
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core import QoSModel, run_profiling, select_failure_points
from repro.data.stream import diurnal_rate, record_workload
from repro.sim import SimCostModel, SimDeployment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ci", default="10,30,60,90,120")
    ap.add_argument("--failure-points", type=int, default=5)
    ap.add_argument("--record-seconds", type=float, default=14_400.0)
    ap.add_argument("--capacity", type=float, default=4600.0)
    ap.add_argument("--ckpt-duration", type=float, default=3.0)
    ap.add_argument("--margin", type=float, default=90.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/profiling.json")
    args = ap.parse_args()

    sched = diurnal_rate(base=0.5 * args.capacity, amplitude=0.55,
                         period=args.record_seconds, seed=args.seed)
    recording = record_workload(sched, duration=args.record_seconds,
                                seed=args.seed)
    steady = select_failure_points(recording, m=args.failure_points,
                                   smoothing_window=30)
    cost = SimCostModel(capacity_eps=args.capacity,
                        ckpt_duration_s=args.ckpt_duration,
                        ckpt_sync_penalty=0.6)
    ci_values = [float(x) for x in args.ci.split(",")]
    prof = run_profiling(
        lambda ci: SimDeployment(ci, recording, cost),
        steady, ci_values, margin=args.margin,
        progress=lambda m: print("  " + m, flush=True))

    ci_f, tr_f, L_f, R_f = prof.flat()
    m_l = QoSModel().fit(ci_f, tr_f, L_f)
    m_r = QoSModel().fit(ci_f, tr_f, R_f)
    out = {
        "ci_values": ci_values,
        "failure_rates": prof.failure_rates.tolist(),
        "latencies": prof.latencies.tolist(),
        "recoveries": prof.recoveries.tolist(),
        "m_l_pct_error": m_l.avg_percent_error(ci_f, tr_f, L_f),
        "m_r_pct_error": m_r.avg_percent_error(ci_f, tr_f, R_f),
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"\nM_L pct error {out['m_l_pct_error']:.3f}  "
          f"M_R pct error {out['m_r_pct_error']:.3f}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
