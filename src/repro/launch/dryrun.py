import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -----------------------------------------
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402
from dataclasses import replace as dc_replace   # noqa: E402

import jax          # noqa: E402
import numpy as np  # noqa: E402

from repro.config import (ALL_SHAPES, SHAPES_BY_NAME, OptimizerConfig,   # noqa: E402
                          ShardingConfig, applicable_shapes)
from repro.configs import ARCH_IDS, get_config                            # noqa: E402
from repro.launch.mesh import make_production_mesh                        # noqa: E402
from repro.models import zoo                                              # noqa: E402
from repro.optim import make_optimizer                                    # noqa: E402
from repro.roofline import analyze_hlo_text, model_flops, roofline_terms  # noqa: E402
from repro.sharding import ShardingRules                                  # noqa: E402

"""Multi-pod dry-run: ``.lower().compile()`` every (arch x shape x mesh)
cell against placeholder devices, record memory / cost / roofline terms.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out experiments/dryrun.json
"""


def default_plan(arch: str, shape_name: str, dp_total: int) -> dict:
    """Per-cell feasibility plan (microbatching / optimizer-state dtypes).

    These are the *baseline* settings; §Perf hillclimb overrides arrive via
    --plan or --recommended.
    """
    plan = {"accum": 1, "state_dtype": "float32", "accum_dtype": "float32",
            "remat": None, "sharding": {}}
    if shape_name == "train_4k":
        batch = 256
        plan["accum"] = max(1, batch // dp_total)       # 1-seq-per-device microbatches
        if arch == "grok-1-314b":
            plan["state_dtype"] = "bfloat16"            # m/v in bf16 (316B params)
            plan["accum_dtype"] = "bfloat16"
            plan["remat"] = "full"
    return plan


# §Perf winners (EXPERIMENTS.md): head padding for TP-unfriendly head
# counts, larger flash chunks, sequence parallelism for train, int8 KV for
# decode, MoE capacity 1.0 for grok.
_PAD_HEADS = {"qwen2.5-14b": 48, "qwen2-vl-7b": 32}


def recommended_plan(arch: str, shape_name: str, dp_total: int) -> dict:
    plan = default_plan(arch, shape_name, dp_total)
    if arch in _PAD_HEADS:
        plan["num_heads"] = _PAD_HEADS[arch]
    if shape_name in ("train_4k", "prefill_32k"):
        plan["attn_chunk_q"] = 1024
        plan["attn_chunk_kv"] = 4096
    if shape_name == "train_4k":
        # SP shards the hidden SEQ dim — poison for time-sequential mixers
        # (WKV / RG-LRU scans reshard every chunk): measured 5x regression
        # on rwkv6, so recurrent families stay batch-sharded.
        if arch not in ("rwkv6-3b", "recurrentgemma-2b"):
            plan["sharding"] = {"seq_shard_hidden": True}
        if arch not in ("grok-1-314b",):
            plan["accum"] = max(1, min(8, 256 // dp_total))
    if shape_name == "decode_32k" and arch not in ("rwkv6-3b",):
        plan["kv_cache_dtype"] = "int8"
    if arch == "grok-1-314b":
        plan["capacity_factor"] = 1.0
    return plan


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               plan: dict | None = None, recommended: bool = False):
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp_total = int(np.prod([mesh.shape[a] for a in mesh.axis_names if a != "model"]))
    maker = recommended_plan if recommended else default_plan
    p = maker(arch, shape_name, dp_total)
    p.update(plan or {})
    if "num_heads" in p and "head_dim" not in p:
        # head padding must preserve the ORIGINAL head_dim (function-
        # preserving: extra heads have zero-init wo rows); otherwise the
        # derived d_model//num_heads silently changes the architecture.
        p["head_dim"] = cfg.resolved_head_dim
    if p.get("remat"):
        cfg = dc_replace(cfg, remat_policy=p["remat"])
    for k in ("attn_chunk_q", "attn_chunk_kv", "kv_cache_dtype", "attn_impl",
              "num_heads", "head_dim", "param_dtype"):
        if k in p:
            cfg = dc_replace(cfg, **{k: p[k]})
    if "capacity_factor" in p and cfg.moe is not None:
        cfg = dc_replace(cfg, moe=dc_replace(cfg.moe,
                                             capacity_factor=p["capacity_factor"]))
    scfg = ShardingConfig(**p.get("sharding", {}))
    rules = ShardingRules(cfg, mesh, scfg)
    ann = rules.annotator()

    if shape.mode == "train":
        opt_cfg = OptimizerConfig(state_dtype=p["state_dtype"])
        opt = make_optimizer(opt_cfg)
        fn = zoo.make_train_step(cfg, opt, opt_cfg, accum=p["accum"], ann=ann,
                                 accum_dtype=p["accum_dtype"])
        state = zoo.state_specs(cfg, opt)
        batch = zoo.input_specs(cfg, shape)
        in_sh = (rules.state_shardings(state), rules.batch_shardings(batch))
        out_struct = jax.eval_shape(fn, state, batch)
        out_sh = (rules.state_shardings(out_struct[0]),
                  jax.tree_util.tree_map(lambda _: rules.replicated(), out_struct[1]))
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=0)
        args = (state, batch)
    elif shape.mode == "prefill":
        from functools import partial
        params = jax.eval_shape(partial(zoo.init_params, cfg), jax.random.PRNGKey(0))
        inputs = zoo.input_specs(cfg, shape)
        fn = zoo.make_prefill_step(cfg, ann=ann)
        in_sh = (rules.params_shardings(params), rules.batch_shardings(inputs))
        out_struct = jax.eval_shape(fn, params, inputs)
        out_sh = (rules.dp_vector(out_struct[0].shape),
                  rules.cache_shardings(out_struct[1]))
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        args = (params, inputs)
    else:  # decode
        from functools import partial
        params = jax.eval_shape(partial(zoo.init_params, cfg), jax.random.PRNGKey(0))
        caches = zoo.cache_specs(cfg, shape)
        inputs = zoo.input_specs(cfg, shape)
        fn = zoo.make_decode_step(cfg, ann=ann)
        in_sh = (rules.params_shardings(params), rules.cache_shardings(caches),
                 rules.batch_shardings(inputs))
        out_struct = jax.eval_shape(fn, params, caches, inputs)
        out_sh = (rules.dp_vector(out_struct[0].shape),
                  rules.cache_shardings(out_struct[1]))
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=1)
        args = (params, caches, inputs)
    return cfg, shape, mesh, jitted, args, p


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             plan: dict | None = None, keep_hlo: bool = False,
             recommended: bool = False) -> dict:
    t0 = time.time()
    cfg, shape, mesh, jitted, args, p = build_cell(arch, shape_name, multi_pod,
                                                   plan, recommended)
    n_dev = mesh.devices.size
    lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    costs = analyze_hlo_text(hlo)
    terms = roofline_terms(costs)
    mf_global = model_flops(cfg, shape)
    mf_per_dev = mf_global / n_dev
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "plan": p,
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "arg_gb_per_dev": ma.argument_size_in_bytes / 2**30,
        "temp_gb_per_dev": ma.temp_size_in_bytes / 2**30,
        "output_gb_per_dev": ma.output_size_in_bytes / 2**30,
        "alias_gb_per_dev": ma.alias_size_in_bytes / 2**30,
        "model_flops_global": mf_global,
        "model_flops_per_dev": mf_per_dev,
        "useful_flops_ratio": (mf_per_dev / costs.flops) if costs.flops else 0.0,
        **terms,
    }
    # peak HBM estimate: args + temps (aliased outputs reuse arg space)
    rec["hbm_gb_per_dev"] = rec["arg_gb_per_dev"] + rec["temp_gb_per_dev"] + \
        max(0.0, rec["output_gb_per_dev"] - rec["alias_gb_per_dev"])
    rec["fits_16gb"] = rec["hbm_gb_per_dev"] <= 16.0
    if keep_hlo:
        rec["_hlo"] = hlo
    return rec


def fmt_row(r: dict) -> str:
    return (f"{r['arch']:>18s} {r['shape']:>11s} {r['mesh']:>7s} "
            f"compile={r['compile_s']:6.1f}s hbm={r['hbm_gb_per_dev']:7.2f}GB "
            f"tc={r['t_compute_s']*1e3:9.3f}ms tm={r['t_memory_s']*1e3:9.3f}ms "
            f"tcoll={r['t_collective_s']*1e3:9.3f}ms dom={r['dominant']:>10s} "
            f"useful={r['useful_flops_ratio']*100:5.1f}%")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--plan", default=None, help="JSON dict of plan overrides")
    ap.add_argument("--recommended", action="store_true",
                    help="apply the EXPERIMENTS.md §Perf recommended plans")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    plan = json.loads(args.plan) if args.plan else None

    records, failures = [], []
    for arch in archs:
        cfg = get_config(arch)
        shapes = [s.name for s in applicable_shapes(cfg)]
        if args.shape != "all":
            shapes = [s for s in args.shape.split(",") if s in shapes or
                      SHAPES_BY_NAME.get(s)]
            shapes = [s for s in shapes
                      if s in [x.name for x in applicable_shapes(cfg)]]
        skipped = [s.name for s in ALL_SHAPES
                   if s.name not in [x.name for x in applicable_shapes(cfg)]]
        for sk in skipped:
            if args.shape in ("all",) or sk in args.shape.split(","):
                records.append({"arch": arch, "shape": sk, "mesh": "-",
                                "skipped": "long-context needs sub-quadratic attention"})
                print(f"{arch:>18s} {sk:>11s}    SKIP (full attention; DESIGN.md §4)")
        for shape_name in shapes:
            for multi in meshes:
                try:
                    r = run_cell(arch, shape_name, multi, plan,
                                 recommended=args.recommended)
                    records.append(r)
                    print(fmt_row(r), flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape_name, multi, repr(e)))
                    print(f"FAIL {arch} {shape_name} multi={multi}: {e}", flush=True)
                    traceback.print_exc()
                    if args.fail_fast:
                        raise

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(records, f, indent=1)
    print(f"\nwrote {len(records)} records to {args.out}; {len(failures)} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
