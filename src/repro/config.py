"""Configuration system.

Every tunable in the framework flows through these frozen dataclasses so a
job is fully described by (ModelConfig, ShapeConfig, MeshConfig,
TrainConfig, CheckpointConfig, KhaosConfig).  Architecture configs live in
``repro.configs.<arch>`` and are resolved by name via
``repro.configs.get_config``.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    # router
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    # capacity factor used by the dense (einsum) dispatch path
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class RecurrentConfig:
    """RG-LRU (recurrentgemma) block parameters."""
    lru_width: int = 0            # 0 -> d_model
    conv1d_width: int = 4
    block_pattern: Sequence[str] = ("recurrent", "recurrent", "attention")
    window_size: int = 2048       # local attention window for hybrid archs


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64
    gate_lora: int = 160


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | hybrid | moe | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    # attention
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Sequence[int]] = None   # qwen2-vl M-RoPE
    attn_logit_softcap: float = 0.0
    # ffn
    activation: str = "swiglu"   # swiglu | geglu | gelu | relu_sq
    # norm
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    norm_eps: float = 1e-6
    # families
    moe: Optional[MoEConfig] = None
    recurrent: Optional[RecurrentConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # enc-dec (whisper)
    is_encoder_decoder: bool = False
    num_decoder_layers: int = 0
    dec_ratio: int = 4           # decoder_len = seq_len // dec_ratio for enc-dec shapes
    # vlm / audio frontends are STUBS: input_specs() provides embeddings
    frontend: Optional[str] = None   # None | "vision_patch" | "audio_frames"
    tie_embeddings: bool = False
    # numerics / impl
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    attn_impl: str = "xla_chunked"   # xla | xla_chunked | pallas
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    remat_policy: str = "minimal"  # none | minimal | full
    scan_layers: bool = True
    vocab_pad_multiple: int = 256
    kv_cache_dtype: str = "bfloat16"   # bfloat16 | int8 (beyond-paper decode lever)
    kv_quant_scale: float = 1.0 / 32.0  # static symmetric scale for int8 KV

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True when per-token decode cost is O(1)/O(window): ssm + hybrid."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + norms), matches zoo init."""
        d, v = self.d_model, self.padded_vocab
        hd = self.resolved_head_dim
        emb = v * d
        out = 0 if self.tie_embeddings else v * d
        def attn_params(bias: bool) -> int:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            b = (self.num_heads * hd + 2 * self.num_kv_heads * hd) if bias else 0
            return q + kv + o + b
        def ffn_params(dff: int) -> int:
            gated = self.activation in ("swiglu", "geglu")
            return d * dff * (3 if gated else 2)
        per_layer = 2 * d  # two rmsnorm scales
        if self.family == "moe":
            assert self.moe is not None
            per_layer += attn_params(self.qkv_bias)
            per_layer += d * self.moe.num_experts  # router
            per_layer += self.moe.num_experts * ffn_params(self.moe.d_ff_expert) // 1
        elif self.family == "ssm":
            assert self.rwkv is not None
            nh = d // self.rwkv.head_size
            # time-mix: r,k,v,g,o projections + decay/gate LoRAs + per-head params
            per_layer += 5 * d * d                     # r,k,v,g,o time-mix projections
            per_layer += d * d                         # channel-mix receptance
            per_layer += 2 * d * self.rwkv.decay_lora  # decay LoRA (wA, wB)
            per_layer += 12 * d + nh * self.rwkv.head_size  # mu/ln vectors + bonus
            per_layer += ffn_params(self.d_ff)
        elif self.family == "hybrid":
            assert self.recurrent is not None
            lru = self.recurrent.lru_width or d
            pat = self.recurrent.block_pattern
            n_rec = sum(1 for b in pat if b == "recurrent")
            n_att = len(pat) - n_rec
            rec = (2 * d * lru + lru * d                       # in/out proj (x,gate) .. out
                   + self.recurrent.conv1d_width * lru + lru   # conv1d + bias
                   + 2 * lru)                                  # a_param, input gate params
            att = attn_params(False)
            frac_rec = n_rec / len(pat)
            per_layer += int(frac_rec * rec + (1 - frac_rec) * att)
            per_layer += ffn_params(self.d_ff)
        else:  # dense / vlm / audio decoder
            per_layer += attn_params(self.qkv_bias)
            per_layer += ffn_params(self.d_ff)
        total = emb + out + self.num_layers * per_layer + d
        if self.is_encoder_decoder:
            # num_layers counts the ENCODER stack above; decoder layers add
            # self-attn + cross-attn + ffn + 3 norms each.
            dec_layer = (2 * attn_params(False) + ffn_params(self.d_ff) + 3 * d)
            total += self.num_decoder_layers * dec_layer + d
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top_k experts active)."""
        if self.family != "moe":
            return self.param_count()
        assert self.moe is not None
        full = self.param_count()
        d = self.d_model
        gated = self.activation in ("swiglu", "geglu")
        per_expert = d * self.moe.d_ff_expert * (3 if gated else 2)
        inactive = self.num_layers * (self.moe.num_experts - self.moe.top_k) * per_expert
        return int(full - inactive)


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    mode: str          # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def applicable_shapes(model: ModelConfig) -> list[ShapeConfig]:
    """The assigned shape cells that are well-defined for this arch.

    long_500k needs sub-quadratic attention -> ssm/hybrid only (skip noted
    in DESIGN.md §4 for full-attention archs).
    """
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if model.supports_long_context:
        shapes.append(LONG_500K)
    return shapes


# ---------------------------------------------------------------------------
# Mesh / distribution
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    data: int = 16
    model: int = 16
    pods: int = 2

    @property
    def shape(self) -> tuple[int, ...]:
        return (self.pods, self.data, self.model) if self.multi_pod else (self.data, self.model)

    @property
    def axis_names(self) -> tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = self.data * self.model
        return n * self.pods if self.multi_pod else n


@dataclass(frozen=True)
class ShardingConfig:
    """Per-job sharding policy knobs (the §Perf hillclimb levers)."""
    fsdp: bool = True                  # shard non-TP weight dim over 'data'
    fsdp_min_params: int = 3_000_000_000   # enable fsdp only for models above this
    expert_axis: str = "auto"          # auto | model | data | none
    decode_kv_seq_shard: bool = True   # flash-decoding style KV-seq sharding on 'model'
    seq_shard_hidden: bool = False     # Megatron-SP: shard hidden (B,S,d) seq over 'model'
    moe_megatron: bool = False         # experts: shard d_ff over (data x model) combined
                                       # instead of d over data — kills the partial-sum
                                       # all-reduces of d-contracted expert einsums
    gradient_accum: int = 1
    compress_cross_pod_grads: bool = False   # error-feedback int8 on 'pod' all-reduce


# ---------------------------------------------------------------------------
# Training / checkpoint / Khaos controller
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"   # adam m/v dtype (bf16 halves optimizer HBM)
    warmup_steps: int = 100
    schedule: str = "cosine"       # constant | cosine
    total_steps: int = 10_000


@dataclass(frozen=True)
class CheckpointPlan:
    """Complete description of the checkpoint *mechanism* + cadence.

    This is the unit the Khaos optimizer searches over: not just the
    interval (the paper's CI) but the whole plane configuration — full vs
    incremental encoding, sync vs async commit, and which storage levels
    participate.  ``checkpoint.manager.CheckpointManager`` executes a plan;
    ``sim.costmodel`` prices one; ``core.ci_optimizer.optimize_plan``
    searches the cross-product of CI grid x plan variants.
    """
    interval_s: float = 60.0          # CI — the Khaos-controlled cadence knob
    mode: str = "full"                # full | incremental
    full_every: int = 8               # full snapshot every N triggers (incremental)
    delta_codec: str = "lossless"     # lossless | int8 (Pallas ckpt_delta codec)
    encode_placement: str = "host"    # host | device: where the delta encode
                                      # runs.  "device" moves the ckpt_delta
                                      # kernels in front of D2H, so only the
                                      # encoded payload (delta+sparse residual,
                                      # or int8 q+scales — ~4x fewer bytes)
                                      # crosses the device->host link
    codec: str = "auto"               # auto | zstd | zlib (auto: zstd if installed)
    levels: Sequence[str] = ("local",)   # subset of {memory, local, remote}
    local_every: int = 1              # write local level every N triggers
    remote_every: int = 8             # write remote level every N triggers
    sync: bool = True                 # sync commit vs background-thread commit
    busy_policy: str = "skip"         # async: skip | block when a write is in flight
    num_shards: int = 4
    keep: int = 3
    replication_factor: int = 1       # k ring-neighbor peers each host pushes
                                      # its level-2 shard replicas to.  k>=1
                                      # makes node-local checkpoints survive a
                                      # single node loss (the level-2 survival
                                      # rule is DERIVED from this, not
                                      # assumed); k=0 opts out — a node
                                      # failure then degrades to remote
    chunk_bytes: int = 4 << 20        # D2H transfer granularity of the pipelined
                                      # snapshot (first chunk = the blocking sync)
    eager_snapshot: bool = False      # materialize EVERY device leaf before
                                      # save() returns: required when the train
                                      # step donates its input buffers
                                      # (donate_argnums) — deferred chunk
                                      # transfer relies on JAX immutability,
                                      # and a donated buffer is re-used the
                                      # moment the next step runs

    def __post_init__(self) -> None:
        assert self.mode in ("full", "incremental"), self.mode
        assert self.delta_codec in ("lossless", "int8"), self.delta_codec
        assert self.encode_placement in ("host", "device"), \
            self.encode_placement
        # device encode holds references to the live device buffers between
        # the trigger and the D2H of the encoded chunks — that relies on JAX
        # immutability, which donated buffers (the eager_snapshot case)
        # break by re-using device memory on the next step
        assert not (self.encode_placement == "device" and self.eager_snapshot), \
            "encode_placement='device' requires non-donated (immutable) " \
            "device buffers; eager_snapshot marks a donating step"
        assert self.busy_policy in ("skip", "block"), self.busy_policy
        unknown = set(self.levels) - {"memory", "local", "remote"}
        assert not unknown, f"unknown checkpoint levels {unknown}"
        assert self.levels, "a plan needs at least one level"
        assert min(self.full_every, self.local_every, self.remote_every) >= 1, \
            "cadences are every-Nth-trigger counts and must be >= 1"
        assert self.chunk_bytes >= 1, "chunk_bytes must be positive"
        assert self.replication_factor >= 0, \
            "replication_factor is a peer count and cannot be negative"

    def is_full_trigger(self, trigger_index: int) -> bool:
        return self.mode == "full" or trigger_index % self.full_every == 0

    def levels_due(self, trigger_index: int) -> list:
        """The (level, kind) writes trigger number ``trigger_index``
        performs: memory on every trigger, local at ``local_every`` (delta
        between fulls in incremental mode), remote at ``remote_every``
        (always a full).  The single source of routing truth — executed by
        ``checkpoint.manager.CheckpointManager`` and priced by
        ``sim.costmodel``."""
        full = self.is_full_trigger(trigger_index)
        out = []
        for level in self.levels:
            if level == "memory":
                out.append(("memory", "full"))
            elif level == "local" and trigger_index % self.local_every == 0:
                out.append(("local", "full" if full else "delta"))
            elif level == "remote" and trigger_index % self.remote_every == 0:
                out.append(("remote", "full"))
        return out

    @property
    def disk_levels(self) -> tuple[str, ...]:
        return tuple(l for l in self.levels if l in ("local", "remote"))

    @property
    def effective_replication(self) -> int:
        """Replicas each shard actually gets: a ring of H hosts has only
        H-1 distinct peers, so k is clamped to ``num_shards - 1`` (one
        shard per simulated host on this substrate)."""
        return max(0, min(self.replication_factor, self.num_shards - 1))

    @property
    def delta_encoding(self) -> str:
        """Pre-PR-5 alias of ``delta_codec`` (read-only)."""
        return self.delta_codec

    @property
    def name(self) -> str:
        """Short human tag, e.g. 'incr8-async-dev-int8-mlr' — used in
        Decisions, benchmark tables and event logs.  Codec/placement parts
        appear only when they differ from the host-lossless default, so
        pre-existing plan names are unchanged."""
        parts = ["full" if self.mode == "full" else f"incr{self.full_every}"]
        parts.append("sync" if self.sync else "async")
        if self.mode == "incremental":
            if self.encode_placement == "device":
                parts.append("dev")
            if self.delta_codec == "int8":
                parts.append("int8")
        if tuple(self.levels) != ("local",):
            parts.append("".join(l[0] for l in self.levels))
        if self.replication_factor != 1:
            parts.append(f"rep{self.replication_factor}")
        return "-".join(parts)


@dataclass(frozen=True)
class CheckpointConfig:
    directory: str = "/tmp/repro_ckpt"
    interval_seconds: float = 60.0      # the Khaos-controlled knob
    mode: str = "sync"                  # sync | async
    levels: Sequence[str] = ("local",)  # subset of {memory, local, remote}
    incremental: bool = False           # delta+int8 encode vs last full ckpt
    full_every: int = 8                 # full checkpoint every N incrementals
    keep: int = 3

    def to_plan(self) -> CheckpointPlan:
        """Lower the legacy job-config block onto the unified plan."""
        return CheckpointPlan(
            interval_s=self.interval_seconds,
            mode="incremental" if self.incremental else "full",
            full_every=self.full_every,
            delta_codec="int8" if self.incremental else "lossless",
            levels=tuple(self.levels),
            sync=self.mode != "async",
            keep=self.keep)


@dataclass(frozen=True)
class KhaosConfig:
    """The paper's knobs (§III)."""
    # Phase 1
    record_seconds: float = 600.0
    smoothing_window: int = 30          # averaging window for W(t)
    num_failure_points: int = 5         # m
    failure_point_mode: str = "throughput"   # throughput (prose) | time (Eq.4 literal)
    # Phase 2
    ci_min: float = 10.0
    ci_max: float = 120.0
    num_configs: int = 6                # z = |C|
    profile_margin_seconds: float = 90.0  # replay window around each injection
    # Phase 3
    latency_constraint: float = 1.0     # l_const (seconds, end-to-end)
    recovery_constraint: float = 240.0  # r_const (seconds)
    optimization_period: float = 60.0   # seconds between optimization cycles
    forecast_horizon: int = 5           # multi-step-ahead TSF steps
    defer_drop_fraction: float = 0.10   # ">10% decrease -> defer"
    proactive: bool = False             # pre-act on forecasted violations:
                                        # when the TSF predicts the rate
                                        # rising enough to break a QoS
                                        # constraint within the horizon,
                                        # re-optimize at the PREDICTED peak
                                        # instead of waiting for the breach
    proactive_rise_fraction: float = 0.05   # minimum forecasted rise
                                        # (fraction of the current rate)
                                        # before pre-acting — symmetric
                                        # guard to defer_drop_fraction
    rescale_history: int = 5            # k pairwise fractional differences for p
    reconfig_cooldown: float = 120.0
    model_degree: int = 2               # polynomial degree for M_L / M_R
    ridge_lambda: float = 1e-3


@dataclass(frozen=True)
class JobConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = MeshConfig()
    sharding: ShardingConfig = ShardingConfig()
    optimizer: OptimizerConfig = OptimizerConfig()
    checkpoint: CheckpointConfig = CheckpointConfig()
    khaos: KhaosConfig = KhaosConfig()
    seed: int = 0

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), default=str, indent=2)


def replace(cfg, **kw):
    """dataclasses.replace that works through our frozen configs."""
    return dataclasses.replace(cfg, **kw)
