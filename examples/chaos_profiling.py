"""Phase-2 deep dive: parallel profiling deployments, worst-case injection,
and the (CI x TR) -> latency/recovery surfaces Khaos learns.  The whole
z x m grid runs as array lanes of ONE batched campaign — the paper's
parallel Kubernetes deployments mapped onto vectorized simulator state —
sequenced by the ``KhaosRuntime`` phase machine (which also fits the
M_L/M_R models the moment profiling completes).

    PYTHONPATH=src python examples/chaos_profiling.py
"""
import numpy as np

from repro.config import KhaosConfig
from repro.core import KhaosRuntime
from repro.data.stream import diurnal_rate, record_workload
from repro.sim import BatchedDeployment, SimCostModel

sched = diurnal_rate(base=2500, amplitude=0.6, period=10_800, seed=9)
recording = record_workload(sched, duration=10_800, seed=9)
cost = SimCostModel(capacity_eps=4400.0, ckpt_duration_s=3.0,
                    ckpt_sync_penalty=0.6)

rt = KhaosRuntime(KhaosConfig(num_failure_points=5, ci_min=10, ci_max=120))
rt.record_steady_state(recording)

ci_values = [10, 30, 60, 90, 120]
print("profiling 5 parallel deployments x 5 worst-case failure injections "
      "(25 lanes, one sweep)...")
prof = rt.run_profiling(BatchedDeployment(cost, recording),
                        ci_values, margin=90,
                        progress=lambda msg: print("  " + msg))

print("\nLatency surface L (ms)  [rows: failure points by rate; cols: CI]")
hdr = "  TR \\ CI " + " ".join(f"{c:>7d}" for c in ci_values)
print(hdr)
for i, tr in enumerate(prof.failure_rates):
    print(f"{tr:9.0f} " + " ".join(f"{v*1e3:7.0f}" for v in prof.latencies[i]))

print("\nRecovery surface R (s)")
print(hdr)
for i, tr in enumerate(prof.failure_rates):
    print(f"{tr:9.0f} " + " ".join(f"{v:7.0f}" for v in prof.recoveries[i]))

ci_f, tr_f, L_f, R_f = prof.flat()
m_l, m_r = rt.m_l, rt.m_r     # fitted by the runtime at the phase boundary
print(f"\nM_L avg pct error: {m_l.avg_percent_error(ci_f, tr_f, L_f):.3f}  "
      f"M_R: {m_r.avg_percent_error(ci_f, tr_f, R_f):.3f}")
print("M_R predictions at TR=3500:",
      np.round(m_r.predict(np.array(ci_values, float), 3500.0)).astype(int).tolist())
print("phase machine:", " -> ".join(rt.phase_sequence()))
