"""End-to-end driver: REAL JAX training of an LM on a live token stream
with Khaos-controlled checkpointing, failure injection and restart.

    PYTHONPATH=src python examples/train_stream.py --arch yi-6b --duration 90

The control plane is the SAME ``KhaosRuntime``/``JobHandle`` machinery the
simulator examples use — ``runtime.TrainerJobHandle`` implements the full
protocol over the live trainer, including ``reconfigure_plan`` (drain +
CheckpointManager rebuild), so a controller Decision can switch the
checkpoint *mechanism* mid-run, not just the interval.

The model is the reduced (smoke) config of the chosen architecture so a
few hundred steps run on CPU; swap in the full config + a TPU mesh for the
production path (launch/train.py assembles exactly the same pieces).
"""
import argparse

from repro.config import CheckpointPlan, KhaosConfig, OptimizerConfig
from repro.configs import get_smoke_config
from repro.core import KhaosRuntime, demo_prior_models
from repro.data.stream import EventStream, diurnal_rate
from repro.runtime import ResilientTrainer, TrainerConfig, TrainerJobHandle
from repro.sim import SimCostModel


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--duration", type=float, default=90.0,
                    help="virtual seconds to run")
    ap.add_argument("--fail-at", type=float, default=35.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    stream = EventStream(schedule=diurnal_rate(base=400.0, period=600.0))
    # a full checkpoint *plan*: async incremental with an in-RAM level for
    # cheap task-restart recovery (deltas land on local disk between fulls)
    plan = CheckpointPlan(interval_s=10.0, mode="incremental", full_every=4,
                          levels=("memory", "local"), sync=False,
                          num_shards=2)
    tcfg = TrainerConfig(batch=8, seq_len=32, ckpt_dir="/tmp/repro_train_stream",
                         time_scale=8.0, detect_s=2.0, restart_s=2.0,
                         plan=plan)
    trainer = ResilientTrainer(cfg, tcfg, stream,
                               OptimizerConfig(total_steps=5000, lr=3e-3))
    trainer.inject_failure_at(args.fail_at)

    # pre-fit models installed into the runtime (in production Phase 1+2
    # fit these on the cluster; here a simple prior keeps the demo short)
    m_l, m_r = demo_prior_models()
    rt = KhaosRuntime(
        KhaosConfig(latency_constraint=1.0, recovery_constraint=20.0,
                    optimization_period=10.0, ci_min=5, ci_max=60,
                    reconfig_cooldown=20.0),
        # a cost model makes Eq. 8 search plan variants too: Decisions can
        # then actuate the trainer's set_plan (drain + manager rebuild)
        cost=SimCostModel(capacity_eps=500.0, ckpt_duration_s=0.5),
        mtbf_s=600.0)
    rt.install_models(m_l, m_r)
    job = TrainerJobHandle(trainer)
    rt.attach(job)

    def on_second(sample):
        rt.step()

    summary = trainer.run(args.duration, on_second=on_second)
    print("\n=== train_stream summary ===")
    print(f"steps: {summary['final_step']}  "
          f"loss: {trainer.losses[0]:.3f} -> {summary['final_loss']:.3f}")
    print(f"checkpoints: {summary['checkpoints']}  failures: {summary['failures']}  "
          f"restores: {summary['restores']}  "
          f"plan switches: {summary['plan_switches']}")
    st = summary["ckpt_stats"]
    print(f"checkpoint plane [{st['plan']}]: {st['bytes_by_kind']} bytes, "
          f"levels {st['saves_by_level']}, restores {st['restores']}")
    print(f"controller reconfigurations: {job.reconfigurations}")
    if job.plan_changes:
        print(f"mechanism switches: {job.plan_changes}")
    print("phase machine:", " -> ".join(rt.phase_sequence()))
    assert summary["failures"] >= 1 and summary["restores"] >= 1
    assert summary["final_loss"] < trainer.losses[0], "model should learn"


if __name__ == "__main__":
    main()
