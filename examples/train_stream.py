"""End-to-end driver: REAL JAX training of an LM on a live token stream
with Khaos-controlled checkpointing, failure injection and restart.

    PYTHONPATH=src python examples/train_stream.py --arch yi-6b --duration 90

The model is the reduced (smoke) config of the chosen architecture so a
few hundred steps run on CPU; swap in the full config + a TPU mesh for the
production path (launch/train.py assembles exactly the same pieces).
"""
import argparse

import numpy as np

from repro.config import CheckpointPlan, KhaosConfig, OptimizerConfig
from repro.configs import get_smoke_config
from repro.core import KhaosController, QoSModel
from repro.data.stream import EventStream, diurnal_rate
from repro.runtime import ResilientTrainer, TrainerConfig


class TrainerJobHandle:
    """core.controller.JobHandle over the live trainer."""

    def __init__(self, trainer: ResilientTrainer):
        self.tr = trainer
        self.reconfigurations = []

    def now(self):
        return self.tr.t

    def current_ci(self):
        return self.tr.policy.interval_s

    def avg_latency(self, w):
        return self.tr.metrics.series("latency").mean_over(self.tr.t - w, self.tr.t)

    def avg_throughput(self, w):
        return self.tr.stream.rate_at(self.tr.t)

    def healthy(self):
        return True

    def reconfigure(self, new_ci):
        self.reconfigurations.append((self.tr.t, new_ci))
        self.tr.set_ci(new_ci)       # hot CI swap — no restart on this substrate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--duration", type=float, default=90.0,
                    help="virtual seconds to run")
    ap.add_argument("--fail-at", type=float, default=35.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    stream = EventStream(schedule=diurnal_rate(base=400.0, period=600.0))
    # a full checkpoint *plan*: async incremental with an in-RAM level for
    # cheap task-restart recovery (deltas land on local disk between fulls)
    plan = CheckpointPlan(interval_s=10.0, mode="incremental", full_every=4,
                          levels=("memory", "local"), sync=False,
                          num_shards=2)
    tcfg = TrainerConfig(batch=8, seq_len=32, ckpt_dir="/tmp/repro_train_stream",
                         time_scale=8.0, detect_s=2.0, restart_s=2.0,
                         plan=plan)
    trainer = ResilientTrainer(cfg, tcfg, stream,
                               OptimizerConfig(total_steps=5000, lr=3e-3))
    trainer.inject_failure_at(args.fail_at)

    # a pre-fit controller (in production the profiling phase fits these
    # on the cluster; here we install a simple prior so the demo is short)
    rng = np.random.default_rng(0)
    ci = rng.uniform(5, 60, 64)
    tr = rng.uniform(100, 800, 64)
    m_l = QoSModel().fit(ci, tr, 0.05 + 2.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 4.0 + 1.0 * ci + tr * 5e-3)
    ctl = KhaosController(
        cfg=KhaosConfig(latency_constraint=1.0, recovery_constraint=20.0,
                        optimization_period=10.0, ci_min=5, ci_max=60,
                        reconfig_cooldown=20.0),
        m_l=m_l, m_r=m_r)
    job = TrainerJobHandle(trainer)

    def on_second(sample):
        ctl.maybe_optimize(job)

    summary = trainer.run(args.duration, on_second=on_second)
    print("\n=== train_stream summary ===")
    print(f"steps: {summary['final_step']}  "
          f"loss: {trainer.losses[0]:.3f} -> {summary['final_loss']:.3f}")
    print(f"checkpoints: {summary['checkpoints']}  failures: {summary['failures']}  "
          f"restores: {summary['restores']}")
    st = summary["ckpt_stats"]
    print(f"checkpoint plane [{st['plan']}]: {st['bytes_by_kind']} bytes, "
          f"levels {st['saves_by_level']}, restores {st['restores']}")
    print(f"controller reconfigurations: {job.reconfigurations}")
    assert summary["failures"] >= 1 and summary["restores"] >= 1
    assert summary["final_loss"] < trainer.losses[0], "model should learn"


if __name__ == "__main__":
    main()
