"""Elastic recovery: lose hosts mid-training, plan a smaller mesh, restore
the checkpoint with a different shard count, and keep training — the
manifest-driven reshard path (DESIGN.md §5), expressed through the unified
control-plane API: one ``CheckpointManager`` executes the plan on the big
mesh, and the surviving cluster rebuilds the manager from a NEW
``CheckpointPlan`` (different ``num_shards``) — exactly the drain+rebuild
primitive ``ResilientTrainer.set_plan``/``TrainerJobHandle.
reconfigure_plan`` actuate when the Khaos controller switches mechanisms.

    PYTHONPATH=src python examples/elastic_recovery.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import CheckpointPlan, MeshConfig, OptimizerConfig
from repro.configs import get_smoke_config
from repro.ft import HeartbeatDetector, plan_rescale
from repro.models import zoo
from repro.optim import make_optimizer

cfg = get_smoke_config("qwen2.5-14b")
opt = make_optimizer(OptimizerConfig())
params = zoo.init_params(cfg, jax.random.PRNGKey(0))
state = {"params": params, "opt": opt.init(params),
         "step": jnp.asarray(120, jnp.int32)}

# 1. production cluster: 64 hosts, one manager executing the 64-shard plan
plan64 = CheckpointPlan(levels=("local",), num_shards=64)
mgr64 = CheckpointManager("/tmp/repro_elastic", plan64)
mgr64.save(120, state, extra={"pipeline": {"cursor": {"offset": 960},
                                           "stream": {"consumed": 960}}})
mgr64.wait()
print(f"saved step-120 checkpoint under plan [{plan64.name}] as 64 shards")

# 2. three hosts miss heartbeats
det = HeartbeatDetector(num_hosts=64, timeout_s=50.0)
det.heartbeat_all(0.0)
for h in range(61):
    det.heartbeat(h, 60.0)
dead = det.failed_hosts(61.0)
print(f"heartbeat detector: hosts {dead} failed")

# 3. plan the new mesh (TP pinned, data axis shrinks, batch stays divisible)
mesh = MeshConfig(data=16, model=16)
plan = plan_rescale(mesh, hosts_alive=64 - len(dead), chips_per_host=4,
                    global_batch=256)
print(f"rescale plan: {plan.old.shape} -> {plan.new.shape} "
      f"({plan.hosts_used} hosts used, {plan.standby} standby, "
      f"batch_ok={plan.batch_ok})")

# 4. the surviving cluster REBUILDS the manager from a new plan (shard
#    count follows the smaller mesh) and restores THE SAME checkpoint —
#    the manifest makes shard count a restore-time choice, and the
#    rebuild is the same primitive a controller plan-switch uses
plan61 = CheckpointPlan(levels=("local",), num_shards=plan.hosts_used)
mgr61 = CheckpointManager("/tmp/repro_elastic", plan61)
report = mgr61.restore(state, failure_kind="node")
restored, extra = report.state, report.extra
same = all(np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
           for a, b in zip(jax.tree_util.tree_leaves(state),
                           jax.tree_util.tree_leaves(restored)))
print(f"restored at step {report.step} from level {report.level!r} with "
      f"cursor {extra['pipeline']['cursor']} — bitwise identical: {same}")
assert same and plan.new.model == 16
print("elastic recovery complete: resume training on the smaller mesh")
