"""Elastic recovery: lose hosts mid-training, plan a smaller mesh, restore
the checkpoint with a different shard count, and keep training — the
manifest-driven reshard path (DESIGN.md §5).

    PYTHONPATH=src python examples/elastic_recovery.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointStore
from repro.config import MeshConfig, OptimizerConfig
from repro.configs import get_smoke_config
from repro.ft import HeartbeatDetector, plan_rescale
from repro.models import zoo
from repro.optim import make_optimizer

cfg = get_smoke_config("qwen2.5-14b")
opt = make_optimizer(OptimizerConfig())
params = zoo.init_params(cfg, jax.random.PRNGKey(0))
state = {"params": params, "opt": opt.init(params),
         "step": jnp.asarray(120, jnp.int32)}

# 1. production cluster: 64 hosts, checkpoint sharded 64 ways
store64 = CheckpointStore("/tmp/repro_elastic", num_shards=64)
store64.save(120, state, extra={"pipeline": {"cursor": {"offset": 960},
                                             "stream": {"consumed": 960}}})
print("saved step-120 checkpoint as 64 shards")

# 2. three hosts miss heartbeats
det = HeartbeatDetector(num_hosts=64, timeout_s=50.0)
det.heartbeat_all(0.0)
for h in range(61):
    det.heartbeat(h, 60.0)
dead = det.failed_hosts(61.0)
print(f"heartbeat detector: hosts {dead} failed")

# 3. plan the new mesh (TP pinned, data axis shrinks, batch stays divisible)
mesh = MeshConfig(data=16, model=16)
plan = plan_rescale(mesh, hosts_alive=64 - len(dead), chips_per_host=4,
                    global_batch=256)
print(f"rescale plan: {plan.old.shape} -> {plan.new.shape} "
      f"({plan.hosts_used} hosts used, {plan.standby} standby, "
      f"batch_ok={plan.batch_ok})")

# 4. the surviving cluster restores THE SAME checkpoint with a different
#    shard count — the manifest makes shard count a restore-time choice
store61 = CheckpointStore("/tmp/repro_elastic", num_shards=plan.hosts_used)
restored, extra = store61.restore(state)
same = all(np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
           for a, b in zip(jax.tree_util.tree_leaves(state),
                           jax.tree_util.tree_leaves(restored)))
print(f"restored at step {int(restored['step'])} with cursor "
      f"{extra['pipeline']['cursor']} — bitwise identical: {same}")
assert same and plan.new.model == 16
print("elastic recovery complete: resume training on the smaller mesh")
