"""Fleet supervision quickstart: one control plane over many Khaos jobs.

    PYTHONPATH=src python examples/fleet_supervision.py

Walks the whole fleet story on the simulator substrate:

1. submit a first wave of jobs — Phase 1 records each one, admission
   reserves fleet capacity and runs a what-if chaos campaign at the
   residual, and an oversized job is REJECTED;
2. profile the admitted cold jobs in ONE pooled ``BatchedCampaign`` (all
   jobs' z x m grids as lanes of a single sweep), fit per-job QoS models
   and file them in the ``QoSModelRegistry``;
3. submit a second wave of near-twin jobs — their fingerprints hit the
   registry, a one-lane probe validates the donor models, and they enter
   Phase 3 WITHOUT a profiling campaign (``adopt_models``), at a fraction
   of the cold jobs' profiling lane-time;
4. supervise everything through one multiplexed tick: a shared Phase-3
   campaign for the lane jobs plus a scalar ``StreamSimulator`` job,
   every controller appending to one decision log, the bounded fleet
   metrics plane rolling up per-job and per-fleet series.
"""
import numpy as np

from repro.config import KhaosConfig
from repro.data.stream import constant_rate, diurnal_rate
from repro.fleet import FleetJobSpec, FleetSupervisor
from repro.sim import SimCostModel


def main():
    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.0,
                        state_bytes=2e9)
    kcfg = KhaosConfig(latency_constraint=1.5, recovery_constraint=240.0,
                       optimization_period=30.0, ci_min=10, ci_max=120,
                       num_failure_points=3, num_configs=3,
                       record_seconds=600.0, reconfig_cooldown=60.0)
    sup = FleetSupervisor(fleet_capacity_eps=16_000.0)

    def spec(name, schedule, seed=0, substrate="lane"):
        return FleetJobSpec(name, cost, kcfg, schedule=schedule, seed=seed,
                            substrate=substrate, horizon_s=900.0,
                            profile_max_recovery_s=900.0,
                            failures=((400.0, "node"),))

    # -- wave 1: cold jobs + one capacity reject ----------------------------
    for name, sched, seed in [
            ("etl-a", constant_rate(1500.0), 0),
            ("etl-b", constant_rate(1500.0), 1),
            ("diurnal-a", diurnal_rate(base=1200.0, amplitude=0.4), 2)]:
        dec = sup.submit(spec(name, sched, seed))
        print(f"submit {name:10s} -> {dec.action:14s} ({dec.reason})")
    dec = sup.submit(spec("firehose", constant_rate(30_000.0)))
    print(f"submit {'firehose':10s} -> {dec.action:14s} ({dec.reason})")

    prof = sup.run_profiling_pooled()
    print(f"\npooled Phase 2: {prof['jobs_profiled']} jobs, "
          f"{prof['pooled_lanes']} lanes in one campaign; "
          f"registry now holds {len(sup.registry)} fingerprints")

    # -- wave 2: near-twins ride the registry -------------------------------
    for name, sched, seed, sub in [
            ("etl-c", constant_rate(1500.0), 3, "lane"),
            ("etl-d", constant_rate(1500.0), 4, "scalar")]:
        dec = sup.submit(spec(name, sched, seed, substrate=sub))
        print(f"submit {name:10s} -> {dec.action}")
    sup.run_profiling_pooled()       # no-op if every wave-2 job transferred

    # -- Phase 3: one multiplexed control tick over the whole fleet ---------
    sup.start()
    status = sup.run(900.0, chunk_s=30.0)

    print("\nfleet after supervision:")
    for name, j in status["jobs"].items():
        print(f"  {name:10s} status={j['status']:9s} "
              f"admission={j['admission']:14s} "
              f"profiling_lane_ticks={j['profiling_lane_ticks']:6d} "
              f"transferred={j['transferred']}")
    print(f"shared campaigns: {status['shared_campaigns']}, "
          f"decisions {status['decisions_by_kind']}")
    cold = status["jobs"]["etl-a"]["profiling_lane_ticks"]
    xfer = status["jobs"]["etl-c"]["profiling_lane_ticks"]
    print(f"profiling lane-time: cold {cold} ticks vs transfer {xfer} ticks "
          f"({cold / max(xfer, 1):.1f}x less for the transfer-admitted job)")
    lat = sup.metrics.series("fleet/latency")
    print(f"fleet latency plane: {len(lat)} raw samples "
          f"(+{len(lat.rollups)} rollups), lifetime mean "
          f"{lat.lifetime_mean():.2f}s")
    for name in ("etl-a", "etl-c"):
        print(f"  {name}: QoS violations {sup.qos_violations(name)}")


if __name__ == "__main__":
    main()
