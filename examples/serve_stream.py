"""Batched streaming inference: prefill + greedy decode over request
batches, with per-batch latency metrics — the serving-side data plane the
dry-run lowers at the assigned decode shapes.

    PYTHONPATH=src python examples/serve_stream.py --arch recurrentgemma-2b

``--fleet`` additionally serves the CONTROL plane: a ``FleetSupervisor``
admitting three jobs next to the data plane (one cold-profiled, one
transfer-admitted from the registry, one rejected for capacity) and
printing the fleet status — the supervisor a real deployment would run
beside its servers.  See ``examples/fleet_supervision.py`` for the full
fleet walkthrough.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import zoo
from repro.runtime.server import ServeRequest, StreamServer


def serve_fleet_supervisor() -> dict:
    """The --fleet mode: one supervisor over three admission outcomes."""
    from repro.config import KhaosConfig
    from repro.data.stream import constant_rate
    from repro.fleet import FleetJobSpec, FleetSupervisor
    from repro.sim import SimCostModel

    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.0,
                        state_bytes=1e9)
    kcfg = KhaosConfig(latency_constraint=1.5, recovery_constraint=240.0,
                       optimization_period=30.0, ci_min=10, ci_max=120,
                       num_failure_points=2, num_configs=3,
                       record_seconds=600.0, reconfig_cooldown=60.0)
    sup = FleetSupervisor(fleet_capacity_eps=6000.0)

    def spec(name, rate, seed=0):
        return FleetJobSpec(name, cost, kcfg, schedule=constant_rate(rate),
                            seed=seed, horizon_s=300.0,
                            profile_max_recovery_s=900.0)

    print("cold:     ", sup.submit(spec("serve-a", 1500.0)).action)
    sup.run_profiling_pooled()          # fits serve-a, files it in the registry
    print("transfer: ", sup.submit(spec("serve-b", 1500.0, seed=1)).action)
    print("rejected: ", sup.submit(spec("serve-xl", 9000.0)).action)
    sup.run_profiling_pooled()
    sup.start()
    status = sup.run(300.0, chunk_s=30.0)
    print(f"fleet status after {status['t']:.0f}s: "
          f"{ {n: j['status'] for n, j in status['jobs'].items()} } "
          f"decisions {status['decisions_by_kind']}")
    return status


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--fleet", action="store_true",
                    help="also run the fleet-supervisor control plane demo")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    server = StreamServer(cfg, params, max_batch=4)
    rng = np.random.default_rng(0)

    rid = 0
    for b in range(args.batches):
        reqs = []
        for _ in range(4):
            reqs.append(ServeRequest(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size, 16, dtype=np.int32),
                max_new_tokens=8))
            rid += 1
        t0 = time.monotonic()
        out = server.serve_batch(reqs)
        dt = time.monotonic() - t0
        print(f"batch {b}: served {len(out)} requests in {dt*1e3:.0f}ms "
              f"({dt*1e3/ (4*8):.1f} ms/token); "
              f"sample completion: {out[reqs[0].rid].tolist()}")

    if args.fleet:
        serve_fleet_supervisor()


if __name__ == "__main__":
    main()
