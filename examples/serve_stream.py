"""Batched streaming inference: prefill + greedy decode over request
batches, with per-batch latency metrics — the serving-side data plane the
dry-run lowers at the assigned decode shapes.

    PYTHONPATH=src python examples/serve_stream.py --arch recurrentgemma-2b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import zoo
from repro.runtime.server import ServeRequest, StreamServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batches", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    server = StreamServer(cfg, params, max_batch=4)
    rng = np.random.default_rng(0)

    rid = 0
    for b in range(args.batches):
        reqs = []
        for _ in range(4):
            reqs.append(ServeRequest(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab_size, 16, dtype=np.int32),
                max_new_tokens=8))
            rid += 1
        t0 = time.monotonic()
        out = server.serve_batch(reqs)
        dt = time.monotonic() - t0
        print(f"batch {b}: served {len(out)} requests in {dt*1e3:.0f}ms "
              f"({dt*1e3/ (4*8):.1f} ms/token); "
              f"sample completion: {out[reqs[0].rid].tolist()}")


if __name__ == "__main__":
    main()
