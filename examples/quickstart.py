"""Quickstart: the whole Khaos loop in one minute on the simulator —
driven end-to-end by the ``KhaosRuntime`` phase machine (the one
control-plane API; ``examples/train_stream.py`` drives the LIVE trainer
through exactly the same sequence).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.config import KhaosConfig
from repro.core import KhaosRuntime, young_daly_interval
from repro.data.stream import diurnal_rate, record_workload
from repro.ft.failures import FailureInjector
from repro.sim import (BatchedDeployment, SimCostModel, SimJobHandle,
                       StreamSimulator)

kcfg = KhaosConfig(latency_constraint=1.0, recovery_constraint=500.0,
                   optimization_period=60.0, ci_min=10, ci_max=120,
                   num_failure_points=4)
cost = SimCostModel(capacity_eps=4200.0, ckpt_duration_s=2.5,
                    ckpt_sync_penalty=0.6)
rt = KhaosRuntime(kcfg)

# ---- Phase 1: record the stream, find failure points over the W(t) range --
sched = diurnal_rate(base=2400, amplitude=0.5, period=7200, seed=5)
recording = record_workload(sched, duration=7200, seed=5)
steady = rt.record_steady_state(recording)
print("Phase 1: failure points at throughputs",
      np.round(steady.failure_rates).astype(int).tolist(), "events/s")

# ---- Phase 2: the whole (CI x failure point) grid as ONE batched campaign -
prof = rt.run_profiling(BatchedDeployment(cost, recording),
                        ci_values=[10, 40, 80, 120], margin=60)
print("Phase 2: recovery grid R (failure-point x CI):")
print(np.round(prof.recoveries).astype(int))

# ---- Phase 3: attach the job handle, monitor, optimize Eq. 8 at runtime ---
ci0 = rt.initial_ci(float(np.mean(recording.counts)))
print(f"Phase 3: initial CI from Eq. 8 = "
      f"{'infeasible' if ci0 is None else f'{ci0:.0f}s'} "
      f"(Young/Daly static would say {young_daly_interval(2.5, 7200):.0f}s)")

sim = StreamSimulator(cost, ci_s=ci0 or 60.0, schedule=sched)
job = SimJobHandle(sim)
ctl = rt.attach(job)
print("phase machine:", " -> ".join(rt.phase_sequence()))
inj = FailureInjector()
for ft in (1800.0, 4200.0):
    sim.inject_failure(inj.worst_case_time(ft, 0.0, sim.policy.interval_s,
                                           cost.ckpt_duration_s))
while sim.t < 7200:
    sim.tick()
    rt.step()

lat = np.array(sim.metrics.series("latency").values)
print(f"run: avg latency {lat.mean()*1e3:.0f}ms, "
      f"violations {np.mean(lat > 1.0)*100:.1f}%, "
      f"recoveries {[round(r['recovery_s']) for r in sim.recoveries]}s, "
      f"reconfigurations {job.reconfigurations}")
print("decisions:", {k: sum(1 for d in ctl.decisions if d.kind == k)
                     for k in {d.kind for d in ctl.decisions}})
