"""E7/E8: summarize the multi-pod dry-run + roofline records produced by
``python -m repro.launch.dryrun`` (experiments/dryrun.json).  This bench
formats the §Dry-run and §Roofline tables for EXPERIMENTS.md."""
from __future__ import annotations

import json
import os


def bench_dryrun(path: str = "experiments/dryrun.json"):
    if not os.path.exists(path):
        print(f"\n=== Dry-run summary: {path} not found ===")
        print("run: PYTHONPATH=src python -m repro.launch.dryrun --arch all "
              "--shape all --mesh both --out experiments/dryrun.json")
        return []
    with open(path) as f:
        records = json.load(f)
    runs = [r for r in records if "skipped" not in r]
    skips = [r for r in records if "skipped" in r]
    print(f"\n=== Multi-pod dry-run: {len(runs)} compiled cells, "
          f"{len(skips)} documented skips ===")
    hdr = (f"{'arch':>18s} {'shape':>11s} {'mesh':>8s} {'HBM/dev':>8s} "
           f"{'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} {'dominant':>10s} "
           f"{'useful':>7s}")
    print(hdr)
    for r in runs:
        print(f"{r['arch']:>18s} {r['shape']:>11s} {r['mesh']:>8s} "
              f"{r['hbm_gb_per_dev']:7.2f}G "
              f"{r['t_compute_s']*1e3:8.1f}ms {r['t_memory_s']*1e3:8.1f}ms "
              f"{r['t_collective_s']*1e3:8.1f}ms {r['dominant']:>10s} "
              f"{100*r['useful_flops_ratio']:6.1f}%")
    for r in skips:
        print(f"{r['arch']:>18s} {r['shape']:>11s}      SKIP ({r['skipped']})")
    n_fit = sum(1 for r in runs if r.get("fits_16gb"))
    print(f"fits 16GB v5e HBM: {n_fit}/{len(runs)} cells "
          f"(see EXPERIMENTS.md for the exceptions)")
    return runs


def main():
    return bench_dryrun()


if __name__ == "__main__":
    main()
