"""Shared evaluation harness for the paper-table benchmarks (E1/E2).

Implements the paper's protocol (§IV): a day-scale variable workload, 12
worst-case failure injections at varied throughput levels, static CI
baselines {10,30,60,90,120}s vs the full three-phase Khaos pipeline, QoS
constraints 1000 ms / 240 s.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.config import KhaosConfig
from repro.core import (KhaosController, QoSModel, run_profiling_campaign,
                        select_failure_points)
from repro.data.stream import RateSchedule, record_workload
from repro.ft.failures import FailureInjector
from repro.sim import (BatchedDeployment, SimCostModel, SimJobHandle,
                       StreamSimulator)

STATIC_CIS = (10.0, 30.0, 60.0, 90.0, 120.0)
L_CONST = 1.0        # 1000 ms
R_CONST = 240.0      # seconds
NUM_FAILURES = 12


@dataclass
class RunResult:
    name: str
    avg_latency_ms: float
    lat_violation_frac: float
    total_recovery_s: float
    recovery_violation_s: float
    reconfigurations: int
    recoveries: list


def make_khaos(recording, cost: SimCostModel, seed: int = 0):
    """Phases 1+2+3 setup: returns (controller, profiling_result)."""
    ss = select_failure_points(recording, m=5, smoothing_window=30)
    ci_grid = np.linspace(10, 120, 6)
    # all z x m profiling deployments advance as lanes of one campaign
    prof = run_profiling_campaign(
        BatchedDeployment(cost, recording, warmup_s=300,
                          max_recovery_s=3600.0),
        ss, ci_grid, margin=90)
    ci_f, tr_f, L_f, R_f = prof.flat()
    # a deployment that cannot keep up at its CI (burst peak + checkpoint
    # tax) reports the cap; winsorize so the cliff doesn't poison the fit —
    # such configs are correctly predicted as infeasible anyway
    R_f = np.minimum(R_f, 3600.0)
    m_l = QoSModel().fit(ci_f, tr_f, L_f)
    m_r = QoSModel().fit(ci_f, tr_f, R_f)
    cfg = KhaosConfig(latency_constraint=L_CONST, recovery_constraint=R_CONST,
                      optimization_period=120.0, ci_min=10.0, ci_max=120.0,
                      reconfig_cooldown=600.0)
    return KhaosController(cfg=cfg, m_l=m_l, m_r=m_r), prof


def failure_times_by_throughput(recording, n=NUM_FAILURES, t_min=2000.0):
    """Failure times spread over throughput levels (paper Fig. 2c/d)."""
    ss = select_failure_points(recording, m=n, smoothing_window=30)
    times = np.sort(ss.failure_times)
    return times[times > t_min]


def evaluate(name: str, schedule: RateSchedule, duration: float,
             cost: SimCostModel, fail_times, ci_static=None,
             controller: KhaosController | None = None,
             initial_tr: float | None = None) -> RunResult:
    ci0 = ci_static or 60.0
    if controller is not None and initial_tr is not None:
        ci0 = controller.initial_ci(initial_tr) or ci0
    sim = StreamSimulator(cost, ci_s=ci0, schedule=schedule)
    job = SimJobHandle(sim)
    inj = FailureInjector()
    for ft in fail_times:
        # worst case: just before the next checkpoint completes (per-job CI)
        t = inj.worst_case_time(float(ft), 0.0, sim.policy.interval_s,
                                cost.ckpt_duration_s)
        sim.inject_failure(t)
    while sim.t < duration:
        sim.tick()
        if controller is not None:
            ctl_obs = controller.maybe_optimize(job)
            del ctl_obs
    lat = np.array(sim.metrics.series("latency").values)
    recs = [r["recovery_s"] for r in sim.recoveries]
    if controller is not None:
        ci_now = sim.policy.interval_s
        for r in sim.recoveries:
            controller.record_recovery(r["ci"], 0.0, r["recovery_s"])
    return RunResult(
        name=name,
        avg_latency_ms=float(np.mean(lat) * 1e3),
        lat_violation_frac=float(np.mean(lat > L_CONST)),
        total_recovery_s=float(np.sum(recs)),
        recovery_violation_s=float(sum(max(0.0, r - R_CONST) for r in recs)),
        reconfigurations=len(job.reconfigurations),
        recoveries=recs,
    )


def _run_once(schedule: RateSchedule, cost: SimCostModel, duration: float,
              seed: int):
    recording = record_workload(schedule, duration=min(duration, 14_400.0),
                                seed=seed)
    controller, prof = make_khaos(recording, cost, seed)
    fails = failure_times_by_throughput(
        record_workload(schedule, duration=duration, seed=seed + 1))
    rows = [evaluate("Khaos", schedule, duration, cost, fails,
                     controller=controller,
                     initial_tr=float(np.mean(recording.counts)))]
    for ci in STATIC_CIS:
        rows.append(evaluate(f"{int(ci)}s", schedule, duration, cost, fails,
                             ci_static=ci))
    # post-execution error analysis (Tables II(a)/III(a)): latency tracked per
    # optimization cycle, recovery at failures with the TR at failure time
    err = {}
    if controller.latency_obs:
        ci_a, tr_a, y = map(np.array, zip(*controller.latency_obs))
        err["latency_pct_error"] = controller.m_l.avg_percent_error(ci_a, tr_a, y)
    # recovery error: predictions vs the profiling ground truth
    ci_f, tr_f, _, R_f = prof.flat()
    err["recovery_pct_error"] = controller.m_r.avg_percent_error(ci_f, tr_f, R_f)
    return rows, err


def run_experiment(exp_name: str, schedule: RateSchedule, cost: SimCostModel,
                   duration: float = 86_400.0, seed: int = 0,
                   repeats: int = 3):
    """Full paper protocol, median over ``repeats`` runs (paper: 5).
    Returns (rows, error_analysis)."""
    all_rows, all_errs = [], []
    for rep in range(repeats):
        rows, err = _run_once(schedule, cost, duration, seed + 100 * rep)
        all_rows.append(rows)
        all_errs.append(err)
    med_rows = []
    for i in range(len(all_rows[0])):
        med_rows.append(RunResult(
            name=all_rows[0][i].name,
            avg_latency_ms=float(np.median([r[i].avg_latency_ms for r in all_rows])),
            lat_violation_frac=float(np.median([r[i].lat_violation_frac for r in all_rows])),
            total_recovery_s=float(np.median([r[i].total_recovery_s for r in all_rows])),
            recovery_violation_s=float(np.median([r[i].recovery_violation_s for r in all_rows])),
            reconfigurations=int(np.median([r[i].reconfigurations for r in all_rows])),
            recoveries=all_rows[0][i].recoveries,
        ))
    err = {k: float(np.median([e[k] for e in all_errs if k in e]))
           for k in all_errs[0]}
    return med_rows, err


def print_table(exp: str, rows, err) -> None:
    print(f"\n=== {exp} ===")
    print(f"{'Configuration':>16s} " + " ".join(f"{r.name:>8s}" for r in rows))
    print(f"{'Avg Latency (ms)':>16s} " +
          " ".join(f"{r.avg_latency_ms:8.0f}" for r in rows))
    print(f"{'Lat Viol (%)':>16s} " +
          " ".join(f"{100*r.lat_violation_frac:8.2f}" for r in rows))
    print(f"{'Recovery (s)':>16s} " +
          " ".join(f"{r.total_recovery_s:8.0f}" for r in rows))
    print(f"{'Rec Viol (s)':>16s} " +
          " ".join(f"{r.recovery_violation_s:8.0f}" for r in rows))
    print(f"{'Reconfigs':>16s} " +
          " ".join(f"{r.reconfigurations:8d}" for r in rows))
    print(f"error analysis: latency={err.get('latency_pct_error', float('nan')):.3f} "
          f"recovery={err.get('recovery_pct_error', float('nan')):.3f} "
          f"(paper: 0.099-0.122 / 0.073-0.131)")
