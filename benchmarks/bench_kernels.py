"""E6: kernel microbenchmarks — per-call wall time of the XLA reference
paths on CPU (the deployable CPU numbers) plus interpret-mode validation of
every Pallas kernel against its oracle.  TPU wall times come from the
roofline analysis (§Roofline), not from this CPU container."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ckpt_delta.ops import delta_decode, delta_encode
from repro.kernels.ckpt_delta.ref import decode_ref, encode_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_ref
from repro.kernels.rwkv6.ops import wkv6
from repro.kernels.rwkv6.ref import wkv6_ref


def _time(fn, *args, n=3, **kw):
    fn(*args, **kw)  # compile
    t0 = time.monotonic()
    for _ in range(n):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / n * 1e6


def bench_kernels():
    print("\n=== Kernels: oracle wall time (CPU) + interpret-mode validation ===")
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    B, S, H, K, hd = 1, 512, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    us = _time(jax.jit(lambda a, b, c: attention_ref(a, b, c, causal=True)), q, k, v)
    out = flash_attention(q, k, v, causal=True, block_q=128, block_kv=128,
                          interpret=True)
    ok = bool(jnp.allclose(out, attention_ref(q, k, v, causal=True), atol=1e-4))
    rows.append(("flash_attention", us, f"validated={ok} (B{B},S{S},H{H},K{K},hd{hd})"))

    D = 512
    a = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, D)) + 2.0)
    b = jax.random.normal(ks[4], (B, S, D)) * 0.1
    h0 = jnp.zeros((B, D))
    us = _time(jax.jit(rglru_ref), a, b, h0)
    out = rglru_scan(a, b, h0, interpret=True)
    ok = bool(jnp.allclose(out, rglru_ref(a, b, h0), atol=1e-4))
    rows.append(("rglru_scan", us, f"validated={ok} (S{S},D{D})"))

    Hh, hs = 4, 32
    r, kk, vv = (jax.random.normal(x, (B, S, Hh, hs)) * 0.5 for x in ks[5:8])
    w = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, Hh, hs))) * 0.3 + 0.65
    u = jax.random.normal(ks[1], (Hh, hs)) * 0.3
    s0 = jnp.zeros((B, Hh, hs, hs))
    us = _time(jax.jit(wkv6_ref), r, kk, vv, w, u, s0)
    y, _ = wkv6(r, kk, vv, w, u, s0, interpret=True)
    yr, _ = wkv6_ref(r, kk, vv, w, u, s0)
    ok = bool(jnp.allclose(y, yr, atol=1e-4))
    rows.append(("wkv6", us, f"validated={ok} (S{S},H{Hh},hs{hs})"))

    n = 1 << 20
    new = jax.random.normal(ks[2], (n,))
    base = new + jax.random.normal(ks[3], (n,)) * 0.01
    us = _time(lambda a, b: encode_ref(np.asarray(a - b)), new, base)
    qq, sc = delta_encode(new, base, interpret=True)
    d = delta_decode(qq, sc, interpret=True)[:n]
    ok = bool(jnp.max(jnp.abs((new - base) - d)) < 1e-3)
    rows.append(("ckpt_delta", us, f"validated={ok} (n=2^20, 4x byte cut)"))

    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows


def main():
    return bench_kernels()


if __name__ == "__main__":
    main()
