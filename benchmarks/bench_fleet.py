"""E11: fleet supervision — one control plane over many jobs, with
QoS-model transfer and admission control.

Eight jobs from four workload families (two constant-rate families in
different log2 rate bins, a diurnal family and a scalar-substrate family
with their own CI windows) run ALL THREE Khaos phases in one process
under a single ``FleetSupervisor``: Phase 1 per job at submit, Phase 2
POOLED (every cold job's z x m grid as lanes of one ``BatchedCampaign``),
Phase 3 multiplexed (one shared supervision campaign for the lane jobs,
scalar sims alongside, every controller polled on the same tick and
appending to one decision log).  A ninth firehose job is REJECTED by
admission control.

The artifact (``BENCH_fleet.json``, schema "bench_fleet/1") gates the
three fleet claims:

* SHARED TICK SCALES — supervising the 8-job fleet costs < 2x the
  controller wall-clock of supervising one job (the pooled campaign
  amortizes the tick across lanes);
* TRANSFER IS CHEAP — second-wave jobs whose fingerprints hit the
  ``QoSModelRegistry`` pay >= 5x less profiling lane-time than their
  cold-profiled donors (one validation-probe lane vs the z x m grid);
* TRANSFER IS SAFE — a transfer-admitted job's QoS-violation seconds
  stay within tolerance of its cold-profiled twin flying the same
  workload and the same failure schedule on the same shared campaign.

``smoke()`` is the micro drill ``benchmarks/run.py --smoke`` runs: three
jobs (one cold, one transfer-admitted, one rejected) through the same
pipeline, with the emitted artifact validated against the schema.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.config import KhaosConfig
from repro.config import replace as cfg_replace
from repro.data.stream import constant_rate, diurnal_rate
from repro.fleet import FleetJobSpec, FleetSupervisor
from repro.sim import SimCostModel

MIN_TRANSFER_RATIO = 5.0
MAX_WALLCLOCK_RATIO = 2.0
TWIN_TOLERANCE_S = 60.0


def _cost() -> SimCostModel:
    """One shared pricing model for the whole fleet (that is what makes
    the pooled campaign a single sweep), at modest utilization — the
    regime where fitted QoS surfaces genuinely transfer between
    near-twin jobs."""
    return SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.0,
                        state_bytes=2e9)


def _kcfg(**over) -> KhaosConfig:
    base = KhaosConfig(latency_constraint=1.5, recovery_constraint=240.0,
                       optimization_period=30.0, ci_min=10.0, ci_max=120.0,
                       num_failure_points=3, num_configs=3,
                       record_seconds=600.0, reconfig_cooldown=60.0)
    return cfg_replace(base, **over) if over else base


def _spec(name: str, sched, cfg: KhaosConfig, seed: int,
          substrate: str = "lane", horizon_s: float = 900.0) -> FleetJobSpec:
    return FleetJobSpec(name, _cost(), cfg, schedule=sched, seed=seed,
                        substrate=substrate, horizon_s=horizon_s,
                        failures=((500.0, "node"),),
                        profile_warmup_s=120.0,
                        profile_max_recovery_s=600.0)


# ---------------------------------------------------------------------------
# artifact schema gate
# ---------------------------------------------------------------------------

def validate_fleet_artifact(art: dict, min_jobs: int = 8) -> None:
    """Schema + claims gate for BENCH_fleet.json (raises ValueError)."""
    if art.get("schema") != "bench_fleet/1":
        raise ValueError(f"bench_fleet schema mismatch: {art.get('schema')}")
    for key in ("jobs", "transfer", "rejected", "decisions_by_kind",
                "shared_campaigns"):
        if key not in art:
            raise ValueError(f"bench_fleet artifact missing {key!r}")
    n_opt = sum(1 for j in art["jobs"].values()
                if j.get("phase") == "optimizing")
    if n_opt < min_jobs:
        raise ValueError(f"only {n_opt} jobs reached Phase 3 "
                         f"(need >= {min_jobs})")
    if art["shared_campaigns"] < 1:
        raise ValueError("no shared Phase-3 campaign was built")
    if not art["rejected"]:
        raise ValueError("admission control rejected nothing")
    tr = art["transfer"]
    if tr["n_transfer"] < 1:
        raise ValueError("no job was transfer-admitted")
    if tr["ratio"] < tr["min_ratio"]:
        raise ValueError(
            f"transfer profiling saving {tr['ratio']:.1f}x is below the "
            f"{tr['min_ratio']:.0f}x gate (cold {tr['cold_lane_ticks']:.0f} "
            f"ticks vs transfer {tr['transfer_lane_ticks']:.0f})")
    wc = art.get("wallclock")
    if wc is not None and not wc["ratio"] < wc["max_ratio"]:
        raise ValueError(
            f"fleet controller wall-clock {wc['fleet_s']:.3f}s is "
            f"{wc['ratio']:.2f}x the one-job baseline "
            f"{wc['one_job_s']:.3f}s (gate < {wc['max_ratio']:.1f}x)")
    for tw in art.get("twins", []):
        if abs(tw["delta_s"]) > tw["tolerance_s"]:
            raise ValueError(
                f"transfer twin {tw['transfer']} diverged from cold twin "
                f"{tw['cold']}: qos-violation delta {tw['delta_s']:.0f}s "
                f"exceeds {tw['tolerance_s']:.0f}s")


# ---------------------------------------------------------------------------
# the benchmark
# ---------------------------------------------------------------------------

WALLCLOCK_REPS = 3       # min-of-N: the claim is intrinsic controller
                         # cost, so the noise-robust estimator is the
                         # minimum over fresh builds, for BOTH sides


def _one_job_baseline(reps: int = WALLCLOCK_REPS) -> float:
    """Controller wall-clock of supervising ONE job end to end — the
    denominator of the shared-tick claim (min over ``reps`` builds)."""
    best = float("inf")
    for _ in range(reps):
        sup = FleetSupervisor(fleet_capacity_eps=6000.0)
        assert sup.submit(_spec("solo", constant_rate(1250.0), _kcfg(),
                                seed=11)).admitted
        sup.run_profiling_pooled()
        sup.start()
        t0 = time.perf_counter()
        sup.run(900.0, chunk_s=30.0)
        best = min(best, time.perf_counter() - t0)
    return best


def _build_fleet():
    """Submit both waves (plus the rejected firehose), pool Phase 2, and
    start Phase 3 — everything up to (not including) the timed run."""
    sup = FleetSupervisor(fleet_capacity_eps=13_000.0)
    cfg_a, cfg_b = _kcfg(), _kcfg()
    cfg_c = _kcfg(ci_min=15.0, ci_max=150.0)      # diurnal family
    cfg_d = _kcfg(ci_min=12.0, ci_max=110.0)      # scalar family
    wave1 = [
        _spec("iot-cold", constant_rate(650.0), cfg_a, seed=0),
        _spec("ysb-cold", constant_rate(1250.0), cfg_b, seed=1),
        _spec("diurnal-cold", diurnal_rate(base=450.0, amplitude=0.4),
              cfg_c, seed=2),
        _spec("scalar-cold", constant_rate(1000.0), cfg_d, seed=3,
              substrate="scalar", horizon_s=300.0),
    ]
    for s in wave1:
        dec = sup.submit(s)
        assert dec.admitted, (s.name, dec.reason)
    rejected = sup.submit(_spec("firehose", constant_rate(20_000.0),
                                _kcfg(), seed=8))
    prof = sup.run_profiling_pooled()

    wave2 = [
        _spec("iot-xfer", constant_rate(650.0), cfg_a, seed=4),
        _spec("ysb-xfer", constant_rate(1250.0), cfg_b, seed=5),
        _spec("diurnal-xfer", diurnal_rate(base=450.0, amplitude=0.4),
              cfg_c, seed=6),
        _spec("scalar-xfer", constant_rate(1000.0), cfg_d, seed=7,
              substrate="scalar", horizon_s=300.0),
    ]
    for s in wave2:
        dec = sup.submit(s)
        assert dec.admitted, (s.name, dec.reason)
    sup.run_profiling_pooled()      # safety net: cold path for failed probes

    sup.start()
    return sup, rejected, prof, len(wave1) + len(wave2)


def bench_fleet(out: str = "BENCH_fleet.json", verbose: bool = True) -> dict:
    one_job_s = _one_job_baseline()

    fleet_s = float("inf")
    for _ in range(WALLCLOCK_REPS):
        sup, rejected, prof, n_jobs = _build_fleet()
        t0 = time.perf_counter()
        status = sup.run(900.0, chunk_s=30.0)
        fleet_s = min(fleet_s, time.perf_counter() - t0)

    cold = [j for j in sup.jobs.values() if j.runtime is not None
            and not j.transferred and j.reprofiles == 0]
    xfer = [j for j in sup.jobs.values() if j.transferred]
    cold_ticks = float(np.mean([j.profiling_lane_ticks for j in cold]))
    xfer_ticks = float(np.mean([j.profiling_lane_ticks for j in xfer])) \
        if xfer else float("inf")
    twins = []
    for c, x in (("iot-cold", "iot-xfer"), ("ysb-cold", "ysb-xfer")):
        if not sup.jobs[x].transferred:
            continue
        vc = sup.qos_violations(c)["qos_violation_s"]
        vx = sup.qos_violations(x)["qos_violation_s"]
        twins.append({"cold": c, "transfer": x,
                      "cold_qos_violation_s": vc,
                      "transfer_qos_violation_s": vx,
                      "delta_s": vx - vc,
                      "tolerance_s": TWIN_TOLERANCE_S})

    art = {
        "schema": "bench_fleet/1",
        "fleet_capacity_eps": sup.fleet_capacity_eps,
        "jobs": status["jobs"],
        "pooled_phase2": prof,
        "shared_campaigns": status["shared_campaigns"],
        "decisions_by_kind": status["decisions_by_kind"],
        "rejected": [n for n, j in sup.jobs.items()
                     if j.status == "rejected"],
        "rejected_reason": rejected.reason,
        "wallclock": {"one_job_s": one_job_s, "fleet_s": fleet_s,
                      "ratio": fleet_s / max(one_job_s, 1e-9),
                      "max_ratio": MAX_WALLCLOCK_RATIO,
                      "reps": WALLCLOCK_REPS},
        "transfer": {"n_transfer": len(xfer), "n_cold": len(cold),
                     "cold_lane_ticks": cold_ticks,
                     "transfer_lane_ticks": xfer_ticks,
                     "ratio": cold_ticks / max(xfer_ticks, 1e-9),
                     "min_ratio": MIN_TRANSFER_RATIO},
        "twins": twins,
    }
    validate_fleet_artifact(art, min_jobs=8)
    with open(out, "w") as f:
        json.dump(art, f, indent=2)
    if verbose:
        wc, tr = art["wallclock"], art["transfer"]
        print(f"fleet of {n_jobs}: controller wall-clock "
              f"{wc['fleet_s']:.3f}s vs one-job {wc['one_job_s']:.3f}s "
              f"({wc['ratio']:.2f}x, gate < {wc['max_ratio']:.1f}x)")
        print(f"transfer profiling: cold {tr['cold_lane_ticks']:.0f} lane-"
              f"ticks vs transfer {tr['transfer_lane_ticks']:.0f} "
              f"({tr['ratio']:.1f}x less, gate >= {tr['min_ratio']:.0f}x); "
              f"{tr['n_transfer']} of {n_jobs // 2} wave-2 jobs "
              f"transferred")
        for tw in twins:
            print(f"twin {tw['cold']} vs {tw['transfer']}: qos-violation "
                  f"{tw['cold_qos_violation_s']:.0f}s vs "
                  f"{tw['transfer_qos_violation_s']:.0f}s "
                  f"(|delta| <= {tw['tolerance_s']:.0f}s)")
        print(f"rejected: {art['rejected']} ({art['rejected_reason']}); "
              f"decisions {art['decisions_by_kind']}")
        print(f"wrote {out}")
    return art


# ---------------------------------------------------------------------------
# smoke drill (run.py --smoke)
# ---------------------------------------------------------------------------

def smoke(tmpdir: str = "/tmp/repro_bench_fleet_smoke") -> dict:
    """Micro fleet drill: one cold job, one transfer-admitted twin, one
    firehose rejected by admission — the emitted artifact must validate
    against "bench_fleet/1" (AssertionError/ValueError on regression)."""
    os.makedirs(tmpdir, exist_ok=True)
    sup = FleetSupervisor(fleet_capacity_eps=4500.0)
    cfg = _kcfg()

    def spec(name, rate, seed, horizon=300.0):
        return FleetJobSpec(name, _cost(), cfg, schedule=constant_rate(rate),
                            seed=seed, horizon_s=horizon,
                            profile_warmup_s=120.0,
                            profile_max_recovery_s=600.0)

    assert sup.submit(spec("cold", 1250.0, seed=0)).action == "admit"
    sup.run_profiling_pooled()
    dec = sup.submit(spec("xfer", 1250.0, seed=1))
    assert dec.action == "admit_transfer", \
        f"twin did not ride the registry: {dec.action} ({dec.reason})"
    rej = sup.submit(spec("firehose", 20_000.0, seed=2))
    assert rej.action == "reject", rej.action
    sup.start()
    status = sup.run(300.0, chunk_s=30.0)

    cold, xfer = sup.jobs["cold"], sup.jobs["xfer"]
    art = {
        "schema": "bench_fleet/1",
        "fleet_capacity_eps": sup.fleet_capacity_eps,
        "jobs": status["jobs"],
        "shared_campaigns": status["shared_campaigns"],
        "decisions_by_kind": status["decisions_by_kind"],
        "rejected": [n for n, j in sup.jobs.items()
                     if j.status == "rejected"],
        "transfer": {"n_transfer": 1, "n_cold": 1,
                     "cold_lane_ticks": float(cold.profiling_lane_ticks),
                     "transfer_lane_ticks": float(xfer.profiling_lane_ticks),
                     "ratio": cold.profiling_lane_ticks /
                     max(xfer.profiling_lane_ticks, 1),
                     "min_ratio": MIN_TRANSFER_RATIO},
    }
    path = os.path.join(tmpdir, "BENCH_fleet.json")
    with open(path, "w") as f:
        json.dump(art, f, indent=2)
    with open(path) as f:
        validate_fleet_artifact(json.load(f), min_jobs=2)
    print(f"fleet smoke OK: cold/transfer/rejected = "
          f"{[j['status'] for j in status['jobs'].values()]}, "
          f"transfer saving {art['transfer']['ratio']:.1f}x, "
          f"artifact validated at {path}")
    return art


def main():
    print("\n=== E11: fleet supervisor — admission, QoS-model transfer, "
          "one multiplexed tick over 8 jobs ===")
    return bench_fleet()


if __name__ == "__main__":
    main()
