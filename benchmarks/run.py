"""Benchmark driver — one section per paper table/figure (DESIGN.md §6):

  E1  IoT-Vehicles analogue  (paper Table II, Fig. 2a/2c, Fig. 3a)
  E2  YSB analogue           (paper Table III, Fig. 2b/2d, Fig. 3b)
  E4  recovery/latency vs CI (paper §III-C premise)
  E5  checkpoint subsystem   (beyond-paper; calibrates sim cost model)
  E6  kernel validation      (oracle timings + interpret-mode allclose)
  E7  dry-run / roofline     (reads experiments/dryrun.json)

Usage: PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="single repetition for E1/E2 (default: median of 3)")
    args = ap.parse_args()

    t0 = time.monotonic()
    from benchmarks import (bench_ckpt, bench_dryrun, bench_kernels,
                            bench_khaos_training, bench_recovery,
                            bench_tables)

    repeats = 1 if args.quick else 3
    bench_tables.bench_iot_vehicles(repeats=repeats)
    bench_tables.bench_ysb(repeats=repeats)
    bench_recovery.main()
    bench_khaos_training.main()
    bench_ckpt.main()
    bench_kernels.main()
    bench_dryrun.main()
    print(f"\nall benchmarks done in {time.monotonic() - t0:.0f}s")


if __name__ == "__main__":
    main()
