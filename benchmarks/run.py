"""Benchmark driver — one section per paper table/figure (DESIGN.md §6):

  E1  IoT-Vehicles analogue  (paper Table II, Fig. 2a/2c, Fig. 3a)
  E2  YSB analogue           (paper Table III, Fig. 2b/2d, Fig. 3b)
  E4  recovery/latency vs CI (paper §III-C premise; scalar oracle AND the
                              batched campaign — emits BENCH_sim.json with
                              the lane-vs-scalar table, the campaign
                              throughput measurement, and the embedded E10
                              proactive section and the E12 device-engine
                              section, schema "bench_sim/3")
  E5  checkpoint subsystem   (beyond-paper; emits the BENCH_ckpt.json
                              calibration artifact the sim cost model loads)
  E6  kernel validation      (oracle timings + interpret-mode allclose)
  E7  dry-run / roofline     (reads experiments/dryrun.json)
  E10 proactive vs reactive  (forecast-driven plan switching + anomaly-
                              triggered reprofiling vs a reactive twin on
                              ONE gray-failure campaign; its result is the
                              "proactive" section of BENCH_sim.json and the
                              validator gates a STRICT proactive win)
  E12 device mega-campaigns  (jitted DeviceCampaign vs the NumPy lanes:
                              throughput at 1e3/1e4/1e5 lanes, the
                              bit-exact parity matrix, and the exhaustive
                              device plan sweep vs top-k replay — the
                              "device" section of BENCH_sim.json)
  E11 fleet supervisor       (one control plane over 8+ concurrent jobs —
                              emits BENCH_fleet.json, schema "bench_fleet/1",
                              gating the shared-tick wall-clock ratio < 2x
                              one job, >= 5x less profiling lane-time via
                              QoS-model transfer with matched twin QoS, and
                              admission-control rejection of an infeasible
                              firehose job)

Usage: PYTHONPATH=src python -m benchmarks.run [--quick | --smoke]

``--smoke`` is the tier-1-adjacent CI check: it runs the E5 checkpoint
bench on a tiny state (device-placement delta encodes included, plus a
micro trainer on an ``encode_placement="device"`` plan in interpret
mode), the PROACTIVE-CONTROL DRILL (diurnal ramp + backpressure window +
crash on one supervised lane, asserting a forecast-driven plan switch
BEFORE the λ peak and an anomaly-triggered ``reprofile`` re-entry in the
phase log), a tiny 4-lane E4 campaign, a tiny end-to-end ``KhaosRuntime``
(all three phases on a 4-lane controller-in-the-loop campaign + a micro
live trainer with a mid-run plan switch), and the replication RECOVERY
DRILL (save under k=1 ring replication, kill one host, assert the
degraded partial restore is bit-exact and pulls only the failed host's
shard bytes — ``restored_bytes < full_state_bytes`` — plus the peer-loss
worst case through the per-shard remote fallback and the optimizer's
``replication_factor`` dimension), and the FLEET DRILL (a 3-job
supervisor: one cold admit, one fingerprint-matched transfer admit that
skips Phase 2 via the QoS-model registry, and one firehose rejected by
admission control, validating the emitted BENCH_fleet.json against
``bench_fleet.validate_fleet_artifact``), validating that the emitted
BENCH_ckpt.json / BENCH_sim.json artifacts match their schemas
("bench_ckpt/3" via ``SimCostModel.from_calibration`` — placement/codec
fields, int8 link fraction <= 0.26, the fused flat device encode under
the per-leaf dispatch baseline, with "bench_ckpt/1" and "/2" artifacts
still loadable as the versioned fallbacks; "bench_sim/2" via
``bench_recovery.validate_sim_artifact``, which also gates the embedded
proactive drill) and that the phase order /
JobHandle protocol have not regressed — exiting non-zero on any
mismatch.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="single repetition for E1/E2 (default: median of 3)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-state bench_ckpt + BENCH artifact schema "
                         "validation + end-to-end KhaosRuntime phase/"
                         "protocol gate (tier-1-adjacent check)")
    args = ap.parse_args()

    # Pin the XLA CPU backend to a pre-FMA ISA BEFORE any bench initializes
    # a backend: the device campaign's bit-exact parity gate needs it
    # (importing sim.device appends the flag as a side effect).
    from repro.sim.device import ensure_bitexact_cpu
    ensure_bitexact_cpu()

    t0 = time.monotonic()
    if args.smoke:
        from benchmarks import (bench_campaign, bench_ckpt, bench_fleet,
                                bench_proactive, bench_recovery,
                                bench_replication, bench_runtime)
        try:
            bench_ckpt.smoke()
            # the proactive drill's summary and the device-engine section
            # are embedded (and gated) in the BENCH_sim.json artifact that
            # bench_recovery.smoke() emits — the device parity gate
            # (divergent_lanes == 0) runs on a small CPU campaign here
            proactive = bench_proactive.smoke()
            device = bench_campaign.device_section(smoke=True)
            bench_recovery.smoke(proactive=proactive, device=device)
            bench_replication.smoke()
            bench_runtime.smoke()
            bench_fleet.smoke()
        except (ValueError, AssertionError) as e:
            print(f"SMOKE FAILED: {e}", file=sys.stderr)
            sys.exit(1)
        print(f"smoke done in {time.monotonic() - t0:.0f}s")
        return
    from benchmarks import (bench_campaign, bench_ckpt, bench_dryrun,
                            bench_fleet, bench_kernels,
                            bench_khaos_training, bench_proactive,
                            bench_recovery, bench_replication, bench_tables)

    repeats = 1 if args.quick else 3
    bench_tables.bench_iot_vehicles(repeats=repeats)
    bench_tables.bench_ysb(repeats=repeats)
    proactive = bench_proactive.main()
    device = bench_campaign.main()
    bench_recovery.main(proactive=proactive, device=device)
    bench_replication.main()
    bench_fleet.main()
    bench_khaos_training.main()
    bench_ckpt.main()
    bench_kernels.main()
    bench_dryrun.main()
    print(f"\nall benchmarks done in {time.monotonic() - t0:.0f}s")


if __name__ == "__main__":
    main()
