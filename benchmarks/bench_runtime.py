"""Smoke gate for the unified control-plane API (``benchmarks/run.py
--smoke`` runs this next to the BENCH_ckpt/BENCH_sim schema checks).

Drives a tiny end-to-end ``KhaosRuntime``: all three phases against a
4-lane controller-in-the-loop campaign, plus a micro live trainer whose
checkpoint plan is switched mid-run through ``TrainerJobHandle`` — and
fails (raises) on phase-order regressions, protocol regressions (a handle
missing a ``JobHandle`` method) or Decision-kind drift.
"""
from __future__ import annotations

import shutil

from repro.config import CheckpointPlan, KhaosConfig, OptimizerConfig
from repro.core import (Decision, KhaosRuntime, missing_handle_methods,
                        PhaseError)
from repro.data.stream import constant_rate, dense_rates, record_workload
from repro.sim import (BatchedCampaign, BatchedDeployment, LaneSpec,
                       SimCostModel, SimJobHandle, StreamSimulator)


def _assert(cond: bool, msg: str) -> None:
    if not cond:
        raise ValueError(f"runtime smoke: {msg}")


def smoke(tmpdir: str = "/tmp/repro_bench_runtime_smoke") -> dict:
    shutil.rmtree(tmpdir, ignore_errors=True)
    cost = SimCostModel(capacity_eps=2600.0, ckpt_duration_s=1.0)
    sched = constant_rate(1800.0)
    recording = record_workload(sched, duration=1200, seed=0)
    kcfg = KhaosConfig(latency_constraint=1.5, recovery_constraint=240.0,
                       optimization_period=30.0, ci_min=10, ci_max=120,
                       num_failure_points=2, num_configs=2,
                       reconfig_cooldown=60.0)

    # -- phase order is enforced, not advisory ---------------------------
    try:
        KhaosRuntime(kcfg).run_profiling(BatchedDeployment(cost, recording))
    except PhaseError:
        pass
    else:
        raise ValueError("runtime smoke: Phase 2 ran before Phase 1")

    # -- phases 1 -> 2 -> 3 on a 4-lane campaign -------------------------
    rt = KhaosRuntime(kcfg, cost=cost)
    rt.record_steady_state(recording)
    rt.run_profiling(
        BatchedDeployment(cost, recording, warmup_s=120,
                          max_recovery_s=900.0),
        ci_values=[30, 90], margin=60)
    T = 600
    lanes = [LaneSpec(rates=dense_rates(0.0, T, schedule=sched),
                      ci_s=float(ci)) for ci in (20, 60, 90, 115)]
    camp = BatchedCampaign(cost, lanes)
    sup = rt.drive_campaign(camp)
    _assert(rt.phase_sequence() == ["steady_state", "profiled", "optimizing"],
            f"phase order regressed: {rt.phase_sequence()}")
    _assert(camp.done, "campaign did not run to completion")
    summary = sup.summary()
    _assert(summary["lanes"] == 4, f"expected 4 supervised lanes: {summary}")
    for ctl in sup.controllers:
        for d in ctl.decisions:
            _assert(d.kind in Decision.KINDS,
                    f"unknown Decision kind {d.kind!r}")

    # -- protocol conformance across every handle ------------------------
    sim = StreamSimulator(cost, ci_s=60.0, schedule=sched)
    for handle in (SimJobHandle(sim), sup.handles[0]):
        missing = missing_handle_methods(handle)
        _assert(not missing,
                f"{type(handle).__name__} missing protocol methods {missing}")

    # -- micro live trainer: plan switch through the same protocol -------
    from repro.configs import get_smoke_config
    from repro.data.stream import EventStream
    from repro.runtime import (ResilientTrainer, TrainerConfig,
                               TrainerJobHandle)

    stream = EventStream(schedule=constant_rate(500.0))
    tcfg = TrainerConfig(batch=4, seq_len=16, ckpt_dir=tmpdir,
                         ckpt_interval_s=4.0, time_scale=20.0,
                         detect_s=1.0, restart_s=1.0)
    trainer = ResilientTrainer(get_smoke_config("yi-6b"), tcfg, stream,
                               OptimizerConfig(total_steps=1000, lr=1e-3))
    job = TrainerJobHandle(trainer)
    missing = missing_handle_methods(job)
    _assert(not missing, f"TrainerJobHandle missing {missing}")
    trainer.run(duration_s=10.0)
    step_before = int(trainer.state["step"])
    new_plan = CheckpointPlan(interval_s=3.0, mode="incremental",
                              full_every=2, levels=("memory", "local"),
                              sync=False, num_shards=2)
    job.reconfigure_plan(new_plan)
    _assert(trainer.ckpt.plan.name == new_plan.name,
            "trainer did not rebuild the manager from the new plan")
    trainer.run(duration_s=10.0)
    summary = trainer.summary()
    _assert(summary["plan_switches"] == 1, "plan switch not recorded")
    _assert(int(trainer.state["step"]) > step_before,
            "trainer made no progress after the plan switch")
    _assert(summary["ckpt_stats"]["plan"] == new_plan.name,
            "checkpoint stats not under the new plan")
    _assert(summary["ckpt_stats"]["saves"] >= 1,
            "no checkpoint landed under the new plan")
    print(f"runtime smoke OK: phases {' -> '.join(rt.phase_sequence())}, "
          f"{summary['checkpoints']} trainer checkpoints, plan switched to "
          f"[{new_plan.name}] mid-run, campaign decisions "
          f"{sup.summary()['decisions_by_kind']}")
    return {"phases": rt.phase_sequence(), "campaign": sup.summary(),
            "trainer": {k: summary[k] for k in
                        ("checkpoints", "plan_switches")}}


if __name__ == "__main__":
    smoke()
