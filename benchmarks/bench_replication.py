"""Recovery drill: the level-2 survival assumption, exercised for real.

The drill saves a checkpoint under k=1 ring replication (levels
local+remote), kills ONE host (its primary shards and the replicas it
held die with it), and then proves the three acceptance properties the
peer-replication plane owes:

  1. the node-failure restore recovers BIT-EXACT from peer replicas,
     pulling strictly fewer bytes than a full remote restore (degraded
     PARTIAL restore: only the dead host's shards move);
  2. with replication disabled (rep0) the same failure degrades to the
     remote level — and the cost model prices both paths, deriving
     per-kind survival from placement+k (the modeled degraded fraction is
     asserted against the drill's measured bytes);
  3. the worst case for k=1 — ``peer_loss``, the host AND its replica
     peer dying in one window — still recovers bit-exact through the
     per-shard remote fallback, and ``optimize_plan``'s variant grid
     carries the ``replication_factor`` dimension that trades this
     replica traffic against recovery time.

Run via ``python -m benchmarks.run --smoke`` (the tier-1-adjacent gate).
"""
from __future__ import annotations

import os
import shutil
import tempfile

import numpy as np


def _state(seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    return {
        "w0": rng.standard_normal((128, 64)).astype(np.float32),
        "w1": rng.standard_normal((96, 96)).astype(np.float32),
        "b0": rng.standard_normal((2048,)).astype(np.float32),
        "b1": rng.standard_normal((777,)).astype(np.float32),
        "step": np.asarray(1234, dtype=np.int64),
    }


def _bit_exact(a: dict, b: dict) -> bool:
    return set(a) == set(b) and all(
        np.array_equal(np.asarray(a[k]), np.asarray(b[k])) for k in a)


def recovery_drill(root: str, verbose: bool = True) -> dict:
    """The k=1 drill (properties 1 and 2 above).  Returns the measured
    record; raises AssertionError on any violated gate."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.config import CheckpointPlan
    from repro.ft.failures import FailureInjector
    from repro.sim import SimCostModel

    state = _state()
    plan = CheckpointPlan(levels=("local", "remote"), remote_every=1,
                         num_shards=4, replication_factor=1)
    mgr = CheckpointManager(os.path.join(root, "rep1"), plan)
    mgr.save(100, state, timestamp=1.0)

    # worst-case, host-targeted node failure (paper §III-C timing + the
    # placement-aware kill): host 2's shards and held replicas die
    inj = FailureInjector()
    failure = inj.worst_case_failure(requested_t=100.0, last_ckpt_t=1.0,
                                     interval_s=60.0, ckpt_cost_s=2.5,
                                     kind="node", host=2)
    mgr.on_failure(failure.kind, host=failure.host)
    report = mgr.restore(state, failure.kind)

    full_bytes = mgr.stores["local"].total_bytes(100)
    assert report.level == "local", \
        f"k=1 node restore must stay at level-2, got {report.level!r}"
    assert report.degraded and report.restored_bytes > 0, \
        "the host-targeted kill must force a degraded partial restore"
    assert _bit_exact(report.state, state), \
        "degraded partial restore is not bit-exact"
    # the partial-restore gate: only the failed host's shard bytes moved
    assert report.restored_bytes < full_bytes, (
        f"degraded restore pulled {report.restored_bytes} bytes, not fewer "
        f"than the {full_bytes}-byte full checkpoint")
    # modeled vs measured: the cost model derives node survival from
    # placement+k and prices the degraded pull at ~1/num_hosts of the
    # state; bin-packing skew is bounded by 2x
    cost = SimCostModel(state_bytes=float(full_bytes))
    assert cost.surviving_levels(plan, "node") == ("local", "remote")
    modeled_fraction = 1.0 / mgr.stores["local"].num_hosts
    measured_fraction = report.restored_bytes / full_bytes
    assert measured_fraction <= 2.0 * modeled_fraction, (
        f"measured degraded pull {measured_fraction:.3f} of state vs "
        f"modeled {modeled_fraction:.3f} (tolerance 2x for bin-packing)")
    # replica traffic was actually pushed and accounted
    stats = mgr.stores["local"].replica_stats
    assert stats.acks >= plan.num_shards and stats.replica_bytes > 0

    # rep0: same failure, no replicas -> the restore degrades to remote,
    # and the cost model's derived survival says so before the bytes do
    plan0 = CheckpointPlan(levels=("local", "remote"), remote_every=1,
                          num_shards=4, replication_factor=0)
    mgr0 = CheckpointManager(os.path.join(root, "rep0"), plan0)
    mgr0.save(100, state, timestamp=1.0)
    assert cost.surviving_levels(plan0, "node") == ("remote",)
    mgr0.on_failure("node", host=2)
    report0 = mgr0.restore(state, "node")
    assert report0.level == "remote", \
        f"rep0 node restore must degrade to remote, got {report0.level!r}"
    assert _bit_exact(report0.state, state)
    # both paths are priced, and the degraded-local path is the cheaper
    # recovery (remote restores pay the remote_restore_factor)
    d_rep1 = cost.plan_downtime_s(plan, "node")
    d_rep0 = cost.plan_downtime_s(plan0, "node")
    assert d_rep1 < d_rep0, (d_rep1, d_rep0)

    rec = {"restored_bytes": int(report.restored_bytes),
           "full_state_bytes": int(full_bytes),
           "measured_fraction": float(measured_fraction),
           "modeled_fraction": float(modeled_fraction),
           "replica_bytes": int(stats.replica_bytes),
           "downtime_rep1_s": float(d_rep1),
           "downtime_rep0_s": float(d_rep0)}
    if verbose:
        print(f"  recovery drill: degraded restore pulled "
              f"{rec['restored_bytes']}/{rec['full_state_bytes']} bytes "
              f"({measured_fraction:.1%}, modeled {modeled_fraction:.1%}); "
              f"rep0 degraded to remote "
              f"({d_rep0:.1f}s vs {d_rep1:.1f}s downtime)")
    return rec


def peer_loss_drill(root: str, verbose: bool = True) -> dict:
    """Property 3: the k=1 worst case (host + its replica peer die in one
    window) recovers bit-exact through the per-shard remote fallback."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.checkpoint.replication import PeerReplicatedStore
    from repro.config import CheckpointPlan
    from repro.ft.failures import FailureInjector

    state = _state(seed=7)
    plan = CheckpointPlan(levels=("local", "remote"), remote_every=1,
                         num_shards=4, replication_factor=1)
    mgr = CheckpointManager(os.path.join(root, "peer_loss"), plan)
    mgr.save(200, state, timestamp=1.0)

    failures = FailureInjector().peer_loss(
        requested_t=100.0, last_ckpt_t=1.0, interval_s=60.0,
        ckpt_cost_s=2.5, host=1, num_hosts=4,
        replication_factor=plan.replication_factor)
    assert len(failures) == 2 and failures[0].t < failures[1].t
    for f in failures:
        mgr.on_failure(f.kind, host=f.host)
    report = mgr.restore(state, "node")
    store = mgr.stores["local"]
    assert isinstance(store, PeerReplicatedStore)
    assert _bit_exact(report.state, state), \
        "peer-loss restore is not bit-exact"
    assert report.degraded
    assert store.last_restore["shards_from_remote"] >= 1, \
        "peer loss must exercise the per-shard remote fallback"
    rec = dict(store.last_restore)
    if verbose:
        print(f"  peer-loss drill: {rec['shards_from_primary']} primary + "
              f"{rec['shards_from_peer']} peer + "
              f"{rec['shards_from_remote']} remote shards, "
              f"{rec['restored_bytes']} bytes pulled")
    return rec


def optimizer_dimension_check(verbose: bool = True) -> None:
    """The replication_factor plan dimension is reachable by
    ``optimize_plan``'s default variant grid, and the model prices its
    traffic/recovery trade."""
    from repro.core.ci_optimizer import default_plan_variants
    from repro.sim import SimCostModel

    cost = SimCostModel(state_bytes=1e9)
    variants = default_plan_variants(cost, ci_ref=60.0)
    reps = sorted({p.replication_factor for p in variants})
    assert 0 in reps and 1 in reps and 2 in reps, (
        f"variant grid lost the replication dimension: {reps}")
    p0 = next(p for p in variants if p.replication_factor == 0)
    p2 = next(p for p in variants if p.replication_factor == 2)
    # traffic ordering: more replicas, more interconnect bytes
    assert cost.avg_replica_bytes(p0) == 0.0
    assert cost.avg_replica_bytes(p2) > 0.0
    # recovery ordering: replicas buy the faster level-2 node restore
    assert cost.plan_downtime_s(p2, "node") < cost.plan_downtime_s(p0, "node")
    if verbose:
        print(f"  optimizer grid: replication factors {reps}, "
              f"rep2 replica traffic "
              f"{cost.avg_replica_bytes(p2) / 1e9:.2f} GB/trigger vs "
              f"rep0 downtime {cost.plan_downtime_s(p0, 'node'):.0f}s")


def smoke() -> None:
    """The --smoke gate: all three drills on a fresh scratch dir."""
    root = tempfile.mkdtemp(prefix="bench_replication_")
    try:
        recovery_drill(root)
        peer_loss_drill(root)
        optimizer_dimension_check()
    finally:
        shutil.rmtree(root, ignore_errors=True)


def main() -> None:
    print("\n== replication recovery drill ==")
    smoke()


if __name__ == "__main__":
    main()
