"""E4: recovery time & latency vs CI at fixed load — the paper's §III-C
premise (and the shape M_R must capture), plus the Young/Daly point for
reference."""
from __future__ import annotations

import numpy as np

from repro.core import young_daly_interval
from repro.data.stream import constant_rate
from repro.ft.failures import FailureInjector
from repro.sim import SimCostModel, StreamSimulator


def bench_recovery_vs_ci():
    cost = SimCostModel(capacity_eps=4600.0, base_latency_s=0.5,
                        ckpt_duration_s=3.0, ckpt_sync_penalty=0.6)
    rate = 3000.0
    print("\n=== Recovery & latency vs CI (constant 3000 ev/s, worst-case failure) ===")
    print(f"{'CI (s)':>8s} {'avg latency (ms)':>18s} {'recovery (s)':>14s}")
    rows = []
    for ci in (10, 20, 30, 60, 90, 120, 180, 240):
        sim = StreamSimulator(cost, ci_s=float(ci), schedule=constant_rate(rate))
        t = FailureInjector().worst_case_time(3 * ci + 5.0, 0.0, ci,
                                              cost.ckpt_duration_s)
        sim.inject_failure(t)
        sim.run_until(t + 5000.0)
        lat_pre = sim.metrics.series("latency").mean_over(0, t) * 1e3
        rec = sim.recoveries[0]["recovery_s"] if sim.recoveries else float("nan")
        rows.append((ci, lat_pre, rec))
        print(f"{ci:8d} {lat_pre:18.0f} {rec:14.0f}")
    yd = young_daly_interval(cost.ckpt_duration_s, mtbf_s=4 * 3600.0)
    print(f"Young/Daly optimum for MTBF=4h, delta={cost.ckpt_duration_s}s: "
          f"{yd:.0f}s (static, workload-blind — the gap Khaos closes)")
    return rows


def main():
    return bench_recovery_vs_ci()


if __name__ == "__main__":
    main()
