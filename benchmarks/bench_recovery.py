"""E4: recovery time & latency vs CI at fixed load — the paper's §III-C
premise (and the shape M_R must capture), plus the Young/Daly point for
reference.

The E4 grid now runs twice: through the scalar ``StreamSimulator`` loop
(the oracle) and as lanes of one ``BatchedCampaign``, which must reproduce
the same table rows.  A 10x larger scenario grid (CI x mechanism x failure
kind x workload, >= 200 lanes) then measures campaign throughput, and the
whole measurement is emitted as the ``BENCH_sim.json`` artifact (schema
"bench_sim/3") — the perf trajectory of the vectorized simulator, next to
``BENCH_ckpt.json``'s "bench_ckpt/1" checkpoint-plane calibration.

bench_sim/3 schema:
  schema               "bench_sim/3"
  e4                   the equivalence gate: per-CI latency/recovery from
                       BOTH engines, wall-clocks, max absolute divergence
  grid                 the throughput measurement: lanes, lane_ticks,
                       wall_s, lane_ticks_per_s, recovered_fraction,
                       compactions/lanes_compacted (lane-level early exit:
                       recovered lanes are compacted out of the arrays),
                       and the scenario axes the lanes span
  proactive            the E10 proactive-control result
                       (``bench_proactive``): either the full head-to-head
                       (per-config ``qos_violation_s`` — the validator
                       gates Khaos-proactive STRICTLY below Khaos-reactive,
                       with >= 1 forecast-driven plan switch) or, under
                       ``--smoke``, the micro drill summary (pre-act before
                       the peak, a ``reprofile`` re-entry in the phase log,
                       backpressure-suppressed cadence slots)
  device               the E12 device-engine section (``bench_campaign``):
                       throughput (NumPy vs device lane-ticks/s at
                       1e3/1e4/1e5 lanes), parity (the HARD gate —
                       ``divergent_lanes`` must be 0 across the full
                       plan x crash x degradation matrix), and sweep
                       (exhaustive device plan replay vs top-k, gated
                       ``exhaustive_objective <= topk_objective``; null
                       under ``--smoke``)
  scalar_ticks_per_s   the scalar loop's measured tick rate
  speedup              grid lane-ticks/s over scalar ticks/s (the >= 20x
                       campaign-throughput target)

"bench_sim/1" (no proactive section) and "bench_sim/2" (no device
section) are no longer emitted; readers treat them as stale artifacts
and re-run the bench.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import numpy as np

from repro.config import CheckpointPlan
from repro.core import young_daly_interval
from repro.data.stream import constant_rate, dense_rates, diurnal_rate
from repro.ft.failures import FailureInjector
from repro.sim import (BatchedCampaign, LaneSpec, SimCostModel,
                       StreamSimulator)

E4_CIS = (10, 20, 30, 60, 90, 120, 180, 240)
E4_RATE = 3000.0
E4_HORIZON_S = 5000.0          # post-injection window of the scalar sweep
GRID_HORIZON = 2200            # ticks per grid lane (recovery completes well
                               # inside this for every grid scenario family)

SIM_SCHEMA = "bench_sim/3"
SIM_SCHEMA_KEYS = ("schema", "e4", "grid", "proactive", "device",
                   "scalar_ticks_per_s", "speedup")


def _e4_cost() -> SimCostModel:
    return SimCostModel(capacity_eps=4600.0, base_latency_s=0.5,
                        ckpt_duration_s=3.0, ckpt_sync_penalty=0.6)


def _worst_case(ci: float, cost: SimCostModel) -> float:
    return FailureInjector().worst_case_time(3 * ci + 5.0, 0.0, float(ci),
                                             cost.ckpt_duration_s)


# ---------------------------------------------------------------------------
# E4 grid, both engines
# ---------------------------------------------------------------------------

def scalar_e4(cost: SimCostModel, cis=E4_CIS) -> tuple[list, float, int]:
    """The original sequential sweep; returns (rows, wall_s, ticks)."""
    rows, ticks = [], 0
    t0 = time.perf_counter()
    for ci in cis:
        sim = StreamSimulator(cost, ci_s=float(ci),
                              schedule=constant_rate(E4_RATE))
        t = _worst_case(ci, cost)
        sim.inject_failure(t)
        sim.run_until(t + E4_HORIZON_S)
        lat_pre = sim.metrics.series("latency").mean_over(0, t) * 1e3
        rec = sim.recoveries[0]["recovery_s"] if sim.recoveries else float("nan")
        rows.append((ci, lat_pre, rec))
        ticks += len(sim.metrics.series("latency"))
    return rows, time.perf_counter() - t0, ticks


def e4_lanes(cost: SimCostModel, cis=E4_CIS) -> list[LaneSpec]:
    lanes = []
    for ci in cis:
        t = _worst_case(ci, cost)
        n = int(np.ceil(t + E4_HORIZON_S))
        lanes.append(LaneSpec(
            rates=dense_rates(0.0, n, schedule=constant_rate(E4_RATE)),
            ci_s=float(ci), failures=((t, "node"),),
            tag={"e4_ci": float(ci), "inject_t": t}))
    return lanes


def batched_e4(cost: SimCostModel, cis=E4_CIS) -> tuple[list, float]:
    """Same table from campaign lanes; rows must match the scalar oracle."""
    lanes = e4_lanes(cost, cis)
    t0 = time.perf_counter()
    camp = BatchedCampaign(cost, lanes).run()
    wall = time.perf_counter() - t0
    lat_hist = camp.latency_history()
    rows = []
    for i, lane in enumerate(lanes):
        ts = camp.times(i)
        pre = ts <= lane.tag["inject_t"]       # mean_over(0, t) is inclusive
        lat_pre = float(np.mean(lat_hist[i, :len(ts)][pre])) * 1e3
        rec = camp.lane_recovery(i)
        rows.append((lane.tag["e4_ci"], lat_pre,
                     rec if rec is not None else float("nan")))
    return rows, wall


# ---------------------------------------------------------------------------
# the 10x scenario grid (throughput measurement)
# ---------------------------------------------------------------------------

GRID_PLANS = (
    ("full-sync", None),
    ("full-async", CheckpointPlan(sync=False)),
    ("incr8-async", CheckpointPlan(mode="incremental", full_every=8,
                                   sync=False)),
    ("incr8-async-mlr", CheckpointPlan(mode="incremental", full_every=8,
                                       sync=False,
                                       levels=("memory", "local", "remote"),
                                       local_every=1, remote_every=8)),
)
GRID_KINDS = ("task", "node", "cluster")


def grid_lanes(cost: SimCostModel, n_cis: int = 18,
               horizon: int = GRID_HORIZON) -> list[LaneSpec]:
    """CI grid x mechanism x failure kind x workload — every lane one chaos
    scenario with a worst-case injection."""
    workloads = (("const", constant_rate(E4_RATE)),
                 ("diurnal", diurnal_rate(base=0.8 * E4_RATE, amplitude=0.4,
                                          period=7200.0, seed=7)))
    # one dense λ array per workload, shared by every lane that replays it
    rates = {w: dense_rates(0.0, horizon, schedule=s) for w, s in workloads}
    lanes = []
    for ci in np.geomspace(10.0, 240.0, n_cis):
        t = _worst_case(float(ci), cost)
        for plan_name, plan in GRID_PLANS:
            for kind in GRID_KINDS:
                for wname, _sched in workloads:
                    lanes.append(LaneSpec(
                        rates=rates[wname],
                        ci_s=float(ci), plan=plan, failures=((t, kind),),
                        tag={"plan": plan_name, "kind": kind,
                             "workload": wname}))
    return lanes


def bench_grid(cost: SimCostModel, repeats: int = 3) -> dict:
    """Throughput grid with lane-level early exit: recovered lanes are
    compacted out of the array state instead of ticking to the longest
    horizon (the compaction counters are part of the artifact)."""
    lanes = grid_lanes(cost)
    walls = []
    camp = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        camp = BatchedCampaign(cost, lanes, record_history=False,
                               early_exit=True).run()
        walls.append(time.perf_counter() - t0)
    wall = float(np.median(walls))
    recovered = sum(1 for r in camp.recoveries if r)
    return {
        "lanes": len(lanes),
        "lane_ticks": int(camp.ticks_run),
        "wall_s": wall,
        "lane_ticks_per_s": camp.ticks_run / wall,
        "recovered_fraction": recovered / len(lanes),
        "compactions": int(camp.compactions),
        "lanes_compacted": int(camp.lanes_compacted),
        "ci_grid": [10.0, 240.0, 18],
        "plans": [n for n, _ in GRID_PLANS],
        "kinds": list(GRID_KINDS),
        "workloads": ["const", "diurnal"],
    }


# ---------------------------------------------------------------------------
# artifact (BENCH_sim.json  <->  the perf trajectory)
# ---------------------------------------------------------------------------

def build_sim_artifact(scalar_rows, scalar_wall, scalar_ticks,
                       batched_rows, batched_wall, grid: dict,
                       proactive: dict, device: dict) -> dict:
    s = np.array(scalar_rows)
    b = np.array(batched_rows)
    scalar_tps = scalar_ticks / max(scalar_wall, 1e-9)
    return {
        "schema": SIM_SCHEMA,
        "e4": {
            "cis": [float(x) for x in s[:, 0]],
            "latency_ms": [float(x) for x in s[:, 1]],
            "recovery_s": [float(x) for x in s[:, 2]],
            "latency_ms_batched": [float(x) for x in b[:, 1]],
            "recovery_s_batched": [float(x) for x in b[:, 2]],
            "scalar_wall_s": float(scalar_wall),
            "batched_wall_s": float(batched_wall),
            "max_abs_recovery_diff_s": float(np.nanmax(np.abs(s[:, 2] - b[:, 2]))),
            "max_abs_latency_diff_ms": float(np.nanmax(np.abs(s[:, 1] - b[:, 1]))),
        },
        "grid": grid,
        "proactive": proactive,
        "device": device,
        "scalar_ticks_per_s": float(scalar_tps),
        "speedup": float(grid["lane_ticks_per_s"] / scalar_tps),
    }


def _validate_proactive(p: dict) -> None:
    """Gate the E10 section: the artifact only validates if proactive control
    actually paid off (full form) or the micro drill exercised every rung of
    the ladder (smoke form)."""
    if not isinstance(p, dict) or not p:
        raise ValueError("proactive section missing or empty")
    if "qos_violation_s" in p:
        # full head-to-head: twin controllers on one campaign, the only
        # difference the proactive flag — the gate is a STRICT win
        qos = p["qos_violation_s"]
        for name in ("Khaos-proactive", "Khaos-reactive"):
            if name not in qos:
                raise ValueError(f"proactive.qos_violation_s missing {name!r}")
        if not (qos["Khaos-proactive"] < qos["Khaos-reactive"]):
            raise ValueError(
                "proactive Khaos did not strictly beat reactive Khaos: "
                f"{qos['Khaos-proactive']:.0f}s vs "
                f"{qos['Khaos-reactive']:.0f}s of QoS violation")
        if not (int(p.get("proactive_decisions", 0)) >= 1):
            raise ValueError("no forecast-driven plan switch in the "
                             "head-to-head run")
        if not np.isfinite(p.get("first_proactive_t", float("nan"))):
            raise ValueError("first_proactive_t missing or non-finite")
    else:
        # micro smoke drill: one lane, one crash, one backpressure window
        if not np.isfinite(p.get("first_proactive_t", float("nan"))):
            raise ValueError("smoke drill produced no proactive decision")
        if "reprofile" not in p.get("phase_sequence", ()):
            raise ValueError("smoke drill never re-entered the reprofile "
                             "phase after the anomaly")
        if not (int(p.get("bp_suppressed", 0)) >= 1):
            raise ValueError("backpressure window suppressed no checkpoint "
                             "cadence slots")


def _validate_device(d: dict) -> None:
    """Gate the E12 device-engine section: parity is the hard requirement
    (zero divergent lanes or the artifact is rejected); when the sweep ran,
    the exhaustive pick must match or beat the top-k pick's measured
    objective (it replays a superset with bit-identical measurements, so
    anything else is a bug)."""
    if not isinstance(d, dict) or not d:
        raise ValueError("device section missing or empty")
    thr = d.get("throughput")
    if not thr:
        raise ValueError("device.throughput missing or empty")
    for row in thr:
        for k in ("lanes", "lane_ticks", "numpy_lane_ticks_per_s",
                  "device_lane_ticks_per_s"):
            if not (k in row and row[k] > 0):
                raise ValueError(f"device.throughput row missing {k}")
    par = d.get("parity")
    if not isinstance(par, dict) or "divergent_lanes" not in par:
        raise ValueError("device.parity section missing")
    if par["divergent_lanes"] != 0:
        raise ValueError(
            f"device engine diverged from the NumPy engine on "
            f"{par['divergent_lanes']}/{par.get('lanes', '?')} parity lanes")
    sweep = d.get("sweep")
    if sweep is not None:
        if not (sweep["replayed_exhaustive"] >= sweep["replayed_topk"]):
            raise ValueError("exhaustive sweep replayed fewer candidates "
                             "than the top-k shortlist")
        if not (sweep["exhaustive_objective"]
                <= sweep["topk_objective"] + 1e-9):
            raise ValueError(
                "exhaustive device sweep chose a WORSE measured objective "
                f"than top-k replay ({sweep['exhaustive_objective']:.6f} vs "
                f"{sweep['topk_objective']:.6f})")


def validate_sim_artifact(art: dict) -> None:
    """Schema gate for BENCH_sim.json (run by ``benchmarks/run.py --smoke``)."""
    missing = [k for k in SIM_SCHEMA_KEYS if k not in art]
    if missing:
        raise ValueError(f"BENCH_sim artifact missing keys {missing}")
    if art["schema"] != SIM_SCHEMA:
        raise ValueError(f"unknown sim-bench schema {art['schema']!r}")
    e4 = art["e4"]
    n = len(e4["cis"])
    for k in ("latency_ms", "recovery_s", "latency_ms_batched",
              "recovery_s_batched"):
        if len(e4[k]) != n:
            raise ValueError(f"e4.{k} length {len(e4[k])} != {n}")
    if not (e4["max_abs_recovery_diff_s"] <= 1.0):
        raise ValueError("batched E4 diverged from the scalar oracle: "
                         f"max |recovery diff| = {e4['max_abs_recovery_diff_s']}s")
    if not (e4["max_abs_latency_diff_ms"] <= 1.0):
        raise ValueError("batched E4 latency diverged from the scalar oracle")
    g = art["grid"]
    for k in ("lanes", "lane_ticks", "wall_s", "lane_ticks_per_s",
              "recovered_fraction", "compactions", "lanes_compacted"):
        if k not in g or not isinstance(g[k], (int, float)) or g[k] < 0:
            raise ValueError(f"grid.{k} missing or not a non-negative number")
    if g["lanes_compacted"] > g["lanes"]:
        raise ValueError("lanes_compacted exceeds the lane count")
    if not (0.0 < g["recovered_fraction"] <= 1.0):
        raise ValueError(f"implausible recovered_fraction {g['recovered_fraction']}")
    _validate_proactive(art["proactive"])
    _validate_device(art["device"])
    if art["speedup"] <= 0:
        raise ValueError("speedup must be positive")


def emit_sim_artifact(path: str, art: dict) -> dict:
    validate_sim_artifact(art)
    with open(path, "w") as f:
        json.dump(art, f, indent=2)
    print(f"\nsim-bench artifact -> {path}")
    g = art["grid"]
    print(f"campaign throughput: {g['lanes']} lanes, "
          f"{g['lane_ticks_per_s']/1e6:.2f}M lane-ticks/s vs scalar "
          f"{art['scalar_ticks_per_s']/1e3:.0f}k ticks/s "
          f"-> {art['speedup']:.1f}x  (target >= 20x)")
    if art["speedup"] < 20.0:
        print("WARNING: campaign speedup below the 20x target on this host")
    return art


# ---------------------------------------------------------------------------
# drivers
# ---------------------------------------------------------------------------

def bench_recovery_vs_ci(out: str = "BENCH_sim.json",
                         proactive: dict | None = None,
                         device: dict | None = None):
    """`proactive` is the E10 section from ``bench_proactive``, `device`
    the E12 section from ``bench_campaign`` — ``benchmarks/run.py`` passes
    their results through so each runs once; standalone invocations
    compute them here."""
    if proactive is None:
        from benchmarks.bench_proactive import bench_proactive
        proactive = bench_proactive()
    if device is None:
        from benchmarks.bench_campaign import device_section
        device = device_section()
    cost = _e4_cost()
    print("\n=== Recovery & latency vs CI (constant 3000 ev/s, worst-case failure) ===")
    scalar_rows, scalar_wall, scalar_ticks = scalar_e4(cost)
    batched_rows, batched_wall = batched_e4(cost)
    print(f"{'CI (s)':>8s} {'avg latency (ms)':>18s} {'recovery (s)':>14s} "
          f"{'batched rec (s)':>16s}")
    for (ci, lat, rec), (_, _, recb) in zip(scalar_rows, batched_rows):
        print(f"{int(ci):8d} {lat:18.0f} {rec:14.0f} {recb:16.0f}")
    yd = young_daly_interval(cost.ckpt_duration_s, mtbf_s=4 * 3600.0)
    print(f"Young/Daly optimum for MTBF=4h, delta={cost.ckpt_duration_s}s: "
          f"{yd:.0f}s (static, workload-blind — the gap Khaos closes)")

    grid = bench_grid(cost)
    print(f"scalar 8-point sweep: {scalar_wall:.2f}s; {grid['lanes']}-lane "
          f"campaign grid: {grid['wall_s']:.2f}s "
          f"({grid['recovered_fraction']*100:.0f}% of lanes recovered)")
    art = build_sim_artifact(scalar_rows, scalar_wall, scalar_ticks,
                             batched_rows, batched_wall, grid, proactive,
                             device)
    emit_sim_artifact(out, art)
    return scalar_rows


def smoke(tmpdir: str = "/tmp/repro_bench_sim_smoke",
          proactive: dict | None = None,
          device: dict | None = None) -> dict:
    """Tiny 4-lane campaign end-to-end: equivalence vs the scalar oracle on
    a reduced E4 grid, artifact emission, schema validation, reload.  The
    embedded proactive section comes from ``bench_proactive.smoke()`` —
    passed through by run.py, or computed here when run standalone."""
    if proactive is None:
        from benchmarks.bench_proactive import smoke as proactive_smoke
        proactive = proactive_smoke()
    if device is None:
        from benchmarks.bench_campaign import device_section
        device = device_section(smoke=True)
    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)
    cost = _e4_cost()
    cis = (30, 120)
    scalar_rows, scalar_wall, scalar_ticks = scalar_e4(cost, cis)
    batched_rows, batched_wall = batched_e4(cost, cis)
    # a 4-lane grid is enough to exercise the whole campaign machinery
    lanes = [LaneSpec(rates=dense_rates(0.0, 1500,
                                        schedule=constant_rate(E4_RATE)),
                      ci_s=float(ci), failures=((_worst_case(ci, cost), kind),))
             for ci in cis for kind in ("task", "node")]
    t0 = time.perf_counter()
    camp = BatchedCampaign(cost, lanes, record_history=False,
                           early_exit=True).run()
    wall = time.perf_counter() - t0
    grid = {"lanes": len(lanes), "lane_ticks": int(camp.ticks_run),
            "wall_s": wall, "lane_ticks_per_s": camp.ticks_run / wall,
            "recovered_fraction": sum(1 for r in camp.recoveries if r) / len(lanes),
            "compactions": int(camp.compactions),
            "lanes_compacted": int(camp.lanes_compacted),
            "plans": ["full-sync"], "kinds": ["task", "node"],
            "workloads": ["const"], "ci_grid": [float(cis[0]), float(cis[-1]), 2]}
    art = build_sim_artifact(scalar_rows, scalar_wall, scalar_ticks,
                             batched_rows, batched_wall, grid, proactive,
                             device)
    path = os.path.join(tmpdir, "BENCH_sim.json")
    emit_sim_artifact(path, art)
    with open(path) as f:
        validate_sim_artifact(json.load(f))
    assert art["e4"]["max_abs_recovery_diff_s"] == 0.0, \
        "smoke lanes must match the scalar oracle exactly"
    print(f"smoke OK: {path} validates "
          f"(4-lane campaign, {grid['lane_ticks_per_s']/1e3:.0f}k lane-ticks/s)")
    return art


def main(out: str = "BENCH_sim.json", proactive: dict | None = None,
         device: dict | None = None):
    return bench_recovery_vs_ci(out, proactive=proactive, device=device)


if __name__ == "__main__":
    main()
