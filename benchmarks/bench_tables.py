"""E1 + E2: the paper's two experiments (Tables II and III, Fig. 3).

IoT-Vehicles analogue: diurnal vehicle-traffic workload (TAPASCologne-like)
YSB analogue:          ad-click CTR workload with bursts

Calibration: service capacity sized for ~0.55 peak utilization and a 2.5s
sync checkpoint write (paper cluster: 50 nodes, 1GbE, Flink 1.12 defaults,
50s heartbeat timeout) — chosen so failure-free latencies sit near the
paper's 500-1100 ms band and single-failure recoveries near its
140-290 s/failure band.
"""
from __future__ import annotations

from repro.data.stream import ctr_rate, diurnal_rate
from repro.sim import SimCostModel

from benchmarks.common import print_table, run_experiment

DAY = 86_400.0     # one-day sim (12 failures, like the paper's runs)


def bench_iot_vehicles(repeats: int = 3):
    sched = diurnal_rate(base=2200.0, amplitude=0.55, period=DAY, seed=42)
    cost = SimCostModel(capacity_eps=4600.0, base_latency_s=0.55,
                        ckpt_duration_s=3.0, ckpt_sync_penalty=0.6)
    rows, err = run_experiment("IoT", sched, cost, duration=DAY, seed=1,
                               repeats=repeats)
    print_table("IoT Vehicles Experiment (Table II analogue)", rows, err)
    return rows, err


def bench_ysb(repeats: int = 3):
    sched = ctr_rate(base=2200.0, seed=43, period=DAY)
    cost = SimCostModel(capacity_eps=6400.0, base_latency_s=0.50,
                        ckpt_duration_s=2.5, ckpt_sync_penalty=0.6)
    rows, err = run_experiment("YSB", sched, cost, duration=DAY, seed=2,
                               repeats=repeats)
    print_table("YSB Experiment (Table III analogue)", rows, err)
    return rows, err


def main():
    iot = bench_iot_vehicles()
    ysb = bench_ysb()
    return {"iot": iot, "ysb": ysb}


if __name__ == "__main__":
    main()
