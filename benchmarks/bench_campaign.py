"""E12: device-resident mega-campaigns — the "device" section of
BENCH_sim.json (schema "bench_sim/3").

Three measurements, one per claim the device engine makes:

  throughput   lane-ticks/s, NumPy vs device, at 1e3/1e4/1e5 lanes of the
               same failure-bearing scenario (record_history=False, warm
               numbers exclude the one-time XLA compile, which is reported
               separately).  On an accelerator the device engine is the
               10x+ story; on the CPU fallback it must merely not lose —
               either way the numbers are measured, not assumed.
  parity       the hard gate: a full (plan x crash kind x degradation
               kind x CI) matrix run through BOTH engines; a lane counts
               as divergent unless its lag history, latency history,
               recovery records, and final counters are ALL bit-identical.
               ``divergent_lanes`` must be 0 for the artifact to validate
               (``fma_contraction`` reports whether the pre-FMA ISA pin
               took — see ``sim.device.ensure_bitexact_cpu``).
  sweep        what the throughput buys: ``optimize_plan`` on the E4
               scenario with the usual top-3 replay (NumPy) vs the
               exhaustive full-variant-grid replay (device).  Because the
               exhaustive replay scores a SUPERSET of the shortlist with
               bit-identical measurements, its pick must match or improve
               the top-k pick's measured Eq.-8 objective — the validator
               gates ``exhaustive_objective <= topk_objective``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.config import CheckpointPlan
from repro.core import QoSModel, optimize_plan
from repro.data.stream import constant_rate, dense_rates
from repro.ft.failures import Degradation
from repro.sim import (BatchedCampaign, LaneSpec, SimCostModel,
                       make_plan_verifier)
from repro.sim.device import DeviceCampaign, fma_contraction_active

THR_TICKS = 2000
THR_LANE_COUNTS = (1_000, 10_000, 100_000)

PARITY_PLANS = (
    ("full-sync", None),
    ("full-async", CheckpointPlan(sync=False)),
    ("incr8-async", CheckpointPlan(mode="incremental", full_every=8,
                                   sync=False)),
    ("incr4-async-mlr", CheckpointPlan(mode="incremental", full_every=4,
                                       levels=("memory", "local", "remote"),
                                       local_every=1, remote_every=8)),
)
PARITY_KINDS = ("task", "node", "cluster")
PARITY_DEGRADATIONS = (
    ("straggler", Degradation(t=300.0, kind="straggler", duration_s=400.0,
                              severity=1.8)),
    ("net_delay_source", Degradation(t=250.0, kind="net_delay",
                                     duration_s=500.0, severity=3.0,
                                     jitter_s=0.8, direction="to_source")),
    ("net_delay_store", Degradation(t=250.0, kind="net_delay",
                                    duration_s=600.0, severity=4.0,
                                    jitter_s=1.0, direction="to_ckpt_store")),
    ("backpressure", Degradation(t=200.0, kind="backpressure",
                                 duration_s=150.0)),
)


def _thr_cost() -> SimCostModel:
    return SimCostModel(capacity_eps=4600.0, base_latency_s=0.5,
                        ckpt_duration_s=3.0, ckpt_sync_penalty=0.6)


def _thr_lanes(n: int, horizon: int = THR_TICKS) -> list[LaneSpec]:
    """n failure-bearing lanes sharing one λ array (the mega-campaign
    shape: many scenarios, one workload upload)."""
    rates = 3000.0 + 800.0 * np.sin(np.arange(horizon) / 40.0)
    return [LaneSpec(rates=rates, ci_s=float(10 + (i % 12) * 10),
                     failures=((300.0 + (i % 700), "task"),))
            for i in range(n)]


def bench_throughput(lane_counts=THR_LANE_COUNTS,
                     horizon: int = THR_TICKS) -> list[dict]:
    cost = _thr_cost()
    rows = []
    for n in lane_counts:
        lanes = _thr_lanes(n, horizon)
        t0 = time.perf_counter()
        BatchedCampaign(cost, lanes, record_history=False).run()
        wall_np = time.perf_counter() - t0
        t0 = time.perf_counter()
        DeviceCampaign(cost, lanes, record_history=False).run()
        wall_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        DeviceCampaign(cost, lanes, record_history=False).run()
        wall_warm = time.perf_counter() - t0
        ticks = n * horizon
        rows.append({
            "lanes": n,
            "lane_ticks": ticks,
            "numpy_lane_ticks_per_s": ticks / wall_np,
            "device_lane_ticks_per_s": ticks / wall_warm,
            "device_cold_wall_s": wall_cold,
            "device_speedup": wall_np / wall_warm,
        })
        print(f"  {n:>7d} lanes: numpy {ticks/wall_np/1e6:6.1f}M t/s, "
              f"device {ticks/wall_warm/1e6:6.1f}M t/s "
              f"({wall_np/wall_warm:.2f}x, cold {wall_cold:.1f}s)")
    return rows


def parity_lanes(horizon: int = 900) -> list[LaneSpec]:
    """The full scenario matrix both engines must agree on bit-for-bit:
    every plan x crash kind x CI with two injections, every plan x
    degradation kind with and without a concurrent crash, and pure
    no-failure lanes (the carry-free fast path)."""
    rates = 3000.0 + 800.0 * np.sin(np.arange(horizon) / 40.0)
    lanes = []
    for pi, (_name, plan) in enumerate(PARITY_PLANS):
        for kind in PARITY_KINDS:
            for ci in (15.0, 45.0):
                lanes.append(LaneSpec(
                    rates=rates, ci_s=ci, plan=plan,
                    failures=((200.0 + 20 * pi, kind), (560.0, "task"))))
    for _name, plan in PARITY_PLANS:
        for _dname, deg in PARITY_DEGRADATIONS:
            for fails in ((), ((400.0, "task"),)):
                lanes.append(LaneSpec(rates=rates, ci_s=20.0, plan=plan,
                                      failures=fails, degradations=[deg]))
    for _name, plan in PARITY_PLANS:
        lanes.append(LaneSpec(rates=rates, ci_s=25.0, plan=plan))
    return lanes


def _divergent_lanes(a: BatchedCampaign, b: DeviceCampaign) -> int:
    """Count lanes that differ ANYWHERE: history, latency, recoveries, or
    final counters.  Bit-exact comparison — no tolerance."""
    n = a.n_lanes
    bad = np.zeros(n, dtype=bool)
    bad |= (a.lag_hist != b.lag_hist).any(axis=1)
    bad |= (a.latency_history() != b.latency_history()).any(axis=1)
    for name in ("lag", "consumed", "produced", "processed_total",
                 "ckpt_count", "save_count", "steady_lag", "down", "t"):
        bad |= np.asarray(getattr(a, name)) != np.asarray(getattr(b, name))
    bad |= (a.off_lvl != b.off_lvl).any(axis=1)
    for i in range(n):
        if a.recoveries[i] != b.recoveries[i]:
            bad[i] = True
    return int(bad.sum())


def parity_check(horizon: int = 900) -> dict:
    cost = _thr_cost()
    lanes = parity_lanes(horizon)
    a = BatchedCampaign(cost, lanes).run()
    b = DeviceCampaign(cost, lanes).run()
    div = _divergent_lanes(a, b)
    out = {"lanes": len(lanes), "ticks": horizon,
           "divergent_lanes": div,
           "fma_contraction": bool(fma_contraction_active())}
    print(f"  parity: {len(lanes)} lanes x {horizon} ticks, "
          f"{div} divergent (fma_contraction={out['fma_contraction']})")
    return out


# ---------------------------------------------------------------------------
# exhaustive sweep vs top-k replay (E4 scenario)
# ---------------------------------------------------------------------------

def _e4_surfaces(cost: SimCostModel) -> tuple[QoSModel, QoSModel]:
    """Analytic stand-in QoS surfaces on the E4 envelope — the surfaces
    only pick the shortlist; the replay measurements decide the winner,
    which is exactly the top-k-vs-exhaustive comparison under test."""
    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 240, 200)
    tr = rng.uniform(2000, 3600, 200)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    return m_l, m_r


def _measured_objective(res) -> float:
    objs = [c.sim["objective"] for c in res.candidates
            if c.sim is not None and c.sim["feasible"]]
    return float(min(objs)) if objs else float("nan")


def bench_sweep(rate: float = 3000.0, l_const: float = 2.0,
                r_const: float = 600.0, max_recovery_s: float = 1200.0,
                grid: int = 64) -> dict:
    cost = SimCostModel(capacity_eps=4600.0, base_latency_s=0.5,
                        ckpt_duration_s=3.0, ckpt_sync_penalty=0.6)
    m_l, m_r = _e4_surfaces(cost)
    kw = dict(tr_avg=rate, l_const=l_const, r_const=r_const, p=1.0,
              ci_min=10.0, ci_max=240.0, cost=cost, grid=grid)

    ver = make_plan_verifier(cost, schedule=constant_rate(rate),
                             warmup_s=120.0, max_recovery_s=max_recovery_s)
    t0 = time.perf_counter()
    res_top = optimize_plan(m_l, m_r, verifier=ver, verify_top_k=3, **kw)
    wall_top = time.perf_counter() - t0

    ver = make_plan_verifier(cost, schedule=constant_rate(rate),
                             warmup_s=120.0, max_recovery_s=max_recovery_s)
    t0 = time.perf_counter()
    res_ex = optimize_plan(m_l, m_r, verifier=ver, exhaustive=True,
                           engine="device", **kw)
    wall_ex = time.perf_counter() - t0

    out = {
        "variants": len(res_top.candidates),
        "replayed_topk": sum(1 for c in res_top.candidates
                             if c.sim is not None),
        "replayed_exhaustive": sum(1 for c in res_ex.candidates
                                   if c.sim is not None),
        "topk_wall_s": wall_top,
        "exhaustive_wall_s": wall_ex,
        "topk_objective": _measured_objective(res_top),
        "exhaustive_objective": _measured_objective(res_ex),
        "topk_plan": res_top.plan.name if res_top.plan else None,
        "exhaustive_plan": res_ex.plan.name if res_ex.plan else None,
        "topk_ci": res_top.ci,
        "exhaustive_ci": res_ex.ci,
    }
    print(f"  sweep: top-3 replay {wall_top:.1f}s (obj "
          f"{out['topk_objective']:.4f}, {out['topk_plan']}) vs exhaustive "
          f"{out['replayed_exhaustive']}-candidate device replay "
          f"{wall_ex:.1f}s (obj {out['exhaustive_objective']:.4f}, "
          f"{out['exhaustive_plan']})")
    return out


def device_section(smoke: bool = False) -> dict:
    """The "device" section of the bench_sim/3 artifact."""
    print("\n=== Device campaign engine (E12) ===")
    if smoke:
        # tiny but complete: a real two-engine throughput point, the full
        # parity matrix at a short horizon, no sweep (run.py --smoke must
        # stay accelerator-free and minute-scale; the validator accepts a
        # null sweep)
        throughput = bench_throughput(lane_counts=(256,), horizon=400)
        parity = parity_check(horizon=400)
        sweep = None
    else:
        throughput = bench_throughput()
        parity = parity_check()
        sweep = bench_sweep()
    return {"throughput": throughput, "parity": parity, "sweep": sweep}


def main() -> dict:
    return device_section(smoke=False)


if __name__ == "__main__":
    main()
