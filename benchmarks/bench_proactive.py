"""E10: proactive control under degradation — the head-to-head the ROADMAP
item 3 called for.

Proactive Khaos (``KhaosConfig.proactive``: forecast-driven plan switching
at the predicted peak rate) races reactive Khaos and two statics as lanes
of ONE ``BatchedCampaign`` under a diurnal λ(t) ramp with injected gray
failures (straggler, directional net_delay, backpressure — the
``ft.failures`` degradation vocabulary) and node crashes.  Both Khaos
lanes are supervised controller-in-the-loop by a single
``KhaosRuntime.drive_campaign`` call via ``lane_cfgs`` — identical
substrate, identical failure schedule, the ONLY difference is the
proactive flag.

The decisive scenario is a crash landing in the *lead window*: the
interval where the proactive controller has already tightened the plan
(the TSF forecast the ramp breaching the recovery constraint) but the
reactive controller is still waiting for the breach to materialize.  The
proactive lane loses a small CI's worth of work; the reactive lane loses
the whole stale interval — strictly fewer QoS-violation seconds, gated
by ``bench_recovery.validate_sim_artifact`` (schema "bench_sim/2").

``smoke()`` is the micro drill ``benchmarks/run.py --smoke`` runs: the
same ramp + one backpressure window + a crash, asserting >= 1 proactive
decision BEFORE the λ peak, an anomaly-triggered ``reprofile`` event in
the phase log (with the legal re-entry order), and the degradations
actually biting (suppressed triggers).
"""
from __future__ import annotations

import numpy as np

from repro.config import KhaosConfig
from repro.config import replace as cfg_replace
from repro.core import AnomalyDetector, KhaosRuntime
from repro.data.stream import dense_rates, record_workload
from repro.ft.failures import Degradation
from repro.sim import (BatchedCampaign, BatchedDeployment, LaneSpec,
                       SimCostModel)


def _cost() -> SimCostModel:
    """Sync-stall checkpoint regime: a heavy full-stop write (8 s at full
    capacity loss) makes the cadence duty-cycle price BOTH latency and the
    post-failure replay drain — a CI of 40 s spends 20% of the day stalled,
    so near capacity it cannot drain its own backlog.  That is what makes
    the Eq.-8 optimum genuinely load-dependent (argmin recovery shifts from
    ~80 s at the diurnal mean to ~160 s at the peak), which is the whole
    point of a proactive plan switch."""
    return SimCostModel(capacity_eps=4600.0, base_latency_s=0.5,
                        ckpt_duration_s=8.0, ckpt_sync_penalty=1.0)


def ramp_schedule(base: float, amplitude: float, period: float):
    """Clean raised-cosine diurnal ramp: λ(0)=base, peak base*(1+amplitude)
    at period/2 — monotone rise then fall, so "before the peak" is a
    well-defined assertion target (``data.stream.diurnal_rate``'s
    rush-hour harmonics are great for E1/E2, noisy for a control drill)."""
    def rate(t: float) -> float:
        x = 2.0 * np.pi * (t % period) / period
        return float(base * (1.0 + amplitude * 0.5 * (1.0 - np.cos(x))))
    return rate


def _violations(camp: BatchedCampaign, lane: int, l_const: float,
                r_const: float) -> dict:
    """QoS-violation seconds for one lane: recovery excess over r_const
    plus the count of ticks whose end-to-end latency exceeded l_const."""
    recs = [r["recovery_s"] for r in camp.recoveries[lane]]
    rec_viol = float(sum(max(0.0, r - r_const) for r in recs))
    ts = camp.times(lane)
    lat = camp.latency_history()[lane, :len(ts)]
    lat_viol = float(np.sum(lat > l_const))
    return {"recovery_violation_s": rec_viol,
            "latency_violation_s": lat_viol,
            "qos_violation_s": rec_viol + lat_viol,
            "recoveries_s": [float(r) for r in recs]}


def head_to_head(period: float = 14_400.0, opt_period: float = 120.0,
                 verbose: bool = True) -> dict:
    """Proactive vs reactive vs statics over one diurnal cycle; returns
    the artifact section ``bench_recovery`` embeds as ``"proactive"``."""
    cost = _cost()
    base, amp = 2200.0, 0.8                     # peak 3960 of 4600 capacity
    sched = ramp_schedule(base, amp, period)
    # r_const sits between the peak's best achievable recovery (~2000 s at
    # CI ~200) and what the mean-optimal CI needs there (~2700+ s): the
    # peak violates the stale plan but a proactive switch restores
    # feasibility.  l_const is loose enough that only a backlog excursion
    # (tight cadence near capacity) breaches it.
    l_const, r_const = 6.0, 2400.0
    horizon = int(period)

    # Phases 1-2 on a recording of the same ramp.  forecast_horizon=12
    # (24 min at the 120 s cycle) reaches far enough up the ramp to see
    # the breach coming without the long-horizon ARIMA overshoot that
    # would put the predicted peak outside the feasible region entirely.
    recording = record_workload(sched, duration=period, seed=7)
    ci_grid = np.geomspace(40.0, 300.0, 6)
    kcfg = KhaosConfig(latency_constraint=l_const,
                       recovery_constraint=r_const,
                       optimization_period=opt_period,
                       ci_min=40.0, ci_max=300.0,
                       reconfig_cooldown=2 * opt_period,
                       num_failure_points=4, smoothing_window=60,
                       forecast_horizon=12)
    rt = KhaosRuntime(kcfg)
    rt.record_steady_state(recording)
    rt.run_profiling(BatchedDeployment(cost, recording, warmup_s=600,
                                       max_recovery_s=3600.0),
                     ci_grid, margin=120)
    ci0 = rt.initial_ci(float(np.mean(recording.counts)))

    # shared chaos schedule: every lane faces the same day.  The scale
    # factor keeps event times proportional when the period shrinks.
    # The decisive crash (5860 s) lands in the LEAD WINDOW: the forecast
    # already pre-acted (~t=3600-4400) but the measured rate has not yet
    # breached anything, so the reactive twin meets it on the stale plan —
    # and the store-path net_delay window (5800-7000 s) inflates every
    # sync barrier, leaving the tight stale cadence with NEGATIVE drain
    # through the peak.  That makes the reactive lane's recovery floor
    # higher than the proactive lane's ceiling regardless of where the
    # crash falls relative to either lane's checkpoint phase.
    s = period / 14_400.0
    crashes = ((2500.0 * s, "node"), (5860.0 * s, "node"))
    degradations = (
        Degradation(1200.0 * s, "straggler", 600.0 * s, severity=1.3),
        Degradation(5000.0 * s, "net_delay", 600.0 * s, severity=2.0,
                    jitter_s=0.5, direction="to_source"),
        Degradation(5800.0 * s, "net_delay", 1200.0 * s, severity=6.0,
                    jitter_s=1.0, direction="to_ckpt_store"),
        Degradation(9000.0 * s, "backpressure", 600.0 * s),
    )

    configs = [("Khaos-proactive", ci0 or 240.0),
               ("Khaos-reactive", ci0 or 240.0),
               ("static 40s", 40.0),
               ("static 480s", 480.0)]
    day_rates = dense_rates(0.0, horizon, schedule=sched)
    lanes = [LaneSpec(rates=day_rates, ci_s=float(ci),
                      failures=crashes, degradations=degradations,
                      tag={"name": name})
             for name, ci in configs]
    camp = BatchedCampaign(cost, lanes, flink_semantics=False)
    sup = rt.drive_campaign(
        camp, lanes=[0, 1],
        lane_cfgs={0: cfg_replace(kcfg, proactive=True)})

    out = {"configs": [n for n, _ in configs], "horizon_s": float(horizon),
           "latency_constraint_s": l_const, "recovery_constraint_s": r_const,
           "initial_ci_s": float(ci0 or 240.0),
           "qos_violation_s": {}, "recovery_violation_s": {},
           "latency_violation_s": {}}
    for i, (name, _ci) in enumerate(configs):
        v = _violations(camp, i, l_const, r_const)
        out["qos_violation_s"][name] = v["qos_violation_s"]
        out["recovery_violation_s"][name] = v["recovery_violation_s"]
        out["latency_violation_s"][name] = v["latency_violation_s"]
        if verbose:
            reconf = len(sup.reconfigurations(i)) if i < 2 else 0
            print(f"{name:>16s}: qos-viol {v['qos_violation_s']:7.0f}s "
                  f"(rec {v['recovery_violation_s']:6.0f}s, lat "
                  f"{v['latency_violation_s']:5.0f}s)  recoveries "
                  f"{[round(r) for r in v['recoveries_s']]}  "
                  f"reconfigs {reconf}  "
                  f"bp-suppressed {int(camp.bp_suppressed[i])}")
    pro = [d for d in sup.controllers[0].decisions if d.kind == "proactive"]
    t0 = pro[0].t if pro else float("inf")
    # the reactive twin's "response" is its first departure from steady
    # operation AFTER the proactive lane had already re-planned — either a
    # breach-driven reconfigure or (as in the decisive scenario) going
    # unhealthy when the unpre-empted breach materializes as a crash
    re_first = next((d.t for d in sup.controllers[1].decisions
                     if d.t > t0 and d.kind in ("reconfigure", "infeasible",
                                                "unhealthy")), float("nan"))
    out["proactive_decisions"] = len(pro)
    out["first_proactive_t"] = float(pro[0].t) if pro else float("nan")
    out["first_reactive_response_t"] = float(re_first)
    out["lead_s"] = float(re_first - pro[0].t) if pro else float("nan")
    out["bp_suppressed"] = [int(x) for x in camp.bp_suppressed[:len(configs)]]
    if verbose and pro:
        print(f"proactive lead: first pre-act at t={pro[0].t:.0f}s, "
              f"reactive response at t={re_first:.0f}s "
              f"(lead {out['lead_s']:.0f}s over a {opt_period:.0f}s period)")
    return out


def bench_proactive():
    print("\n=== E10: proactive vs reactive Khaos under gray failures "
          "(one campaign, twin controllers) ===")
    return head_to_head()


# ---------------------------------------------------------------------------
# smoke drill (run.py --smoke)
# ---------------------------------------------------------------------------

def smoke() -> dict:
    """Micro proactive-control drill: diurnal ramp + backpressure + crash.
    Gates (AssertionError on regression):
      * >= 1 forecast-driven ("proactive") plan switch BEFORE the λ peak;
      * the crash's latency excursion trips the anomaly detector, whose
        sustained anomaly fires the ``reprofile`` rung — phase_log shows
        the legal re-entry optimizing -> reprofile -> profiled -> optimizing;
      * the backpressure window actually suppressed cadence slots.
    """
    cost = _cost()
    period = 7200.0
    base, amp = 2200.0, 0.8
    sched = ramp_schedule(base, amp, period)
    l_const, r_const = 6.0, 2400.0

    recording = record_workload(sched, duration=period, seed=7)
    ci_grid = np.geomspace(40.0, 300.0, 5)
    kcfg = KhaosConfig(latency_constraint=l_const,
                       recovery_constraint=r_const,
                       optimization_period=60.0,
                       ci_min=40.0, ci_max=300.0,
                       reconfig_cooldown=120.0,
                       num_failure_points=3, smoothing_window=60,
                       forecast_horizon=12, proactive=True)
    rt = KhaosRuntime(kcfg)
    rt.record_steady_state(recording)
    deployment = BatchedDeployment(cost, recording, warmup_s=300,
                                   max_recovery_s=1800.0)
    rt.run_profiling(deployment, ci_grid, margin=90)
    ci0 = rt.initial_ci(float(np.mean(recording.counts)))

    # arm the mitigation ladder: small-p detector so the micro drill warms.
    # error_window=30 matters: the supervised feed is the campaign's
    # arrival rate + lag-derived latency, and the first few warm-up
    # predictions produce astronomical relative errors — a 60-sample
    # window would still hold them at crash time, inflating the 3-sigma
    # threshold beyond any real excursion.  30 samples flush the warm-up
    # noise so the crash's latency spike is an unambiguous hit.
    rt.attach_anomaly_detector(
        AnomalyDetector(metrics=("latency",), p=3, d=1, threshold_sigma=3.0,
                        error_window=30, min_anomaly_len=1,
                        recovery_normal_len=5), lane=0)
    rt.enable_reprofiling(deployment, ci_grid)

    # backpressure holds the barrier, then the crash right after the window
    # loses the whole suppressed span -> latency excursion -> anomaly
    lane = LaneSpec(
        rates=dense_rates(0.0, int(period), schedule=sched),
        ci_s=float(ci0 or 240.0),
        failures=((2850.0, "node"),),
        degradations=(Degradation(2200.0, "backpressure", 600.0),),
        tag={"name": "proactive-drill"})
    camp = BatchedCampaign(cost, [lane], flink_semantics=False)
    sup = rt.drive_campaign(camp, lanes=[0])

    pro = [d for d in sup.controllers[0].decisions if d.kind == "proactive"]
    t_peak = period / 2.0
    assert pro and pro[0].t < t_peak, \
        f"no proactive plan switch before the λ peak (t={t_peak:.0f}s): " \
        f"{[(d.t, d.kind) for d in sup.controllers[0].decisions][:40]}"
    seq = rt.phase_sequence()
    assert "reprofile" in seq, f"anomaly never fired the reprofile rung: {seq}"
    i = seq.index("reprofile")
    assert seq[:3] == ["steady_state", "profiled", "optimizing"] and \
        seq[i:i + 3] == ["reprofile", "profiled", "optimizing"], \
        f"illegal phase order around reprofile: {seq}"
    assert int(camp.bp_suppressed[0]) >= 1, \
        "backpressure window suppressed no cadence slot"
    assert any(k for t, k, _ in rt.mitigations if k == "reprofile")
    print(f"proactive smoke OK: first pre-act t={pro[0].t:.0f}s "
          f"(peak {t_peak:.0f}s), reprofile at phase_log[{i}], "
          f"{int(camp.bp_suppressed[0])} suppressed slots")
    return {"first_proactive_t": float(pro[0].t),
            "phase_sequence": seq,
            "bp_suppressed": int(camp.bp_suppressed[0])}


def main():
    return bench_proactive()


if __name__ == "__main__":
    main()
