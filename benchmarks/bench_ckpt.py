"""E5 (beyond-paper): checkpoint subsystem microbenchmarks on a real model
state, measuring the pipelined save path stage by stage:

    trigger -> chunked D2H transfer || delta encode || compress || write

i.e. the ``ChunkedHostSnapshot`` first-chunk sync is the only blocking
cost (reported as ``blocking_s`` and compared against the monolithic
``snapshot_to_host`` deep copy it replaced), while the remaining chunks
stream to the leaf-parallel encode/compress/write workers on the io pool.

Besides the printed tables, ``main`` emits a ``BENCH_ckpt.json``
calibration artifact (schema "bench_ckpt/1": state bytes, full write
seconds, restore seconds, measured delta byte fractions, and the per-byte
host encode CPU of the delta path) that
``sim.costmodel.SimCostModel.from_calibration`` loads — closing the loop
so the Khaos plan optimizer prices checkpoint mechanisms with measured
numbers instead of the hand-set ``delta_fraction``/level defaults.  The
final scenario runs the plan optimizer against that calibration and shows
the (mode, CI) it picks vs the full-sync baseline.

``smoke()`` (wired as ``benchmarks/run.py --smoke``) runs the same flow on
a tiny state and validates the emitted artifact's schema — a
tier-1-adjacent check that the calibration loop stays loadable.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (AsyncCheckpointer, CheckpointManager,
                              CheckpointPlan, CheckpointStore,
                              IncrementalCheckpointer)
from repro.checkpoint.async_ckpt import snapshot_to_host
from repro.config import OptimizerConfig
from repro.configs import get_smoke_config
from repro.models import zoo
from repro.optim import make_optimizer
from repro.sim import SimCostModel
from repro.sim.costmodel import CALIBRATION_KEYS
from repro.utils.trees import tree_bytes


def _mk_state(scale: int = 4):
    import dataclasses
    cfg = get_smoke_config("yi-6b")
    cfg = dataclasses.replace(cfg, d_model=64 * scale, d_ff=128 * scale,
                              num_layers=4)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(OptimizerConfig())
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _bump(state):
    return jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(1e-4, x.dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, state)


def bench_checkpoint(tmpdir: str = "/tmp/repro_bench_ckpt", scale: int = 4):
    """Single-mechanism microbenchmarks; returns (rows, measurements) where
    measurements feed the calibration artifact."""
    shutil.rmtree(tmpdir, ignore_errors=True)
    state = _mk_state(scale)
    jax.block_until_ready(state)   # don't bill pending init compute to the copy
    nbytes = tree_bytes(state)
    print(f"\n=== Checkpoint subsystem (state = {nbytes/2**20:.1f} MiB) ===")
    rows = []
    meas = {"state_bytes": nbytes}

    t0 = time.monotonic()
    snapshot_to_host(state)
    meas["snapshot_full_copy_s"] = time.monotonic() - t0
    rows.append(("ckpt_snapshot_full_copy", meas["snapshot_full_copy_s"] * 1e6,
                 "monolithic D2H deep copy (pre-pipeline blocking cost)"))

    store = CheckpointStore(tmpdir + "/sync", num_shards=4)
    t0 = time.monotonic()
    store.save(1, state)
    sync_s = time.monotonic() - t0
    meas["full_write_s"] = sync_s
    rows.append(("ckpt_sync_save", sync_s * 1e6, f"{nbytes/sync_s/2**20:.0f} MiB/s"))

    ac = AsyncCheckpointer(CheckpointStore(tmpdir + "/async", num_shards=4))
    t0 = time.monotonic()
    ac.save(1, state)
    block_s = time.monotonic() - t0     # only the chunked snapshot blocks
    ac.wait()
    meas["async_blocking_s"] = block_s
    rows.append(("ckpt_async_block", block_s * 1e6,
                 f"{block_s/sync_s:.3f}x of sync"))

    t0 = time.monotonic()
    restored, _ = store.restore(state)
    restore_s = time.monotonic() - t0
    meas["restore_s"] = restore_s
    rows.append(("ckpt_restore", restore_s * 1e6, f"{nbytes/restore_s/2**20:.0f} MiB/s"))

    for mode in ("lossless", "int8"):
        inc = IncrementalCheckpointer(CheckpointStore(tmpdir + f"/inc_{mode}",
                                                      num_shards=2),
                                      full_every=8, mode=mode)
        inc.save(0, state)
        bumped = _bump(state)
        t0 = time.monotonic()
        inc.save(1, bumped)
        dt = time.monotonic() - t0
        ratio = inc.bytes_written_delta / max(inc.bytes_written_full, 1)
        meas[f"delta_fraction_{mode}"] = ratio
        rows.append((f"ckpt_incr_{mode}", dt * 1e6,
                     f"delta/full bytes = {ratio:.4f}"))

    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows, meas


PLANS = {
    "full-sync": CheckpointPlan(),
    "full-async": CheckpointPlan(sync=False),
    "incr8-sync": CheckpointPlan(mode="incremental", full_every=8),
    "incr8-async": CheckpointPlan(mode="incremental", full_every=8,
                                  sync=False, busy_policy="block"),
    "multilevel": CheckpointPlan(levels=("memory", "local", "remote"),
                                 local_every=2, remote_every=8),
    "ml+delta": CheckpointPlan(mode="incremental", full_every=8,
                               levels=("memory", "local", "remote"),
                               local_every=1, remote_every=8),
}


def bench_plans(tmpdir: str = "/tmp/repro_bench_ckpt_plans",
                triggers: int = 16, scale: int = 4):
    """Whole-plan accounting: run ``triggers`` checkpoint triggers of a
    drifting train state through each plan and report total bytes written,
    mean blocking/write durations and delta-encode CPU seconds — the
    overhead the optimizer trades against QoS.  Returns (rows, per-plan
    stats dict for the calibration artifact)."""
    state = _mk_state(scale)
    nbytes = tree_bytes(state)
    print(f"\n=== Checkpoint plans ({triggers} triggers, "
          f"state = {nbytes/2**20:.1f} MiB) ===")
    print(f"{'plan':12s} {'bytes_written':>14s} {'vs_full':>8s} "
          f"{'write_ms':>9s} {'block_ms':>9s} {'encode_ms':>9s}")
    rows = []
    plan_stats: dict[str, dict] = {}
    baseline_bytes = None
    for name, plan in PLANS.items():
        shutil.rmtree(f"{tmpdir}/{name}", ignore_errors=True)
        mgr = CheckpointManager(f"{tmpdir}/{name}", plan)
        cur = state
        block, writes, encode, deltas = [], [], [], 0
        for i in range(triggers):
            cur = _bump(cur)
            rep = mgr.save(i, cur, float(i))
            block.append(rep.blocking_s)
            mgr.wait()
            writes.append(rep.duration_s)
            encode.append(rep.encode_s)
            deltas += rep.kind == "delta"
        st = mgr.stats()
        total = st["bytes_written"]
        if baseline_bytes is None:
            baseline_bytes = total
        plan_stats[name] = {
            "bytes_per_trigger": total / triggers,
            "write_s": float(np.mean(writes)),
            "blocking_s": float(np.mean(block)),
            "encode_cpu_s": float(np.sum(encode)),
            "delta_triggers": deltas,
            "bytes_by_kind": st["bytes_by_kind"],
        }
        rows.append((name, total, total / baseline_bytes,
                     1e3 * float(np.mean(writes)),
                     1e3 * float(np.mean(block))))
        print(f"{name:12s} {total:>14d} {total/baseline_bytes:>8.3f} "
              f"{1e3*np.mean(writes):>9.1f} {1e3*np.mean(block):>9.1f} "
              f"{1e3*np.sum(encode):>9.1f}")
    return rows, plan_stats


# ---------------------------------------------------------------------------
# calibration artifact (BENCH_ckpt.json  <->  SimCostModel.from_calibration)
# ---------------------------------------------------------------------------

def build_calibration(meas: dict, plan_stats: dict) -> dict:
    """Assemble the "bench_ckpt/1" artifact from the measured tables."""
    incr = plan_stats.get("incr8-sync", {})
    encode_per_byte = 0.0
    if incr.get("delta_triggers"):
        encode_per_byte = incr["encode_cpu_s"] / (
            meas["state_bytes"] * incr["delta_triggers"])
    return {
        "schema": "bench_ckpt/1",
        "state_bytes": meas["state_bytes"],
        "full_write_s": meas["full_write_s"],
        "restore_s": meas["restore_s"],
        "delta_fraction": meas["delta_fraction_lossless"],
        "delta_int8_fraction": meas["delta_fraction_int8"],
        "delta_encode_s_per_byte": encode_per_byte,
        "snapshot_full_copy_s": meas["snapshot_full_copy_s"],
        "async_blocking_s": meas["async_blocking_s"],
        "plans": plan_stats,
    }


def validate_calibration(cal: dict) -> None:
    """Schema check for the artifact (the ``run.py --smoke`` gate).
    Key/schema-version checking is delegated to the consumer
    (``SimCostModel.from_calibration``) so the contract lives in one
    place; the numeric and plans-table checks below are bench-side only."""
    SimCostModel.from_calibration(cal)      # raises ValueError on mismatch
    for k in CALIBRATION_KEYS[1:]:
        if not isinstance(cal[k], (int, float)) or cal[k] < 0:
            raise ValueError(f"{k} must be a non-negative number, "
                             f"got {cal[k]!r}")
    if cal["state_bytes"] <= 0:
        raise ValueError("state_bytes must be positive")
    if not isinstance(cal.get("plans"), dict) or not cal["plans"]:
        raise ValueError("plans table missing or empty")
    for name, st in cal["plans"].items():
        for k in ("bytes_per_trigger", "write_s", "blocking_s",
                  "encode_cpu_s"):
            if k not in st:
                raise ValueError(f"plan {name!r} missing {k}")


def emit_calibration(path: str, meas: dict, plan_stats: dict) -> dict:
    cal = build_calibration(meas, plan_stats)
    validate_calibration(cal)
    with open(path, "w") as f:
        json.dump(cal, f, indent=2)
    print(f"\ncalibration artifact -> {path}")
    speedup = cal["snapshot_full_copy_s"] / max(cal["async_blocking_s"], 1e-9)
    print(f"async blocking {cal['async_blocking_s']*1e3:.1f} ms vs "
          f"monolithic snapshot {cal['snapshot_full_copy_s']*1e3:.1f} ms "
          f"({speedup:.1f}x lower)")
    return cal


def bench_optimize_plan():
    """The acceptance scenario: with latency the binding constraint, the
    plan optimizer must leave the full-sync baseline for a cheaper
    mechanism at equal QoS feasibility."""
    from repro.core import QoSModel, optimize_plan

    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 200)
    tr = rng.uniform(1000, 4000, 200)
    cost = SimCostModel(capacity_eps=4600.0, ckpt_duration_s=3.0,
                        ckpt_sync_penalty=0.6)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    res = optimize_plan(m_l, m_r, tr_avg=2500.0, l_const=1.0, r_const=240.0,
                        p=1.0, ci_min=10, ci_max=120, cost=cost)
    print("\n=== Plan optimization (latency-bound scenario) ===")
    print(f"{'variant':16s} {'feasible':>8s} {'ci':>6s} {'q_l':>6s} "
          f"{'q_r':>6s} {'objective':>9s} {'overhead':>8s}")
    for c in res.candidates:
        ci_s = f"{c.ci:.1f}" if c.ci is not None else "-"
        print(f"{c.plan.name:16s} {str(c.feasible):>8s} {ci_s:>6s} "
              f"{c.q_l:>6.3f} {c.q_r:>6.3f} {c.objective:>9.3f} "
              f"{c.overhead:>8.4f}")
    b = res.baseline
    print(f"chosen: {res.plan.name} @ CI={res.ci:.1f}s "
          f"(overhead {res.overhead:.4f}) vs baseline {b.plan.name} "
          f"(overhead {b.overhead:.4f})")
    assert res.plan.name != b.plan.name and res.overhead < b.overhead, \
        "optimizer failed to beat the full-sync baseline"
    return res


def bench_calibrated_optimize(cal: dict):
    """Run the same optimizer scenario with the MEASURED cost model — the
    end of the calibration loop.  With the host encode CPU priced, delta
    plans only win when their encode actually beats the write they save."""
    from repro.core import QoSModel, optimize_plan

    cost = SimCostModel.from_calibration(cal, capacity_eps=4600.0,
                                         ckpt_sync_penalty=0.6)
    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 200)
    tr = rng.uniform(1000, 4000, 200)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    res = optimize_plan(m_l, m_r, tr_avg=2500.0, l_const=1.0, r_const=240.0,
                        p=1.0, ci_min=10, ci_max=120, cost=cost)
    print("\n=== Plan optimization (calibrated cost model) ===")
    print(f"measured: full_write={cost.ckpt_duration_s*1e3:.1f}ms "
          f"delta_fraction={cost.delta_fraction:.4f} "
          f"encode={cost.delta_encode_s_per_byte * cost.state_bytes*1e3:.1f}"
          f"ms/trigger")
    if res.plan is not None:
        print(f"chosen: {res.plan.name} @ CI={res.ci:.1f}s "
              f"(overhead {res.overhead:.4f})")
    else:
        print("no feasible plan under the measured cost model")
    return res


def main(out: str = "BENCH_ckpt.json"):
    rows, meas = bench_checkpoint()
    plan_rows, plan_stats = bench_plans()
    rows += [(n, ms, f"bytes={b} vs_full={r:.3f}")
             for n, b, r, ms, _ in plan_rows]
    cal = emit_calibration(out, meas, plan_stats)
    bench_optimize_plan()
    bench_calibrated_optimize(cal)
    return rows


def smoke(tmpdir: str = "/tmp/repro_bench_ckpt_smoke") -> dict:
    """Tiny-state end-to-end check of the calibration loop: run the plan
    bench, emit BENCH_ckpt.json, validate its schema and load it back
    through ``SimCostModel.from_calibration``."""
    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)
    _, meas = bench_checkpoint(tmpdir + "/micro", scale=1)
    _, plan_stats = bench_plans(tmpdir + "/plans", triggers=6, scale=1)
    path = os.path.join(tmpdir, "BENCH_ckpt.json")
    cal = emit_calibration(path, meas, plan_stats)
    with open(path) as f:
        validate_calibration(json.load(f))
    cost = SimCostModel.from_calibration(path, capacity_eps=3000.0)
    assert cost.state_bytes > 0 and cost.ckpt_duration_s > 0
    assert cost.write_duration("delta") <= cost.write_duration("full") \
        or cost.delta_encode_s_per_byte > 0
    print(f"smoke OK: {path} validates and loads "
          f"(delta_fraction={cost.delta_fraction:.4f}, "
          f"encode_s_per_byte={cost.delta_encode_s_per_byte:.3e})")
    return cal


if __name__ == "__main__":
    main()
