"""E5 (beyond-paper): checkpoint subsystem microbenchmarks on a real model
state — sync vs async write blocking, incremental delta bytes, int8 codec
ratio, restore time.  These numbers calibrate the simulator's cost model
(sim/costmodel.py) for arch-specific CI optimization."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (AsyncCheckpointer, CheckpointStore,
                              IncrementalCheckpointer)
from repro.config import OptimizerConfig
from repro.configs import get_smoke_config
from repro.models import zoo
from repro.optim import make_optimizer
from repro.utils.trees import tree_bytes


def _mk_state(scale: int = 4):
    import dataclasses
    cfg = get_smoke_config("yi-6b")
    cfg = dataclasses.replace(cfg, d_model=64 * scale, d_ff=128 * scale,
                              num_layers=4)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(OptimizerConfig())
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def bench_checkpoint(tmpdir: str = "/tmp/repro_bench_ckpt"):
    import shutil
    shutil.rmtree(tmpdir, ignore_errors=True)
    state = _mk_state()
    nbytes = tree_bytes(state)
    print(f"\n=== Checkpoint subsystem (state = {nbytes/2**20:.1f} MiB) ===")
    rows = []

    store = CheckpointStore(tmpdir + "/sync", num_shards=4)
    t0 = time.monotonic()
    store.save(1, state)
    sync_s = time.monotonic() - t0
    rows.append(("ckpt_sync_save", sync_s * 1e6, f"{nbytes/sync_s/2**20:.0f} MiB/s"))

    ac = AsyncCheckpointer(CheckpointStore(tmpdir + "/async", num_shards=4))
    t0 = time.monotonic()
    ac.save(1, state)
    block_s = time.monotonic() - t0     # only the snapshot blocks
    ac.wait()
    rows.append(("ckpt_async_block", block_s * 1e6,
                 f"{block_s/sync_s:.3f}x of sync"))

    t0 = time.monotonic()
    restored, _ = store.restore(state)
    restore_s = time.monotonic() - t0
    rows.append(("ckpt_restore", restore_s * 1e6, f"{nbytes/restore_s/2**20:.0f} MiB/s"))

    for mode in ("lossless", "int8"):
        inc = IncrementalCheckpointer(CheckpointStore(tmpdir + f"/inc_{mode}",
                                                      num_shards=2),
                                      full_every=8, mode=mode)
        inc.save(0, state)
        bumped = jax.tree_util.tree_map(
            lambda x: x + jnp.asarray(1e-4, x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, state)
        t0 = time.monotonic()
        inc.save(1, bumped)
        dt = time.monotonic() - t0
        ratio = inc.bytes_written_delta / max(inc.bytes_written_full, 1)
        rows.append((f"ckpt_incr_{mode}", dt * 1e6,
                     f"delta/full bytes = {ratio:.4f}"))

    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows


def main():
    return bench_checkpoint()


if __name__ == "__main__":
    main()
