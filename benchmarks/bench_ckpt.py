"""E5 (beyond-paper): checkpoint subsystem microbenchmarks on a real model
state, measuring the pipelined save path stage by stage:

    trigger -> chunked D2H transfer || delta encode || compress || write

i.e. the ``ChunkedHostSnapshot`` first-chunk sync is the only blocking
cost (reported as ``blocking_s`` and compared against the monolithic
``snapshot_to_host`` deep copy it replaced), while the remaining chunks
stream to the leaf-parallel encode/compress/write workers on the io pool.

Besides the printed tables, ``main`` emits a ``BENCH_ckpt.json``
calibration artifact (schema "bench_ckpt/2": state bytes, full write
seconds, restore seconds, measured delta byte fractions, the per-byte
host encode CPU of the delta path, AND the ``device`` section — per-codec
on-device encode seconds and bytes-on-link of the ``DeltaLeafSource``
path, where the ckpt_delta kernels run in front of D2H) that
``sim.costmodel.SimCostModel.from_calibration`` loads — closing the loop
so the Khaos plan optimizer prices checkpoint mechanisms AND encode
placements with measured numbers instead of the hand-set
``delta_fraction``/level defaults.  The final scenario runs the plan
optimizer against that calibration and shows the (mode, CI) it picks vs
the full-sync baseline.

``smoke()`` (wired as ``benchmarks/run.py --smoke``) runs the same flow on
a tiny state and validates the emitted artifact's schema — a
tier-1-adjacent check that the calibration loop stays loadable.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (AsyncCheckpointer, CheckpointManager,
                              CheckpointPlan, CheckpointStore,
                              DeltaLeafSource, DeviceDeltaBase,
                              IncrementalCheckpointer)
from repro.checkpoint.async_ckpt import snapshot_to_host
from repro.config import OptimizerConfig
from repro.configs import get_smoke_config
from repro.models import zoo
from repro.optim import make_optimizer
from repro.sim import SimCostModel
from repro.sim.costmodel import CALIBRATION_KEYS
from repro.utils.trees import tree_bytes


def _mk_state(scale: int = 4):
    import dataclasses
    cfg = get_smoke_config("yi-6b")
    cfg = dataclasses.replace(cfg, d_model=64 * scale, d_ff=128 * scale,
                              num_layers=4)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(OptimizerConfig())
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _bump(state):
    return jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(1e-4, x.dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, state)


def bench_checkpoint(tmpdir: str = "/tmp/repro_bench_ckpt", scale: int = 4):
    """Single-mechanism microbenchmarks; returns (rows, measurements) where
    measurements feed the calibration artifact."""
    shutil.rmtree(tmpdir, ignore_errors=True)
    state = _mk_state(scale)
    jax.block_until_ready(state)   # don't bill pending init compute to the copy
    nbytes = tree_bytes(state)
    print(f"\n=== Checkpoint subsystem (state = {nbytes/2**20:.1f} MiB) ===")
    rows = []
    meas = {"state_bytes": nbytes}

    t0 = time.monotonic()
    snapshot_to_host(state)
    meas["snapshot_full_copy_s"] = time.monotonic() - t0
    rows.append(("ckpt_snapshot_full_copy", meas["snapshot_full_copy_s"] * 1e6,
                 "monolithic D2H deep copy (pre-pipeline blocking cost)"))

    store = CheckpointStore(tmpdir + "/sync", num_shards=4)
    t0 = time.monotonic()
    store.save(1, state)
    sync_s = time.monotonic() - t0
    meas["full_write_s"] = sync_s
    rows.append(("ckpt_sync_save", sync_s * 1e6, f"{nbytes/sync_s/2**20:.0f} MiB/s"))

    ac = AsyncCheckpointer(CheckpointStore(tmpdir + "/async", num_shards=4))
    t0 = time.monotonic()
    ac.save(1, state)
    block_s = time.monotonic() - t0     # only the chunked snapshot blocks
    ac.wait()
    meas["async_blocking_s"] = block_s
    rows.append(("ckpt_async_block", block_s * 1e6,
                 f"{block_s/sync_s:.3f}x of sync"))

    t0 = time.monotonic()
    restored, _ = store.restore(state)
    restore_s = time.monotonic() - t0
    meas["restore_s"] = restore_s
    rows.append(("ckpt_restore", restore_s * 1e6, f"{nbytes/restore_s/2**20:.0f} MiB/s"))

    for mode in ("lossless", "int8"):
        inc = IncrementalCheckpointer(CheckpointStore(tmpdir + f"/inc_{mode}",
                                                      num_shards=2),
                                      full_every=8, mode=mode)
        inc.save(0, state)
        bumped = _bump(state)
        t0 = time.monotonic()
        inc.save(1, bumped)
        dt = time.monotonic() - t0
        ratio = inc.bytes_written_delta / max(inc.bytes_written_full, 1)
        meas[f"delta_fraction_{mode}"] = ratio
        rows.append((f"ckpt_incr_{mode}", dt * 1e6,
                     f"delta/full bytes = {ratio:.4f}"))

    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows, meas


PLANS = {
    "full-sync": CheckpointPlan(),
    "full-async": CheckpointPlan(sync=False),
    "incr8-sync": CheckpointPlan(mode="incremental", full_every=8),
    "incr8-async": CheckpointPlan(mode="incremental", full_every=8,
                                  sync=False, busy_policy="block"),
    "dev-lossless": CheckpointPlan(mode="incremental", full_every=8,
                                   encode_placement="device"),
    "dev-int8": CheckpointPlan(mode="incremental", full_every=8,
                               encode_placement="device",
                               delta_codec="int8"),
    "multilevel": CheckpointPlan(levels=("memory", "local", "remote"),
                                 local_every=2, remote_every=8),
    "ml+delta": CheckpointPlan(mode="incremental", full_every=8,
                               levels=("memory", "local", "remote"),
                               local_every=1, remote_every=8),
}


def bench_plans(tmpdir: str = "/tmp/repro_bench_ckpt_plans",
                triggers: int = 16, scale: int = 4):
    """Whole-plan accounting: run ``triggers`` checkpoint triggers of a
    drifting train state through each plan and report total bytes written,
    mean blocking/write durations and delta-encode CPU seconds — the
    overhead the optimizer trades against QoS.  Returns (rows, per-plan
    stats dict for the calibration artifact)."""
    state = _mk_state(scale)
    nbytes = tree_bytes(state)
    print(f"\n=== Checkpoint plans ({triggers} triggers, "
          f"state = {nbytes/2**20:.1f} MiB) ===")
    print(f"{'plan':12s} {'bytes_written':>14s} {'vs_full':>8s} "
          f"{'write_ms':>9s} {'block_ms':>9s} {'encode_ms':>9s} "
          f"{'link_frac':>9s}")
    rows = []
    plan_stats: dict[str, dict] = {}
    baseline_bytes = None
    for name, plan in PLANS.items():
        shutil.rmtree(f"{tmpdir}/{name}", ignore_errors=True)
        mgr = CheckpointManager(f"{tmpdir}/{name}", plan)
        cur = state
        block, writes, encode, deltas = [], [], [], 0
        link, delta_link = [], []
        for i in range(triggers):
            cur = _bump(cur)
            rep = mgr.save(i, cur, float(i))
            block.append(rep.blocking_s)
            mgr.wait()
            writes.append(rep.duration_s)
            encode.append(rep.encode_s)
            link.append(rep.bytes_on_link)
            if rep.kind == "delta":
                deltas += 1
                delta_link.append(rep.bytes_on_link)
        st = mgr.stats()
        total = st["bytes_written"]
        if baseline_bytes is None:
            baseline_bytes = total
        plan_stats[name] = {
            "bytes_per_trigger": total / triggers,
            "write_s": float(np.mean(writes)),
            "blocking_s": float(np.mean(block)),
            "encode_cpu_s": float(np.sum(encode)),
            "delta_triggers": deltas,
            "bytes_by_kind": st["bytes_by_kind"],
            # pre-compression post-encode D2H traffic — the link/disk
            # distinction the cost model prices (host encodes move the raw
            # state; device encodes move only the payload)
            "bytes_on_link_per_trigger": float(np.mean(link)),
            "delta_bytes_on_link": (float(np.mean(delta_link))
                                    if delta_link else 0.0),
            "encode_placement": plan.encode_placement,
            "delta_codec": plan.delta_codec,
        }
        rows.append((name, total, total / baseline_bytes,
                     1e3 * float(np.mean(writes)),
                     1e3 * float(np.mean(block))))
        print(f"{name:12s} {total:>14d} {total/baseline_bytes:>8.3f} "
              f"{1e3*np.mean(writes):>9.1f} {1e3*np.mean(block):>9.1f} "
              f"{1e3*np.sum(encode):>9.1f} {np.mean(link)/nbytes:>9.3f}")
    return rows, plan_stats


# ---------------------------------------------------------------------------
# device-placement encode (DeltaLeafSource: kernels in front of D2H)
# ---------------------------------------------------------------------------

def bench_device_delta(scale: int = 4) -> dict:
    """Measure the on-device delta encode per codec: encode+payload-D2H
    seconds and bytes-on-link of one delta trigger vs the full state —
    the ``device`` section of the bench_ckpt/2 artifact
    (``SimCostModel.device_encode_s*`` / ``device_link_fraction*``)."""
    state = _mk_state(scale)
    jax.block_until_ready(state)
    bumped = _bump(state)
    jax.block_until_ready(bumped)
    nbytes = tree_bytes(state)
    base = DeviceDeltaBase(state)
    print(f"\n=== Device-placement delta encode "
          f"(state = {nbytes/2**20:.1f} MiB) ===")
    out: dict[str, dict] = {}
    for codec in ("lossless", "int8"):
        # warm the per-leaf-shape kernel jit caches so encode_s measures
        # the steady-state trigger, not compilation
        DeltaLeafSource(bumped, base, codec=codec).wait()
        t0 = time.monotonic()
        src = DeltaLeafSource(bumped, base, codec=codec)
        src.wait()
        encode_s = time.monotonic() - t0
        link = src.bytes_on_link()
        out[codec] = {"bytes_on_link": int(link),
                      "link_fraction": link / nbytes,
                      "encode_s": encode_s}
        print(f"device_{codec}: {1e3*encode_s:.1f} ms, "
              f"{link} B on link ({link/nbytes:.3f}x full state)")
    return out


# ---------------------------------------------------------------------------
# calibration artifact (BENCH_ckpt.json  <->  SimCostModel.from_calibration)
# ---------------------------------------------------------------------------

def build_calibration(meas: dict, plan_stats: dict, device: dict) -> dict:
    """Assemble the "bench_ckpt/2" artifact from the measured tables."""
    incr = plan_stats.get("incr8-sync", {})
    encode_per_byte = 0.0
    if incr.get("delta_triggers"):
        encode_per_byte = incr["encode_cpu_s"] / (
            meas["state_bytes"] * incr["delta_triggers"])
    return {
        "schema": "bench_ckpt/2",
        "state_bytes": meas["state_bytes"],
        "full_write_s": meas["full_write_s"],
        "restore_s": meas["restore_s"],
        "delta_fraction": meas["delta_fraction_lossless"],
        "delta_int8_fraction": meas["delta_fraction_int8"],
        "delta_encode_s_per_byte": encode_per_byte,
        "snapshot_full_copy_s": meas["snapshot_full_copy_s"],
        "async_blocking_s": meas["async_blocking_s"],
        "device": device,
        "plans": plan_stats,
    }


def validate_calibration(cal: dict) -> None:
    """Schema check for the artifact (the ``run.py --smoke`` gate).
    Key/schema-version checking is delegated to the consumer
    (``SimCostModel.from_calibration``) so the contract lives in one
    place; the numeric, plans-table and device-section checks below are
    bench-side only."""
    SimCostModel.from_calibration(cal)      # raises ValueError on mismatch
    for k in CALIBRATION_KEYS[1:]:
        if not isinstance(cal[k], (int, float)) or cal[k] < 0:
            raise ValueError(f"{k} must be a non-negative number, "
                             f"got {cal[k]!r}")
    if cal["state_bytes"] <= 0:
        raise ValueError("state_bytes must be positive")
    if not isinstance(cal.get("plans"), dict) or not cal["plans"]:
        raise ValueError("plans table missing or empty")
    for name, st in cal["plans"].items():
        for k in ("bytes_per_trigger", "write_s", "blocking_s",
                  "encode_cpu_s", "bytes_on_link_per_trigger",
                  "encode_placement", "delta_codec"):
            if k not in st:
                raise ValueError(f"plan {name!r} missing {k}")
    if cal["schema"] == "bench_ckpt/2":
        # device-encoded delta triggers must beat the full-state D2H —
        # the whole point of moving the encode in front of the link
        int8 = cal["device"]["int8"]
        if not int8["bytes_on_link"] < cal["state_bytes"]:
            raise ValueError(
                f"device int8 delta moved {int8['bytes_on_link']} B over "
                f"the link, >= the {cal['state_bytes']} B full state")
        for pname, st in cal["plans"].items():
            if (st.get("encode_placement") == "device"
                    and st.get("delta_codec") == "int8"
                    and st.get("delta_triggers")
                    and not st["delta_bytes_on_link"] < cal["state_bytes"]):
                raise ValueError(
                    f"plan {pname!r}: delta-trigger bytes_on_link "
                    f"{st['delta_bytes_on_link']} not under the full state")


def emit_calibration(path: str, meas: dict, plan_stats: dict,
                     device: dict) -> dict:
    cal = build_calibration(meas, plan_stats, device)
    validate_calibration(cal)
    with open(path, "w") as f:
        json.dump(cal, f, indent=2)
    print(f"\ncalibration artifact -> {path}")
    speedup = cal["snapshot_full_copy_s"] / max(cal["async_blocking_s"], 1e-9)
    print(f"async blocking {cal['async_blocking_s']*1e3:.1f} ms vs "
          f"monolithic snapshot {cal['snapshot_full_copy_s']*1e3:.1f} ms "
          f"({speedup:.1f}x lower)")
    return cal


def bench_optimize_plan():
    """The acceptance scenario: with latency the binding constraint, the
    plan optimizer must leave the full-sync baseline for a cheaper
    mechanism at equal QoS feasibility."""
    from repro.core import QoSModel, optimize_plan

    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 200)
    tr = rng.uniform(1000, 4000, 200)
    cost = SimCostModel(capacity_eps=4600.0, ckpt_duration_s=3.0,
                        ckpt_sync_penalty=0.6)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    res = optimize_plan(m_l, m_r, tr_avg=2500.0, l_const=1.0, r_const=240.0,
                        p=1.0, ci_min=10, ci_max=120, cost=cost)
    print("\n=== Plan optimization (latency-bound scenario) ===")
    print(f"{'variant':16s} {'feasible':>8s} {'ci':>6s} {'q_l':>6s} "
          f"{'q_r':>6s} {'objective':>9s} {'overhead':>8s}")
    for c in res.candidates:
        ci_s = f"{c.ci:.1f}" if c.ci is not None else "-"
        print(f"{c.plan.name:16s} {str(c.feasible):>8s} {ci_s:>6s} "
              f"{c.q_l:>6.3f} {c.q_r:>6.3f} {c.objective:>9.3f} "
              f"{c.overhead:>8.4f}")
    b = res.baseline
    print(f"chosen: {res.plan.name} @ CI={res.ci:.1f}s "
          f"(overhead {res.overhead:.4f}) vs baseline {b.plan.name} "
          f"(overhead {b.overhead:.4f})")
    assert res.plan.name != b.plan.name and res.overhead < b.overhead, \
        "optimizer failed to beat the full-sync baseline"
    return res


def bench_calibrated_optimize(cal: dict):
    """Run the same optimizer scenario with the MEASURED cost model — the
    end of the calibration loop.  With the host encode CPU priced, delta
    plans only win when their encode actually beats the write they save."""
    from repro.core import QoSModel, optimize_plan

    cost = SimCostModel.from_calibration(cal, capacity_eps=4600.0,
                                         ckpt_sync_penalty=0.6)
    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 200)
    tr = rng.uniform(1000, 4000, 200)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    res = optimize_plan(m_l, m_r, tr_avg=2500.0, l_const=1.0, r_const=240.0,
                        p=1.0, ci_min=10, ci_max=120, cost=cost)
    print("\n=== Plan optimization (calibrated cost model) ===")
    print(f"measured: full_write={cost.ckpt_duration_s*1e3:.1f}ms "
          f"delta_fraction={cost.delta_fraction:.4f} "
          f"encode={cost.delta_encode_s_per_byte * cost.state_bytes*1e3:.1f}"
          f"ms/trigger")
    if res.plan is not None:
        print(f"chosen: {res.plan.name} @ CI={res.ci:.1f}s "
              f"(overhead {res.overhead:.4f})")
    else:
        print("no feasible plan under the measured cost model")
    return res


def main(out: str = "BENCH_ckpt.json"):
    rows, meas = bench_checkpoint()
    plan_rows, plan_stats = bench_plans()
    device = bench_device_delta()
    rows += [(n, ms, f"bytes={b} vs_full={r:.3f}")
             for n, b, r, ms, _ in plan_rows]
    cal = emit_calibration(out, meas, plan_stats, device)
    bench_optimize_plan()
    bench_calibrated_optimize(cal)
    return rows


def _smoke_device_trainer(tmpdir: str) -> None:
    """Drive one micro live trainer on an ``encode_placement="device"``
    plan (interpret-mode kernels on CPU): a device-encoded delta must land
    and restore through the manager's decode path."""
    from repro.config import CheckpointPlan as Plan
    from repro.configs import get_smoke_config
    from repro.data.stream import EventStream, constant_rate
    from repro.runtime import ResilientTrainer, TrainerConfig

    plan = Plan(interval_s=2.0, mode="incremental", full_every=2,
                encode_placement="device", num_shards=2)
    tcfg = TrainerConfig(batch=2, seq_len=16, ckpt_dir=tmpdir,
                         time_scale=40.0, detect_s=1.0, restart_s=1.0,
                         plan=plan)
    from repro.config import OptimizerConfig as Opt
    trainer = ResilientTrainer(get_smoke_config("yi-6b"), tcfg,
                               EventStream(schedule=constant_rate(400.0)),
                               Opt(total_steps=500, lr=1e-3))
    trainer.run(duration_s=12.0)
    st = trainer.ckpt.stats()
    if st["bytes_by_kind"]["delta"] <= 0:
        raise ValueError(f"no device-encoded delta landed: {st}")
    if not 0 < st["bytes_on_link"] < st["bytes_written"] * 1000:
        raise ValueError(f"implausible bytes_on_link accounting: {st}")
    rep = trainer.ckpt.restore(trainer.state, "node")
    if rep.kind not in ("full", "full+delta"):
        raise ValueError(f"unexpected restore kind {rep.kind!r}")
    print(f"device-plan micro trainer OK: {st['saves']} triggers, "
          f"{st['bytes_by_kind']['delta']} delta bytes, restored "
          f"step {rep.step} ({rep.kind}) via the {plan.encode_placement} "
          f"decode path")


def smoke(tmpdir: str = "/tmp/repro_bench_ckpt_smoke") -> dict:
    """Tiny-state end-to-end check of the calibration loop: run the plan
    bench (device placements included), emit BENCH_ckpt.json, validate its
    bench_ckpt/2 schema (placement/codec fields, delta-trigger
    bytes-on-link under the full state), load it back through
    ``SimCostModel.from_calibration`` (plus a v1 artifact for the
    versioned fallback), and drive a micro trainer on a device-encode
    plan."""
    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)
    _, meas = bench_checkpoint(tmpdir + "/micro", scale=1)
    _, plan_stats = bench_plans(tmpdir + "/plans", triggers=6, scale=1)
    device = bench_device_delta(scale=1)
    path = os.path.join(tmpdir, "BENCH_ckpt.json")
    cal = emit_calibration(path, meas, plan_stats, device)
    with open(path) as f:
        validate_calibration(json.load(f))
    cost = SimCostModel.from_calibration(path, capacity_eps=3000.0)
    assert cost.state_bytes > 0 and cost.ckpt_duration_s > 0
    assert cost.write_duration("delta") <= cost.write_duration("full") \
        or cost.delta_encode_s_per_byte > 0
    assert cost.device_link_fraction_int8 < 1.0, \
        "int8 device deltas must shrink the link traffic"
    # placement pricing: device deltas swap the host encode term
    # (delta_encode_s_per_byte * state_bytes) for the measured device
    # encode — the difference must be exactly that swap, nothing dropped
    # or double-charged
    host_d = cost.write_duration("delta")
    dev_d = cost.write_duration("delta", placement="device")
    swap = cost.device_encode_s \
        - cost.delta_encode_s_per_byte * cost.state_bytes
    assert abs((dev_d - host_d) - swap) < 1e-12, \
        f"device placement mispriced: {dev_d - host_d} != {swap}"
    # link accounting: the modeled per-trigger link bytes must rank the
    # int8-device plan under the host plan (and match the artifact's
    # measured fraction on delta triggers)
    incr8 = CheckpointPlan(mode="incremental", full_every=8)
    dev8 = CheckpointPlan(mode="incremental", full_every=8,
                          encode_placement="device", delta_codec="int8")
    assert cost.avg_link_bytes(dev8) < cost.avg_link_bytes(incr8) \
        == cost.state_bytes, "link-bytes model lost the placement dimension"
    # versioned fallback: a v1 artifact (no device section) still loads,
    # with the device fields at their modeled defaults
    v1 = {k: v for k, v in cal.items() if k != "device"}
    v1["schema"] = "bench_ckpt/1"
    cost_v1 = SimCostModel.from_calibration(v1)
    assert cost_v1.device_link_fraction_int8 == \
        SimCostModel.device_link_fraction_int8
    _smoke_device_trainer(tmpdir + "/trainer")
    print(f"smoke OK: {path} validates and loads "
          f"(delta_fraction={cost.delta_fraction:.4f}, "
          f"encode_s_per_byte={cost.delta_encode_s_per_byte:.3e}, "
          f"device int8 link fraction "
          f"{cost.device_link_fraction_int8:.3f})")
    return cal


if __name__ == "__main__":
    main()
