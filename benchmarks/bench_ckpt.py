"""E5 (beyond-paper): checkpoint subsystem microbenchmarks on a real model
state — sync vs async write blocking, incremental delta bytes, int8 codec
ratio, restore time, and whole-*plan* comparisons (full vs delta vs
multilevel: bytes written + write duration per trigger) through the
unified ``CheckpointManager``.  These numbers calibrate the simulator's
cost model (sim/costmodel.py); the final scenario runs the plan optimizer
against that calibration and shows the (mode, CI) it picks vs the
full-sync baseline."""
from __future__ import annotations

import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (AsyncCheckpointer, CheckpointManager,
                              CheckpointPlan, CheckpointStore,
                              IncrementalCheckpointer)
from repro.config import OptimizerConfig
from repro.configs import get_smoke_config
from repro.models import zoo
from repro.optim import make_optimizer
from repro.utils.trees import tree_bytes


def _mk_state(scale: int = 4):
    import dataclasses
    cfg = get_smoke_config("yi-6b")
    cfg = dataclasses.replace(cfg, d_model=64 * scale, d_ff=128 * scale,
                              num_layers=4)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(OptimizerConfig())
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def bench_checkpoint(tmpdir: str = "/tmp/repro_bench_ckpt"):
    import shutil
    shutil.rmtree(tmpdir, ignore_errors=True)
    state = _mk_state()
    nbytes = tree_bytes(state)
    print(f"\n=== Checkpoint subsystem (state = {nbytes/2**20:.1f} MiB) ===")
    rows = []

    store = CheckpointStore(tmpdir + "/sync", num_shards=4)
    t0 = time.monotonic()
    store.save(1, state)
    sync_s = time.monotonic() - t0
    rows.append(("ckpt_sync_save", sync_s * 1e6, f"{nbytes/sync_s/2**20:.0f} MiB/s"))

    ac = AsyncCheckpointer(CheckpointStore(tmpdir + "/async", num_shards=4))
    t0 = time.monotonic()
    ac.save(1, state)
    block_s = time.monotonic() - t0     # only the snapshot blocks
    ac.wait()
    rows.append(("ckpt_async_block", block_s * 1e6,
                 f"{block_s/sync_s:.3f}x of sync"))

    t0 = time.monotonic()
    restored, _ = store.restore(state)
    restore_s = time.monotonic() - t0
    rows.append(("ckpt_restore", restore_s * 1e6, f"{nbytes/restore_s/2**20:.0f} MiB/s"))

    for mode in ("lossless", "int8"):
        inc = IncrementalCheckpointer(CheckpointStore(tmpdir + f"/inc_{mode}",
                                                      num_shards=2),
                                      full_every=8, mode=mode)
        inc.save(0, state)
        bumped = jax.tree_util.tree_map(
            lambda x: x + jnp.asarray(1e-4, x.dtype)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, state)
        t0 = time.monotonic()
        inc.save(1, bumped)
        dt = time.monotonic() - t0
        ratio = inc.bytes_written_delta / max(inc.bytes_written_full, 1)
        rows.append((f"ckpt_incr_{mode}", dt * 1e6,
                     f"delta/full bytes = {ratio:.4f}"))

    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows


PLANS = {
    "full-sync": CheckpointPlan(),
    "full-async": CheckpointPlan(sync=False),
    "incr8-sync": CheckpointPlan(mode="incremental", full_every=8),
    "multilevel": CheckpointPlan(levels=("memory", "local", "remote"),
                                 local_every=2, remote_every=8),
    "ml+delta": CheckpointPlan(mode="incremental", full_every=8,
                               levels=("memory", "local", "remote"),
                               local_every=1, remote_every=8),
}


def bench_plans(tmpdir: str = "/tmp/repro_bench_ckpt_plans",
                triggers: int = 16):
    """Whole-plan accounting: run ``triggers`` checkpoint triggers of a
    drifting train state through each plan and report total bytes written
    and mean blocking/write durations — the overhead the optimizer trades
    against QoS."""
    state = _mk_state()
    nbytes = tree_bytes(state)
    print(f"\n=== Checkpoint plans ({triggers} triggers, "
          f"state = {nbytes/2**20:.1f} MiB) ===")
    print(f"{'plan':12s} {'bytes_written':>14s} {'vs_full':>8s} "
          f"{'write_ms':>9s} {'block_ms':>9s}")
    rows = []
    baseline_bytes = None
    for name, plan in PLANS.items():
        shutil.rmtree(f"{tmpdir}/{name}", ignore_errors=True)
        mgr = CheckpointManager(f"{tmpdir}/{name}", plan)
        cur = state
        block, writes = [], []
        for i in range(triggers):
            cur = jax.tree_util.tree_map(
                lambda x: x + jnp.asarray(1e-4, x.dtype)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, cur)
            rep = mgr.save(i, cur, float(i))
            block.append(rep.blocking_s)
            mgr.wait()
            writes.append(rep.duration_s)
        st = mgr.stats()
        total = st["bytes_written"]
        if baseline_bytes is None:
            baseline_bytes = total
        rows.append((name, total, total / baseline_bytes,
                     1e3 * float(np.mean(writes)),
                     1e3 * float(np.mean(block))))
        print(f"{name:12s} {total:>14d} {total/baseline_bytes:>8.3f} "
              f"{1e3*np.mean(writes):>9.1f} {1e3*np.mean(block):>9.1f}")
    return rows


def bench_optimize_plan():
    """The acceptance scenario: with latency the binding constraint, the
    plan optimizer must leave the full-sync baseline for a cheaper
    mechanism at equal QoS feasibility."""
    from repro.core import QoSModel, optimize_plan
    from repro.sim import SimCostModel

    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 200)
    tr = rng.uniform(1000, 4000, 200)
    cost = SimCostModel(capacity_eps=4600.0, ckpt_duration_s=3.0,
                        ckpt_sync_penalty=0.6)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    res = optimize_plan(m_l, m_r, tr_avg=2500.0, l_const=1.0, r_const=240.0,
                        p=1.0, ci_min=10, ci_max=120, cost=cost)
    print("\n=== Plan optimization (latency-bound scenario) ===")
    print(f"{'variant':16s} {'feasible':>8s} {'ci':>6s} {'q_l':>6s} "
          f"{'q_r':>6s} {'objective':>9s} {'overhead':>8s}")
    for c in res.candidates:
        ci_s = f"{c.ci:.1f}" if c.ci is not None else "-"
        print(f"{c.plan.name:16s} {str(c.feasible):>8s} {ci_s:>6s} "
              f"{c.q_l:>6.3f} {c.q_r:>6.3f} {c.objective:>9.3f} "
              f"{c.overhead:>8.4f}")
    b = res.baseline
    print(f"chosen: {res.plan.name} @ CI={res.ci:.1f}s "
          f"(overhead {res.overhead:.4f}) vs baseline {b.plan.name} "
          f"(overhead {b.overhead:.4f})")
    assert res.plan.name != b.plan.name and res.overhead < b.overhead, \
        "optimizer failed to beat the full-sync baseline"
    return res


def main():
    rows = bench_checkpoint()
    rows += [(n, ms, f"bytes={b} vs_full={r:.3f}")
             for n, b, r, ms, _ in bench_plans()]
    bench_optimize_plan()
    return rows


if __name__ == "__main__":
    main()
