"""E5 (beyond-paper): checkpoint subsystem microbenchmarks on a real model
state, measuring the pipelined save path stage by stage:

    trigger -> chunked D2H transfer || delta encode || compress || write

i.e. the ``ChunkedHostSnapshot`` first-chunk sync is the only blocking
cost (reported as ``blocking_s`` and compared against the monolithic
``snapshot_to_host`` deep copy it replaced), while the remaining chunks
stream to the leaf-parallel encode/compress/write workers on the io pool.

Besides the printed tables, ``main`` emits a ``BENCH_ckpt.json``
calibration artifact (schema "bench_ckpt/3": state bytes, full write
seconds, restore seconds, measured delta byte fractions, the per-byte
host encode CPU of the delta path, AND the ``device`` section — per-codec
FUSED flat encode seconds (one kernel over the packed mega-buffer), the
pack dispatch seconds, the pre-flat per-leaf dispatch baseline
``per_leaf_encode_s`` the CI gate regresses against, and bytes-on-link of
the ``DeltaLeafSource`` path, where the ckpt_delta kernels run in front
of D2H) that ``sim.costmodel.SimCostModel.from_calibration`` loads —
closing the loop so the Khaos plan optimizer prices checkpoint mechanisms
AND encode placements with measured numbers instead of the hand-set
``delta_fraction``/level defaults.  The final scenario runs the plan
optimizer against that calibration and shows the (mode, CI) it picks vs
the full-sync baseline.

``smoke()`` (wired as ``benchmarks/run.py --smoke``) runs the same flow on
a tiny state and validates the emitted artifact's schema — including the
v3 gates: int8 ``bytes_on_link`` <= 0.26x the full state, and the fused
``encode_s`` under the recorded per-leaf baseline — a tier-1-adjacent
check that the calibration loop stays loadable and the flat path stays
the fast one.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import (AsyncCheckpointer, CheckpointManager,
                              CheckpointPlan, CheckpointStore,
                              DeltaLeafSource, DeviceDeltaBase,
                              IncrementalCheckpointer)
from repro.checkpoint.async_ckpt import snapshot_to_host
from repro.config import OptimizerConfig
from repro.configs import get_smoke_config
from repro.models import zoo
from repro.optim import make_optimizer
from repro.sim import SimCostModel
from repro.sim.costmodel import CALIBRATION_KEYS
from repro.utils.trees import tree_bytes


def _mk_state(scale: int = 4):
    import dataclasses
    cfg = get_smoke_config("yi-6b")
    cfg = dataclasses.replace(cfg, d_model=64 * scale, d_ff=128 * scale,
                              num_layers=4)
    params = zoo.init_params(cfg, jax.random.PRNGKey(0))
    opt = make_optimizer(OptimizerConfig())
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def _bump(state):
    return jax.tree_util.tree_map(
        lambda x: x + jnp.asarray(1e-4, x.dtype)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, state)


def bench_checkpoint(tmpdir: str = "/tmp/repro_bench_ckpt", scale: int = 4):
    """Single-mechanism microbenchmarks; returns (rows, measurements) where
    measurements feed the calibration artifact."""
    shutil.rmtree(tmpdir, ignore_errors=True)
    state = _mk_state(scale)
    jax.block_until_ready(state)   # don't bill pending init compute to the copy
    nbytes = tree_bytes(state)
    print(f"\n=== Checkpoint subsystem (state = {nbytes/2**20:.1f} MiB) ===")
    rows = []
    meas = {"state_bytes": nbytes}

    t0 = time.monotonic()
    snapshot_to_host(state)
    meas["snapshot_full_copy_s"] = time.monotonic() - t0
    rows.append(("ckpt_snapshot_full_copy", meas["snapshot_full_copy_s"] * 1e6,
                 "monolithic D2H deep copy (pre-pipeline blocking cost)"))

    store = CheckpointStore(tmpdir + "/sync", num_shards=4)
    t0 = time.monotonic()
    store.save(1, state)
    sync_s = time.monotonic() - t0
    meas["full_write_s"] = sync_s
    rows.append(("ckpt_sync_save", sync_s * 1e6, f"{nbytes/sync_s/2**20:.0f} MiB/s"))

    ac = AsyncCheckpointer(CheckpointStore(tmpdir + "/async", num_shards=4))
    t0 = time.monotonic()
    ac.save(1, state)
    block_s = time.monotonic() - t0     # only the chunked snapshot blocks
    ac.wait()
    meas["async_blocking_s"] = block_s
    rows.append(("ckpt_async_block", block_s * 1e6,
                 f"{block_s/sync_s:.3f}x of sync"))

    t0 = time.monotonic()
    restored, _ = store.restore(state)
    restore_s = time.monotonic() - t0
    meas["restore_s"] = restore_s
    rows.append(("ckpt_restore", restore_s * 1e6, f"{nbytes/restore_s/2**20:.0f} MiB/s"))

    for mode in ("lossless", "int8"):
        inc = IncrementalCheckpointer(CheckpointStore(tmpdir + f"/inc_{mode}",
                                                      num_shards=2),
                                      full_every=8, mode=mode)
        inc.save(0, state)
        bumped = _bump(state)
        t0 = time.monotonic()
        inc.save(1, bumped)
        dt = time.monotonic() - t0
        ratio = inc.bytes_written_delta / max(inc.bytes_written_full, 1)
        meas[f"delta_fraction_{mode}"] = ratio
        rows.append((f"ckpt_incr_{mode}", dt * 1e6,
                     f"delta/full bytes = {ratio:.4f}"))

    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
    return rows, meas


PLANS = {
    "full-sync": CheckpointPlan(),
    "full-async": CheckpointPlan(sync=False),
    "incr8-sync": CheckpointPlan(mode="incremental", full_every=8),
    "incr8-async": CheckpointPlan(mode="incremental", full_every=8,
                                  sync=False, busy_policy="block"),
    "dev-lossless": CheckpointPlan(mode="incremental", full_every=8,
                                   encode_placement="device"),
    "dev-int8": CheckpointPlan(mode="incremental", full_every=8,
                               encode_placement="device",
                               delta_codec="int8"),
    "multilevel": CheckpointPlan(levels=("memory", "local", "remote"),
                                 local_every=2, remote_every=8),
    "ml+delta": CheckpointPlan(mode="incremental", full_every=8,
                               levels=("memory", "local", "remote"),
                               local_every=1, remote_every=8),
}


def bench_plans(tmpdir: str = "/tmp/repro_bench_ckpt_plans",
                triggers: int = 16, scale: int = 4):
    """Whole-plan accounting: run ``triggers`` checkpoint triggers of a
    drifting train state through each plan and report total bytes written,
    mean blocking/write durations and delta-encode CPU seconds — the
    overhead the optimizer trades against QoS.  Returns (rows, per-plan
    stats dict for the calibration artifact)."""
    state = _mk_state(scale)
    nbytes = tree_bytes(state)
    print(f"\n=== Checkpoint plans ({triggers} triggers, "
          f"state = {nbytes/2**20:.1f} MiB) ===")
    print(f"{'plan':12s} {'bytes_written':>14s} {'vs_full':>8s} "
          f"{'write_ms':>9s} {'block_ms':>9s} {'encode_ms':>9s} "
          f"{'link_frac':>9s}")
    rows = []
    plan_stats: dict[str, dict] = {}
    baseline_bytes = None
    for name, plan in PLANS.items():
        shutil.rmtree(f"{tmpdir}/{name}", ignore_errors=True)
        mgr = CheckpointManager(f"{tmpdir}/{name}", plan)
        cur = state
        block, writes, encode, deltas = [], [], [], 0
        link, delta_link = [], []
        for i in range(triggers):
            cur = _bump(cur)
            rep = mgr.save(i, cur, float(i))
            block.append(rep.blocking_s)
            mgr.wait()
            writes.append(rep.duration_s)
            encode.append(rep.encode_s)
            link.append(rep.bytes_on_link)
            if rep.kind == "delta":
                deltas += 1
                delta_link.append(rep.bytes_on_link)
        st = mgr.stats()
        total = st["bytes_written"]
        if baseline_bytes is None:
            baseline_bytes = total
        plan_stats[name] = {
            "bytes_per_trigger": total / triggers,
            "write_s": float(np.mean(writes)),
            "blocking_s": float(np.mean(block)),
            "encode_cpu_s": float(np.sum(encode)),
            "delta_triggers": deltas,
            "bytes_by_kind": st["bytes_by_kind"],
            # pre-compression post-encode D2H traffic — the link/disk
            # distinction the cost model prices (host encodes move the raw
            # state; device encodes move only the payload)
            "bytes_on_link_per_trigger": float(np.mean(link)),
            "delta_bytes_on_link": (float(np.mean(delta_link))
                                    if delta_link else 0.0),
            "encode_placement": plan.encode_placement,
            "delta_codec": plan.delta_codec,
        }
        rows.append((name, total, total / baseline_bytes,
                     1e3 * float(np.mean(writes)),
                     1e3 * float(np.mean(block))))
        print(f"{name:12s} {total:>14d} {total/baseline_bytes:>8.3f} "
              f"{1e3*np.mean(writes):>9.1f} {1e3*np.mean(block):>9.1f} "
              f"{1e3*np.sum(encode):>9.1f} {np.mean(link)/nbytes:>9.3f}")
    return rows, plan_stats


# ---------------------------------------------------------------------------
# device-placement encode (DeltaLeafSource: kernels in front of D2H)
# ---------------------------------------------------------------------------

def bench_device_delta(scale: int = 4) -> dict:
    """Measure the on-device delta encode per codec — the ``device``
    section of the bench_ckpt/3 artifact:

      * ``pack_s``: the per-trigger ``pack_flat`` dispatch (new state's
        f32 subtree -> one GROUP-aligned mega-buffer);
      * ``encode_s``: ONE fused flat kernel dispatch + pulling every
        output plane to host (``SimCostModel.device_encode_s*``);
      * ``per_leaf_encode_s``: the pre-flat baseline — one
        ``*_encode_leaf`` dispatch per leaf with all outputs pulled —
        that the validate gate regresses ``encode_s`` against;
      * ``bytes_on_link``/``link_fraction``: one ``DeltaLeafSource``
        trigger's payload D2H vs the full state
        (``device_link_fraction*``).
    """
    from repro.kernels.ckpt_delta.ops import (default_interpret,
                                              flat_int8_encode,
                                              flat_lossless_encode,
                                              int8_encode_leaf,
                                              lossless_encode_leaf,
                                              pack_flat)
    from repro.utils.trees import tree_flatten_with_names

    state = _mk_state(scale)
    jax.block_until_ready(state)
    bumped = _bump(state)
    jax.block_until_ready(bumped)
    nbytes = tree_bytes(state)
    base = DeviceDeltaBase(state)
    layout = base.layout
    assert layout is not None, "bench state has no packable f32 subtree"
    interp = default_interpret()
    new_leaves = dict(tree_flatten_with_names(bumped))
    packable = [new_leaves[n] for n in layout.names]
    print(f"\n=== Device-placement delta encode "
          f"(state = {nbytes/2**20:.1f} MiB, "
          f"{len(layout.names)} packed leaves) ===")

    jax.block_until_ready(pack_flat(packable))       # warm the jit cache
    t0 = time.monotonic()
    new_flat = jax.block_until_ready(pack_flat(packable))
    pack_s = time.monotonic() - t0

    gl = layout.group_leaf_device()
    nl = len(layout.names)
    out: dict[str, dict] = {}
    for codec in ("lossless", "int8"):
        fused = flat_lossless_encode if codec == "lossless" \
            else flat_int8_encode
        leaf_op = lossless_encode_leaf if codec == "lossless" \
            else int8_encode_leaf
        # warm every jit cache BEFORE any timing (fused: one trace;
        # per-leaf: one per distinct leaf shape) — the 36 per-leaf traces
        # churn enough allocator state to inflate a timing taken right
        # after them — then take best-of-3, the standard microbenchmark
        # defense against interpret-mode jitter
        jax.block_until_ready(fused(new_flat, base.flat, gl,
                                    num_leaves=nl, interpret=interp))
        for n in layout.names:
            jax.block_until_ready(leaf_op(new_leaves[n], base.leaves[n],
                                          interpret=interp))

        encode_s = float("inf")
        for _ in range(3):
            t0 = time.monotonic()
            for arr in fused(new_flat, base.flat, gl,
                             num_leaves=nl, interpret=interp):
                np.asarray(arr)
            encode_s = min(encode_s, time.monotonic() - t0)

        per_leaf_s = float("inf")
        for _ in range(3):
            t0 = time.monotonic()
            for n in layout.names:
                for arr in leaf_op(new_leaves[n], base.leaves[n],
                                   interpret=interp):
                    np.asarray(arr)
            per_leaf_s = min(per_leaf_s, time.monotonic() - t0)

        src = DeltaLeafSource(bumped, base, codec=codec)
        src.wait()
        link = src.bytes_on_link()
        out[codec] = {"bytes_on_link": int(link),
                      "link_fraction": link / nbytes,
                      "encode_s": encode_s,
                      "pack_s": pack_s,
                      "per_leaf_encode_s": per_leaf_s}
        print(f"device_{codec}: pack {1e3*pack_s:.1f} ms, fused encode "
              f"{1e3*encode_s:.1f} ms (per-leaf baseline "
              f"{1e3*per_leaf_s:.1f} ms), {link} B on link "
              f"({link/nbytes:.3f}x full state)")
    return out


# ---------------------------------------------------------------------------
# calibration artifact (BENCH_ckpt.json  <->  SimCostModel.from_calibration)
# ---------------------------------------------------------------------------

def build_calibration(meas: dict, plan_stats: dict, device: dict) -> dict:
    """Assemble the "bench_ckpt/3" artifact from the measured tables."""
    incr = plan_stats.get("incr8-sync", {})
    encode_per_byte = 0.0
    if incr.get("delta_triggers"):
        encode_per_byte = incr["encode_cpu_s"] / (
            meas["state_bytes"] * incr["delta_triggers"])
    return {
        "schema": "bench_ckpt/3",
        "state_bytes": meas["state_bytes"],
        "full_write_s": meas["full_write_s"],
        "restore_s": meas["restore_s"],
        "delta_fraction": meas["delta_fraction_lossless"],
        "delta_int8_fraction": meas["delta_fraction_int8"],
        "delta_encode_s_per_byte": encode_per_byte,
        "snapshot_full_copy_s": meas["snapshot_full_copy_s"],
        "async_blocking_s": meas["async_blocking_s"],
        "device": device,
        "plans": plan_stats,
    }


def validate_calibration(cal: dict) -> None:
    """Schema check for the artifact (the ``run.py --smoke`` gate).
    Key/schema-version checking is delegated to the consumer
    (``SimCostModel.from_calibration``) so the contract lives in one
    place; the numeric, plans-table and device-section checks below are
    bench-side only."""
    SimCostModel.from_calibration(cal)      # raises ValueError on mismatch
    for k in CALIBRATION_KEYS[1:]:
        if not isinstance(cal[k], (int, float)) or cal[k] < 0:
            raise ValueError(f"{k} must be a non-negative number, "
                             f"got {cal[k]!r}")
    if cal["state_bytes"] <= 0:
        raise ValueError("state_bytes must be positive")
    if not isinstance(cal.get("plans"), dict) or not cal["plans"]:
        raise ValueError("plans table missing or empty")
    for name, st in cal["plans"].items():
        for k in ("bytes_per_trigger", "write_s", "blocking_s",
                  "encode_cpu_s", "bytes_on_link_per_trigger",
                  "encode_placement", "delta_codec"):
            if k not in st:
                raise ValueError(f"plan {name!r} missing {k}")
    if cal["schema"] in ("bench_ckpt/2", "bench_ckpt/3"):
        # device-encoded delta triggers must beat the full-state D2H —
        # the whole point of moving the encode in front of the link.
        # Gate on the FRACTION: the device section may be measured on a
        # different state scale than the rest of the artifact (smoke does
        # this), and the cost model only ever consumes the fractions
        int8 = cal["device"]["int8"]
        if not int8["link_fraction"] < 1.0:
            raise ValueError(
                f"device int8 delta moved {int8['link_fraction']:.3f}x the "
                f"full state over the link — encode-before-link must shrink "
                f"the payload")
        for pname, st in cal["plans"].items():
            if (st.get("encode_placement") == "device"
                    and st.get("delta_codec") == "int8"
                    and st.get("delta_triggers")
                    and not st["delta_bytes_on_link"] < cal["state_bytes"]):
                raise ValueError(
                    f"plan {pname!r}: delta-trigger bytes_on_link "
                    f"{st['delta_bytes_on_link']} not under the full state")
    if cal["schema"] == "bench_ckpt/3":
        # the flat-path gates: the int8 payload must stay within its
        # analytic bound (q + 1/256 scales + GROUP padding ~= 0.26x the
        # state), and the fused flat encode must not regress above the
        # per-leaf dispatch baseline it replaced
        if not cal["device"]["int8"]["link_fraction"] <= 0.26:
            raise ValueError(
                f"device int8 link fraction "
                f"{cal['device']['int8']['link_fraction']:.4f} exceeds the "
                f"0.26 payload bound (q + scales + GROUP padding)")
        for codec in ("lossless", "int8"):
            e = cal["device"][codec]
            if not e["encode_s"] < e["per_leaf_encode_s"]:
                raise ValueError(
                    f"fused {codec} encode regressed: {e['encode_s']:.4f}s "
                    f">= per-leaf baseline {e['per_leaf_encode_s']:.4f}s")


def emit_calibration(path: str, meas: dict, plan_stats: dict,
                     device: dict) -> dict:
    cal = build_calibration(meas, plan_stats, device)
    validate_calibration(cal)
    with open(path, "w") as f:
        json.dump(cal, f, indent=2)
    print(f"\ncalibration artifact -> {path}")
    speedup = cal["snapshot_full_copy_s"] / max(cal["async_blocking_s"], 1e-9)
    print(f"async blocking {cal['async_blocking_s']*1e3:.1f} ms vs "
          f"monolithic snapshot {cal['snapshot_full_copy_s']*1e3:.1f} ms "
          f"({speedup:.1f}x lower)")
    return cal


def bench_optimize_plan():
    """The acceptance scenario: with latency the binding constraint, the
    plan optimizer must leave the full-sync baseline for a cheaper
    mechanism at equal QoS feasibility."""
    from repro.core import QoSModel, optimize_plan

    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 200)
    tr = rng.uniform(1000, 4000, 200)
    cost = SimCostModel(capacity_eps=4600.0, ckpt_duration_s=3.0,
                        ckpt_sync_penalty=0.6)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    res = optimize_plan(m_l, m_r, tr_avg=2500.0, l_const=1.0, r_const=240.0,
                        p=1.0, ci_min=10, ci_max=120, cost=cost)
    print("\n=== Plan optimization (latency-bound scenario) ===")
    print(f"{'variant':16s} {'feasible':>8s} {'ci':>6s} {'q_l':>6s} "
          f"{'q_r':>6s} {'objective':>9s} {'overhead':>8s}")
    for c in res.candidates:
        ci_s = f"{c.ci:.1f}" if c.ci is not None else "-"
        print(f"{c.plan.name:16s} {str(c.feasible):>8s} {ci_s:>6s} "
              f"{c.q_l:>6.3f} {c.q_r:>6.3f} {c.objective:>9.3f} "
              f"{c.overhead:>8.4f}")
    b = res.baseline
    print(f"chosen: {res.plan.name} @ CI={res.ci:.1f}s "
          f"(overhead {res.overhead:.4f}) vs baseline {b.plan.name} "
          f"(overhead {b.overhead:.4f})")
    assert res.plan.name != b.plan.name and res.overhead < b.overhead, \
        "optimizer failed to beat the full-sync baseline"
    return res


def bench_calibrated_optimize(cal: dict):
    """Run the same optimizer scenario with the MEASURED cost model — the
    end of the calibration loop.  With the host encode CPU priced, delta
    plans only win when their encode actually beats the write they save."""
    from repro.core import QoSModel, optimize_plan

    cost = SimCostModel.from_calibration(cal, capacity_eps=4600.0,
                                         ckpt_sync_penalty=0.6)
    rng = np.random.default_rng(0)
    ci = rng.uniform(10, 120, 200)
    tr = rng.uniform(1000, 4000, 200)
    m_l = QoSModel().fit(ci, tr, cost.base_latency_s + 40.0 / ci + tr * 1e-5)
    m_r = QoSModel().fit(ci, tr, 80.0 + 1.2 * ci + 0.01 * tr)
    res = optimize_plan(m_l, m_r, tr_avg=2500.0, l_const=1.0, r_const=240.0,
                        p=1.0, ci_min=10, ci_max=120, cost=cost)
    print("\n=== Plan optimization (calibrated cost model) ===")
    print(f"measured: full_write={cost.ckpt_duration_s*1e3:.1f}ms "
          f"delta_fraction={cost.delta_fraction:.4f} "
          f"encode={cost.delta_encode_s_per_byte * cost.state_bytes*1e3:.1f}"
          f"ms/trigger")
    if res.plan is not None:
        print(f"chosen: {res.plan.name} @ CI={res.ci:.1f}s "
              f"(overhead {res.overhead:.4f})")
    else:
        print("no feasible plan under the measured cost model")
    return res


def main(out: str = "BENCH_ckpt.json"):
    rows, meas = bench_checkpoint()
    plan_rows, plan_stats = bench_plans()
    device = bench_device_delta()
    rows += [(n, ms, f"bytes={b} vs_full={r:.3f}")
             for n, b, r, ms, _ in plan_rows]
    cal = emit_calibration(out, meas, plan_stats, device)
    # the flat-path acceptance bar: ONE fused device encode dispatch must
    # come in under the host full write it lets the plan skip
    for codec in ("lossless", "int8"):
        e = cal["device"][codec]
        assert e["encode_s"] < cal["full_write_s"], \
            f"fused {codec} encode {e['encode_s']:.4f}s not under the " \
            f"host full write {cal['full_write_s']:.4f}s"
    bench_optimize_plan()
    bench_calibrated_optimize(cal)
    return rows


def _smoke_device_trainer(tmpdir: str) -> None:
    """Drive one micro live trainer on an ``encode_placement="device"``
    plan (interpret-mode kernels on CPU): a device-encoded delta must land
    and restore through the manager's decode path."""
    from repro.config import CheckpointPlan as Plan
    from repro.configs import get_smoke_config
    from repro.data.stream import EventStream, constant_rate
    from repro.runtime import ResilientTrainer, TrainerConfig

    plan = Plan(interval_s=2.0, mode="incremental", full_every=2,
                encode_placement="device", num_shards=2)
    tcfg = TrainerConfig(batch=2, seq_len=16, ckpt_dir=tmpdir,
                         time_scale=40.0, detect_s=1.0, restart_s=1.0,
                         plan=plan)
    from repro.config import OptimizerConfig as Opt
    trainer = ResilientTrainer(get_smoke_config("yi-6b"), tcfg,
                               EventStream(schedule=constant_rate(400.0)),
                               Opt(total_steps=500, lr=1e-3))
    trainer.run(duration_s=12.0)
    st = trainer.ckpt.stats()
    if st["bytes_by_kind"]["delta"] <= 0:
        raise ValueError(f"no device-encoded delta landed: {st}")
    if not 0 < st["bytes_on_link"] < st["bytes_written"] * 1000:
        raise ValueError(f"implausible bytes_on_link accounting: {st}")
    rep = trainer.ckpt.restore(trainer.state, "node")
    if rep.kind not in ("full", "full+delta"):
        raise ValueError(f"unexpected restore kind {rep.kind!r}")
    print(f"device-plan micro trainer OK: {st['saves']} triggers, "
          f"{st['bytes_by_kind']['delta']} delta bytes, restored "
          f"step {rep.step} ({rep.kind}) via the {plan.encode_placement} "
          f"decode path")


def smoke(tmpdir: str = "/tmp/repro_bench_ckpt_smoke") -> dict:
    """Tiny-state end-to-end check of the calibration loop: run the plan
    bench (device placements included), emit BENCH_ckpt.json, validate its
    bench_ckpt/3 schema (placement/codec fields, delta-trigger
    bytes-on-link under the full state, int8 link fraction <= 0.26, fused
    encode under the per-leaf baseline), load it back through
    ``SimCostModel.from_calibration`` (plus v1/v2 artifacts for the
    versioned fallbacks), and drive a micro trainer on a device-encode
    plan."""
    shutil.rmtree(tmpdir, ignore_errors=True)
    os.makedirs(tmpdir, exist_ok=True)
    _, meas = bench_checkpoint(tmpdir + "/micro", scale=1)
    _, plan_stats = bench_plans(tmpdir + "/plans", triggers=6, scale=1)
    # device section at scale=3: the smallest state where the fused flat
    # encode beats the per-leaf dispatch baseline by a margin comfortably
    # outside interpret-mode jitter (at scale 1 the 36 per-leaf dispatches
    # cost ~4 ms total — less than one whole-buffer interpret pass) — the
    # v3 validate gates regress against THIS measurement
    device = bench_device_delta(scale=3)
    path = os.path.join(tmpdir, "BENCH_ckpt.json")
    cal = emit_calibration(path, meas, plan_stats, device)
    with open(path) as f:
        validate_calibration(json.load(f))
    cost = SimCostModel.from_calibration(path, capacity_eps=3000.0)
    assert cost.state_bytes > 0 and cost.ckpt_duration_s > 0
    assert cost.write_duration("delta") <= cost.write_duration("full") \
        or cost.delta_encode_s_per_byte > 0
    assert cost.device_link_fraction_int8 < 1.0, \
        "int8 device deltas must shrink the link traffic"
    # placement pricing: device deltas swap the host encode term
    # (delta_encode_s_per_byte * state_bytes) for the measured device
    # pack + fused encode — the difference must be exactly that swap,
    # nothing dropped or double-charged
    host_d = cost.write_duration("delta")
    dev_d = cost.write_duration("delta", placement="device")
    swap = cost.device_pack_s + cost.device_encode_s \
        - cost.delta_encode_s_per_byte * cost.state_bytes
    assert abs((dev_d - host_d) - swap) < 1e-12, \
        f"device placement mispriced: {dev_d - host_d} != {swap}"
    # link accounting: the modeled per-trigger link bytes must rank the
    # int8-device plan under the host plan (and match the artifact's
    # measured fraction on delta triggers)
    incr8 = CheckpointPlan(mode="incremental", full_every=8)
    dev8 = CheckpointPlan(mode="incremental", full_every=8,
                          encode_placement="device", delta_codec="int8")
    assert cost.avg_link_bytes(dev8) < cost.avg_link_bytes(incr8) \
        == cost.state_bytes, "link-bytes model lost the placement dimension"
    # versioned fallbacks: a v1 artifact (no device section) still loads
    # with the device fields at their modeled defaults, and a v2 artifact
    # (per-leaf device section: no pack_s/per_leaf_encode_s) loads with
    # pack_s at 0 — the per-leaf path packed nothing
    v1 = {k: v for k, v in cal.items() if k != "device"}
    v1["schema"] = "bench_ckpt/1"
    cost_v1 = SimCostModel.from_calibration(v1)
    assert cost_v1.device_link_fraction_int8 == \
        SimCostModel.device_link_fraction_int8
    v2 = json.loads(json.dumps(cal))
    v2["schema"] = "bench_ckpt/2"
    for entry in v2["device"].values():
        del entry["pack_s"], entry["per_leaf_encode_s"]
    cost_v2 = SimCostModel.from_calibration(v2)
    assert cost_v2.device_pack_s == 0.0 \
        and cost_v2.device_encode_s == cost.device_encode_s
    _smoke_device_trainer(tmpdir + "/trainer")
    print(f"smoke OK: {path} validates and loads "
          f"(delta_fraction={cost.delta_fraction:.4f}, "
          f"encode_s_per_byte={cost.delta_encode_s_per_byte:.3e}, "
          f"device int8 link fraction "
          f"{cost.device_link_fraction_int8:.3f})")
    return cal


if __name__ == "__main__":
    main()
