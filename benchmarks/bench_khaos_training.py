"""E9 (beyond-paper capstone): Khaos applied to the TPU *training* domain.

Takes a real architecture's roofline record (experiments/dryrun.json), the
measured checkpoint economics (TrainState bytes over host disk bandwidth)
and a cluster failure model, then runs the full three-phase pipeline —
sequenced by ``KhaosRuntime`` — to pick the checkpoint interval for a
continual-training job ingesting a variable document stream, against
Young/Daly and naive statics.

The day-scale evaluation no longer ticks the scalar engine one
configuration at a time: Khaos AND every static baseline run as lanes of
ONE ``BatchedCampaign``, with the Khaos lane supervised controller-in-the-
loop (``KhaosRuntime.drive_campaign`` + ``BatchedLaneHandle``) — the
Phase-3 counterpart of the batched Phase-2 profiling.

This is the thesis of the adaptation (DESIGN.md §2): the paper's insight
transfers verbatim once "events/s" means "sequences/s" and "consumer lag"
means ingestion backlog.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.config import KhaosConfig
from repro.configs import get_config
from repro.core import (KhaosRuntime, optimize_plan, young_daly_interval)
from repro.data.stream import dense_rates, diurnal_rate, record_workload
from repro.ft.failures import FailureModel
from repro.sim import (BatchedCampaign, BatchedDeployment, LaneSpec,
                       costmodel_from_arch, make_plan_verifier)

DAY = 86_400.0


def _arch_costmodel(arch: str = "yi-6b", dryrun_path: str = "experiments/dryrun.json"):
    cfg = get_config(arch)
    bound = 2.0
    if os.path.exists(dryrun_path):
        recs = json.load(open(dryrun_path))
        for r in recs:
            if r.get("arch") == arch and r.get("shape") == "train_4k" \
                    and r.get("mesh") == "16x16":
                bound = r["bound_step_s"]
                break
    cm = costmodel_from_arch(
        param_count=cfg.param_count(), bound_step_s=bound,
        tokens_per_step=256 * 4096, seq_len=4096,
        n_hosts=64, disk_bw_per_host=1.0e9,
        opt_state_bytes_per_param=12.0)
    return cfg, cm, bound


def bench_khaos_training(arch: str = "yi-6b"):
    cfg, cm, bound = _arch_costmodel(arch)
    print(f"\n=== Khaos for TPU training: {arch} (roofline-bound step "
          f"{bound:.2f}s, ckpt {cm.ckpt_duration_s:.1f}s for "
          f"{cfg.param_count()*12/2**30:.0f} GiB TrainState) ===")

    # ingestion stream: diurnal document arrivals at ~75% of capacity
    sched = diurnal_rate(base=0.62 * cm.capacity_eps, amplitude=0.45,
                         period=DAY, seed=7)
    fm = FailureModel(mtbf_node_s=30 * DAY, num_nodes=64, seed=3)
    mtbf = fm.cluster_mtbf_s
    yd = young_daly_interval(cm.ckpt_duration_s, mtbf)
    print(f"cluster MTBF {mtbf/3600:.1f}h -> Young/Daly CI = {yd:.0f}s")

    # the one phase machine drives Phase 1 -> 2 -> 3
    recording = record_workload(sched, duration=14_400.0, seed=7)
    ci_grid = np.geomspace(max(10.0, yd / 8), yd * 2.5, 6)
    kcfg = KhaosConfig(latency_constraint=4.0 * bound,
                       recovery_constraint=450.0,
                       optimization_period=300.0,
                       ci_min=float(ci_grid[0]), ci_max=float(ci_grid[-1]),
                       reconfig_cooldown=1800.0,
                       num_failure_points=4, smoothing_window=60)
    rt = KhaosRuntime(kcfg)
    rt.record_steady_state(recording)
    prof = rt.run_profiling(
        BatchedDeployment(cm, recording, warmup_s=600,
                          max_recovery_s=3600.0),
        ci_grid, margin=120)
    m_l, m_r = rt.m_l, rt.m_r

    # Phase 3 mechanism search with the simulate-to-verify pass: top plan
    # candidates are replayed through a batched campaign before committing
    plan_opt = optimize_plan(
        m_l, m_r, tr_avg=float(np.mean(recording.counts)),
        l_const=4.0 * bound, r_const=450.0, p=1.0,
        ci_min=float(ci_grid[0]), ci_max=float(ci_grid[-1]), cost=cm,
        mtbf_s=mtbf,
        verifier=make_plan_verifier(cm, recording=recording, warmup_s=600,
                                    margin_s=120, max_recovery_s=3600.0))
    if plan_opt.plan is not None:
        n_sim = sum(1 for c in plan_opt.candidates if c.sim is not None)
        print(f"plan search (simulate-to-verify over {n_sim} candidates): "
              f"{plan_opt.plan.name} @ CI={plan_opt.ci:.0f}s "
              f"(verified={plan_opt.verified})")

    ci0 = rt.initial_ci(float(np.mean(recording.counts)))
    print(f"Khaos initial CI (Eq. 8) = {ci0 and round(ci0)}s")

    # one shared failure schedule so every configuration faces the same day
    t, shared_fails = 0.0, []
    while t < DAY:
        t = fm.next_failure_after(t)
        if t < DAY:
            shared_fails.append((t, "node"))

    # Khaos + every static baseline as lanes of ONE campaign; only the
    # Khaos lane gets a controller (hot CI swap on TPU: no flink restart)
    configs = [("Khaos", ci0 or yd),
               (f"YoungDaly {yd:.0f}s", yd),
               ("static 60s", 60.0),
               ("static 1800s", 1800.0)]
    day_rates = dense_rates(0.0, int(DAY), schedule=sched)
    lanes = [LaneSpec(rates=day_rates, ci_s=float(ci),
                      failures=tuple(shared_fails), tag={"name": name})
             for name, ci in configs]
    camp = BatchedCampaign(cm, lanes, flink_semantics=False)
    sup = rt.drive_campaign(camp, lanes=[0])

    results = {}
    for i, (name, _ci) in enumerate(configs):
        goodput = camp.processed_total[i] / (cm.capacity_eps * DAY)
        recs = [r["recovery_s"] for r in camp.recoveries[i]]
        viol = sum(max(0.0, r - kcfg.recovery_constraint) for r in recs)
        n_reconf = len(sup.reconfigurations(0)) if i == 0 else 0
        print(f"{name:>16s}: goodput {100*goodput:5.1f}%  "
              f"ckpts {camp.ckpt_count[i]:4d}  failures {len(shared_fails)}  "
              f"recoveries {[round(r) for r in recs]}  "
              f"rec-viol {viol:6.0f}s  reconfigs {n_reconf}")
        results[name] = (goodput, viol)
    print(f"phase machine: {' -> '.join(rt.phase_sequence())}  "
          f"(controller-in-the-loop lane decisions: "
          f"{sup.summary()['decisions_by_kind']})")
    return results


def main():
    return bench_khaos_training()


if __name__ == "__main__":
    main()
